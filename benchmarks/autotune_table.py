"""Analytic-vs-measured ChainPlan table (kernels/autotune.py).

For each MobileNetV2 inverted-residual block this tunes the whole chain
with the measured autotuner and reports, side by side, the analytic
planner's blocking and the measured winner, the timings that decided it,
and whether the persistent cache answered (``cache=hit`` rows did ZERO
measurement — that is the CI replay gate).

Quick mode (the default) runs tiny-resolution stand-ins for the V2
geometries so interpret-mode Pallas measurement stays in CI seconds;
``--full`` tunes the real ``MOBILENET_V2_IR`` shapes (use on TPU, where
the compiled kernels make measurement meaningful AND fast).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.layers import MOBILENET_V2_IR, IRBlock
from repro.core import chain
from repro.kernels import autotune
from repro.kernels.policy import KernelPolicy

# Tiny stand-ins for the V2 stages: same stride/residual structure, small
# enough that interpret-mode measurement of the whole candidate ladder is
# a few seconds per block on CPU.
AUTOTUNE_QUICK = [
    IRBlock("V2-IR1q", 16, 8, 4, 8, 2),
    IRBlock("V2-IR4q", 8, 8, 4, 16, 1),
    IRBlock("V2-IR7q", 8, 8, 4, 8, 1),   # residual case (c_in == c_out)
]


def _blocks_str(cp) -> str:
    """Compact per-segment blocking description for the CSV column."""
    out = []
    for seg in cp.segments:
        p = seg.plan
        if seg.kind in ("fused3", "fused2"):
            out.append(f"{seg.kind}:co{p.block_co}xslab{p.slab_h}")
        elif seg.kind == "pw":
            out.append(f"pw:g{p.block_g}")
        else:
            out.append(f"dw:c{p.block_c}")
    return "+".join(out)


def _tune_policy(cache_path: Optional[str]) -> KernelPolicy:
    """Measured tuning wants the real kernels: compiled Pallas on TPU,
    interpret-mode Pallas elsewhere (slow but faithful to the blocking)."""
    on_tpu = jax.default_backend() == "tpu"
    return KernelPolicy(impl="pallas", interpret=not on_tpu,
                        autotune=True, tune_cache=cache_path)


def autotune_rows(cache_path: Optional[str] = None, *,
                  full: bool = False) -> tuple[list[str], list[dict]]:
    """Tune each block, returning (csv_rows, result_records).

    Row format::

        autotune/mobilenet_v2/<name>,<measured_us>,cache=miss|hit;
            analytic=<blocks>;measured=<blocks>;analytic_us=<us>;n_cand=N
    """
    blocks = MOBILENET_V2_IR if full else AUTOTUNE_QUICK
    policy = _tune_policy(cache_path)
    rng = np.random.default_rng(0)
    rows, records = [], []
    for blk in blocks:
        spec = chain.inverted_residual_spec(
            blk.c_in, blk.c_out, expand=blk.expand, stride=blk.stride,
            hf=blk.hf)
        params = chain.init_chain(jax.random.PRNGKey(0), spec, blk.c_in)
        x = jnp.asarray(rng.normal(
            size=(1, blk.h, blk.h, blk.c_in)).astype(np.float32))
        base = chain.plan(spec, x.shape, dtype=x.dtype,
                          policy=dataclasses.replace(policy, autotune=False))
        res = autotune.autotune_chain(spec, params, x, policy=policy,
                                      base_plan=base)
        rec = {
            "name": blk.name,
            "cache": "hit" if res.cache_hit else "miss",
            "analytic_blocks": _blocks_str(base),
            "measured_blocks": _blocks_str(res.plan),
            "measured_us": res.measured_us,
            "analytic_us": res.analytic_us,
            "n_measured": res.n_measured,
            "key": res.key,
        }
        records.append(rec)
        rows.append(
            f"autotune/mobilenet_v2/{blk.name},{res.measured_us:.1f},"
            f"cache={rec['cache']};analytic={rec['analytic_blocks']};"
            f"measured={rec['measured_blocks']};"
            f"analytic_us={res.analytic_us:.1f};n_cand={res.n_measured}")
    return rows, records


if __name__ == "__main__":
    for row in autotune_rows()[0]:
        print(row)
