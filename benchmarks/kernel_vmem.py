"""BlockSpec/VMEM design table for the Pallas kernels.

The paper's register-tiling argument, one level up: BlockSpec shapes
determine the VMEM working set each kernel *claims*, and the MXU wants
its matmul dims in multiples of 128. This table enumerates the shipped
block-shape choices per workload and reports:

* VMEM bytes claimed (incl. 2x input double-buffering where streamed),
* whether the MXU-facing dims are 128-aligned,
* the kernel-level AI (FLOPs per HBM byte) at those blocks,
* v5e roofline time and the bound (MXU vs HBM).

Structural analysis from the lowering parameters — no TPU needed.
"""
from __future__ import annotations

from repro.core import intensity as it
from repro.kernels.dwconv2d import _block_c
from repro.kernels.separable_fused import _block_sizes, _vmem_bytes

PEAK = 197e12
HBM = 819e9
VMEM = 16 * 2**20


def dwconv2d_rows(layers) -> list[dict]:
    rows = []
    for l in layers:
        ho = (l.h - l.hf) // l.stride + 1
        wo = (l.w - l.hf) // l.stride + 1
        cb = _block_c(l.h, l.w, ho, wo, l.c)
        vmem = (2 * l.h * l.w + ho * wo) * cb * 4 + l.hf * l.hf * cb * 4
        t = it.dwconv2d_traffic(1, l.h, l.w, l.c, l.hf, l.hf, l.stride)
        tc, tm = t.time_s(PEAK, HBM)
        rows.append({
            "name": l.name,
            "block_c": cb,
            "lane_aligned": cb % 128 == 0 or cb == l.c,
            "vmem_bytes": vmem,
            "vmem_ok": vmem <= VMEM,
            "ai_flops_per_byte": t.intensity,
            "bound": "HBM" if tm > tc else "MXU",
            "roofline_us": max(tc, tm) * 1e6,
        })
    return rows


def pwconv_rows(layers, bg=256, bco=256, bci=256) -> list[dict]:
    rows = []
    for l in layers:
        g = l.h * l.w
        # acc f32 + 2x double-buffered A/B tiles (bf16-widths use 4 here: f32)
        vmem = (bg * bco * 4) + 2 * (bg * bci + bci * bco) * 4
        t = it.pwconv_traffic_rtrd(g, l.c_in, l.c_out, bg, bci, bco)
        tc, tm = t.time_s(PEAK, HBM)
        rows.append({
            "name": l.name,
            "blocks": f"{min(bg,g)}x{min(bco,l.c_out)}x{min(bci,l.c_in)}",
            "mxu_aligned": (bco % 128 == 0 and bci % 128 == 0),
            "vmem_bytes": vmem,
            "vmem_ok": vmem <= VMEM,
            "ai_flops_per_byte": t.intensity,
            "bound": "HBM" if tm > tc else "MXU",
            "roofline_us": max(tc, tm) * 1e6,
        })
    return rows


def separable_fused_rows(blocks) -> list[dict]:
    """VMEM claim of the fused DW+PW kernel at the chooser's block shapes:
    2x input slab + DW intermediate + fp32 accumulator + out tile + 2x W."""
    from benchmarks.layers import sep_geometry

    rows = []
    for blk in blocks:
        s = blk.stride
        hi, wi, ho, wo = sep_geometry(blk)
        picked = _block_sizes(hi, wi, ho, wo, blk.c_in, blk.c_out)
        if picked is None:
            rows.append({"name": blk.name, "fusible": False})
            continue
        cb, cob = picked
        vmem = _vmem_bytes(hi, wi, ho, wo, cb, cob)
        t = it.separable_traffic_fused(
            1, hi, wi, blk.c_in, blk.c_out, blk.hf, blk.hf, s, block_co=cob)
        tc, tm = t.time_s(PEAK, HBM)
        rows.append({
            "name": blk.name,
            "fusible": True,
            "block_c": cb,
            "block_co": cob,
            "vmem_bytes": vmem,
            "vmem_ok": vmem <= VMEM,
            "ai_flops_per_byte": t.intensity,
            "bound": "HBM" if tm > tc else "MXU",
            "roofline_us": max(tc, tm) * 1e6,
        })
    return rows


def csv_rows() -> list[str]:
    from benchmarks.layers import SEP_SUITES, SUITES
    out = []
    dws, pws = SUITES["mobilenet_v1"]
    for r in dwconv2d_rows(dws):
        out.append(
            f"vmem/dwconv2d/{r['name']},{r['roofline_us']:.1f},"
            f"block_c={r['block_c']};vmem_KiB={r['vmem_bytes']//1024};"
            f"fits={r['vmem_ok']};AI={r['ai_flops_per_byte']:.2f};"
            f"bound={r['bound']}")
    for r in pwconv_rows(pws):
        out.append(
            f"vmem/pwconv/{r['name']},{r['roofline_us']:.1f},"
            f"blocks={r['blocks']};vmem_KiB={r['vmem_bytes']//1024};"
            f"fits={r['vmem_ok']};mxu128={r['mxu_aligned']};"
            f"AI={r['ai_flops_per_byte']:.2f};bound={r['bound']}")
    for r in separable_fused_rows(SEP_SUITES["mobilenet_v1"]):
        if not r["fusible"]:
            out.append(f"vmem/sepfused/{r['name']},0.0,fusible=False")
            continue
        out.append(
            f"vmem/sepfused/{r['name']},{r['roofline_us']:.1f},"
            f"blocks=c{r['block_c']}xco{r['block_co']};"
            f"vmem_KiB={r['vmem_bytes']//1024};fits={r['vmem_ok']};"
            f"AI={r['ai_flops_per_byte']:.2f};bound={r['bound']}")
    return out
