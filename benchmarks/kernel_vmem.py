"""BlockSpec/VMEM design table for the Pallas kernels.

The paper's register-tiling argument, one level up: BlockSpec shapes
determine the VMEM working set each kernel *claims*, and the MXU wants
its matmul dims in multiples of 128. This table enumerates the planner's
block choices (kernels/blocking.py — the single owner of that logic) per
workload and reports:

* VMEM bytes claimed (incl. 2x input double-buffering where streamed),
  budgeted at the activation dtype's width — bf16 rows claim ~2x less,
* whether the MXU-facing dims are 128-aligned,
* the row-slab split the fused kernel runs at (slab_h x n_slabs),
* the kernel-level AI (FLOPs per HBM byte) at those blocks,
* v5e roofline time and the bound (MXU vs HBM).

Structural analysis from the lowering parameters — no TPU needed.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import intensity as it
from repro.kernels import blocking

PEAK = 197e12
HBM = 819e9
VMEM = 16 * 2**20


def dwconv2d_rows(layers, dtype=jnp.float32) -> list[dict]:
    nb = blocking.dtype_bytes(dtype)
    rows = []
    for l in layers:
        ho = (l.h - l.hf) // l.stride + 1
        wo = (l.w - l.hf) // l.stride + 1
        plan = blocking.plan_dwconv2d(l.h, l.w, ho, wo, l.c, l.hf, l.hf,
                                      dtype=dtype)
        t = it.dwconv2d_traffic(1, l.h, l.w, l.c, l.hf, l.hf, l.stride,
                                dtype_bytes=nb)
        tc, tm = t.time_s(PEAK, HBM)
        rows.append({
            "name": l.name,
            "block_c": plan.block_c,
            "lane_aligned": plan.block_c % 128 == 0 or plan.block_c == l.c,
            "vmem_bytes": plan.vmem_bytes,
            "vmem_ok": plan.vmem_bytes <= VMEM,
            "ai_flops_per_byte": t.intensity,
            "bound": "HBM" if tm > tc else "MXU",
            "roofline_us": max(tc, tm) * 1e6,
        })
    return rows


def pwconv_rows(layers, dtype=jnp.float32) -> list[dict]:
    nb = blocking.dtype_bytes(dtype)
    rows = []
    for l in layers:
        g = l.h * l.w
        plan = blocking.plan_pwconv(g, l.c_in, l.c_out, dtype=dtype)
        bg, bco, bci = plan.block_g, plan.block_co, plan.block_c
        t = it.pwconv_traffic_rtrd(g, l.c_in, l.c_out, bg, bci, bco,
                                   dtype_bytes=nb)
        tc, tm = t.time_s(PEAK, HBM)
        rows.append({
            "name": l.name,
            "blocks": f"{min(bg,g)}x{min(bco,l.c_out)}x{min(bci,l.c_in)}",
            "mxu_aligned": (bco % 128 == 0 and bci % 128 == 0),
            "vmem_bytes": plan.vmem_bytes,
            "vmem_ok": plan.vmem_bytes <= VMEM,
            "ai_flops_per_byte": t.intensity,
            "bound": "HBM" if tm > tc else "MXU",
            "roofline_us": max(tc, tm) * 1e6,
        })
    return rows


def separable_fused_rows(blocks, dtype=jnp.float32) -> list[dict]:
    """VMEM claim of the fused DW+PW kernel at the planner's block shapes
    (2x input slab + DW intermediate + fp32 accumulator + out tile + 2x W),
    including the row-slab split that keeps high-resolution blocks fusible."""
    from benchmarks.layers import sep_geometry

    nb = blocking.dtype_bytes(dtype)
    rows = []
    for blk in blocks:
        s = blk.stride
        hi, wi, ho, wo = sep_geometry(blk)
        plan = blocking.plan_separable(ho, wo, blk.c_in, blk.c_out,
                                       stride=s, hf=blk.hf, wf=blk.hf,
                                       dtype=dtype)
        if plan is None:
            rows.append({"name": blk.name, "fusible": False})
            continue
        t = it.separable_traffic_fused(
            1, hi, wi, blk.c_in, blk.c_out, blk.hf, blk.hf, s,
            block_co=plan.block_co, slab_h=plan.slab_h, dtype_bytes=nb)
        tc, tm = t.time_s(PEAK, HBM)
        rows.append({
            "name": blk.name,
            "fusible": True,
            "block_c": plan.block_c,
            "block_co": plan.block_co,
            "slab_h": plan.slab_h,
            "n_slabs": plan.n_slabs,
            "halo_rows": plan.halo_rows,
            "vmem_bytes": plan.vmem_bytes,
            "vmem_ok": plan.vmem_bytes <= VMEM,
            "ai_flops_per_byte": t.intensity,
            "bound": "HBM" if tm > tc else "MXU",
            "roofline_us": max(tc, tm) * 1e6,
        })
    return rows


def fused3_rows(blocks, dtype=jnp.float32) -> list[dict]:
    """VMEM claim of the 3-stage fused kernel (expand-on-the-fly) at the
    planner's blocks, per whole MobileNetV2 inverted residual: the 2-stage
    working set plus the raw-input window, the expand-weight tile and the
    fp32 expanded value (kernels/blocking.fused3_vmem_bytes).

    The block shapes come from the SAME planner path the op runs
    (core/chain.plan over an inverted_residual_spec — residual rule and
    all), so this table cannot drift from what actually lowers."""
    from repro.core import chain

    nb = blocking.dtype_bytes(dtype)
    rows = []
    for blk in blocks:
        ho = -(-blk.h // blk.stride)
        hi = (ho - 1) * blk.stride + blk.hf
        spec = chain.inverted_residual_spec(
            blk.c_in, blk.c_out, expand=blk.expand, stride=blk.stride,
            hf=blk.hf)
        cp = chain.plan(spec, (1, blk.h, blk.h, blk.c_in), dtype=dtype)
        if [s.kind for s in cp.segments] != ["fused3"]:
            rows.append({"name": blk.name, "fusible": False})
            continue
        plan = cp.segments[0].plan
        t = it.separable_traffic_fused3(
            1, hi, hi, blk.c_in, blk.c_mid, blk.c_out, blk.hf, blk.hf,
            blk.stride, block_co=plan.block_co, slab_h=plan.slab_h,
            dtype_bytes=nb)
        tc, tm = t.time_s(PEAK, HBM)
        rows.append({
            "name": blk.name,
            "fusible": True,
            "block_c": plan.block_c,
            "block_co": plan.block_co,
            "slab_h": plan.slab_h,
            "n_slabs": plan.n_slabs,
            "vmem_bytes": plan.vmem_bytes,
            "vmem_ok": plan.vmem_bytes <= VMEM,
            "ai_flops_per_byte": t.intensity,
            "bound": "HBM" if tm > tc else "MXU",
            "roofline_us": max(tc, tm) * 1e6,
        })
    return rows


def csv_rows() -> list[str]:
    from benchmarks.layers import MOBILENET_V2_IR, SEP_SUITES, SUITES
    out = []
    dws, pws = SUITES["mobilenet_v1"]
    for r in dwconv2d_rows(dws):
        out.append(
            f"vmem/dwconv2d/{r['name']},{r['roofline_us']:.1f},"
            f"block_c={r['block_c']};vmem_KiB={r['vmem_bytes']//1024};"
            f"fits={r['vmem_ok']};AI={r['ai_flops_per_byte']:.2f};"
            f"bound={r['bound']}")
    for r in pwconv_rows(pws):
        out.append(
            f"vmem/pwconv/{r['name']},{r['roofline_us']:.1f},"
            f"blocks={r['blocks']};vmem_KiB={r['vmem_bytes']//1024};"
            f"fits={r['vmem_ok']};mxu128={r['mxu_aligned']};"
            f"AI={r['ai_flops_per_byte']:.2f};bound={r['bound']}")
    for suite in ("mobilenet_v1", "hires"):
        for dt, tag in ((jnp.float32, "sepfused"), (jnp.bfloat16,
                                                    "sepfused_bf16")):
            for r in separable_fused_rows(SEP_SUITES[suite], dtype=dt):
                if not r["fusible"]:
                    out.append(f"vmem/{tag}/{suite}/{r['name']},0.0,"
                               "fusible=False")
                    continue
                out.append(
                    f"vmem/{tag}/{suite}/{r['name']},{r['roofline_us']:.1f},"
                    f"blocks=c{r['block_c']}xco{r['block_co']}"
                    f"xs{r['slab_h']};n_slabs={r['n_slabs']};"
                    f"vmem_KiB={r['vmem_bytes']//1024};fits={r['vmem_ok']};"
                    f"AI={r['ai_flops_per_byte']:.2f};bound={r['bound']}")
    for dt, tag in ((jnp.float32, "sepfused3"), (jnp.bfloat16,
                                                 "sepfused3_bf16")):
        for r in fused3_rows(MOBILENET_V2_IR, dtype=dt):
            if not r["fusible"]:
                out.append(f"vmem/{tag}/mobilenet_v2/{r['name']},0.0,"
                           "fusible=False")
                continue
            out.append(
                f"vmem/{tag}/mobilenet_v2/{r['name']},"
                f"{r['roofline_us']:.1f},"
                f"blocks=c{r['block_c']}xco{r['block_co']}"
                f"xs{r['slab_h']};n_slabs={r['n_slabs']};"
                f"vmem_KiB={r['vmem_bytes']//1024};fits={r['vmem_ok']};"
                f"AI={r['ai_flops_per_byte']:.2f};bound={r['bound']}")
    return out
