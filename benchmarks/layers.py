"""The paper's benchmark workloads: DWConv / PWConv layers extracted from
MobileNetV1, MobileNetV2 and MnasNet-A1 (paper figs. 4-6).

Shapes follow the architecture papers:
* MobileNetV1 (arXiv:1704.04861, Table 1) — D1..D9 depthwise layers and the
  pointwise layers that follow them.
* MobileNetV2 (arXiv:1801.04381, Table 2) — depthwise stages of the inverted
  residuals (expanded channels) and expand/project pointwise layers.
* MnasNet-A1 (arXiv:1807.11626, Fig. 7) — includes 5x5 depthwise stages.
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class DWLayer:
    name: str
    h: int
    w: int
    c: int
    hf: int
    stride: int


@dataclasses.dataclass(frozen=True)
class PWLayer:
    name: str
    h: int
    w: int
    c_in: int
    c_out: int


@dataclasses.dataclass(frozen=True)
class SepBlock:
    """A whole depthwise-separable block (DW -> act -> PW): the unit the
    fused kernel (kernels/separable_fused.py) executes in one pass."""
    name: str
    h: int          # input spatial size (square input assumed, SAME pad)
    w: int
    c_in: int       # DW channels == PW reduction dim
    c_out: int
    stride: int
    hf: int = 3


def sep_geometry(blk: SepBlock) -> tuple[int, int, int, int]:
    """SAME-pad geometry the fused kernel sees: (hi, wi, ho, wo), with
    hi/wi the VALID-equivalent padded input dims. Single source for every
    traffic/VMEM table over SepBlocks."""
    s = blk.stride
    ho, wo = -(-blk.h // s), -(-blk.w // s)
    return (ho - 1) * s + blk.hf, (wo - 1) * s + blk.hf, ho, wo


MOBILENET_V1_DW = [
    DWLayer("V1-D1", 112, 112, 32, 3, 1),
    DWLayer("V1-D2", 112, 112, 64, 3, 2),
    DWLayer("V1-D3", 56, 56, 128, 3, 1),
    DWLayer("V1-D4", 56, 56, 128, 3, 2),
    DWLayer("V1-D5", 28, 28, 256, 3, 1),
    DWLayer("V1-D6", 28, 28, 256, 3, 2),
    DWLayer("V1-D7", 14, 14, 512, 3, 1),
    DWLayer("V1-D8", 14, 14, 512, 3, 2),
    DWLayer("V1-D9", 7, 7, 1024, 3, 1),
]

MOBILENET_V1_PW = [
    PWLayer("V1-P1", 112, 112, 32, 64),
    PWLayer("V1-P2", 56, 56, 64, 128),
    PWLayer("V1-P3", 56, 56, 128, 128),
    PWLayer("V1-P4", 28, 28, 128, 256),
    PWLayer("V1-P5", 28, 28, 256, 256),
    PWLayer("V1-P6", 14, 14, 256, 512),
    PWLayer("V1-P7", 14, 14, 512, 512),
    PWLayer("V1-P8", 7, 7, 512, 1024),
    PWLayer("V1-P9", 7, 7, 1024, 1024),
]

MOBILENET_V2_DW = [
    DWLayer("V2-D1", 112, 112, 32, 3, 1),
    DWLayer("V2-D2", 112, 112, 96, 3, 2),
    DWLayer("V2-D3", 56, 56, 144, 3, 1),
    DWLayer("V2-D4", 56, 56, 144, 3, 2),
    DWLayer("V2-D5", 28, 28, 192, 3, 1),
    DWLayer("V2-D6", 28, 28, 192, 3, 2),
    DWLayer("V2-D7", 14, 14, 384, 3, 1),
    DWLayer("V2-D8", 14, 14, 576, 3, 1),
    DWLayer("V2-D9", 14, 14, 576, 3, 2),
    DWLayer("V2-D10", 7, 7, 960, 3, 1),
]

MOBILENET_V2_PW = [
    PWLayer("V2-P1", 112, 112, 32, 16),
    PWLayer("V2-P2", 112, 112, 16, 96),
    PWLayer("V2-P3", 56, 56, 96, 24),
    PWLayer("V2-P4", 56, 56, 24, 144),
    PWLayer("V2-P5", 28, 28, 144, 32),
    PWLayer("V2-P6", 28, 28, 32, 192),
    PWLayer("V2-P7", 14, 14, 192, 64),
    PWLayer("V2-P8", 14, 14, 64, 384),
    PWLayer("V2-P9", 14, 14, 96, 576),
    PWLayer("V2-P10", 7, 7, 576, 160),
    PWLayer("V2-P11", 7, 7, 160, 960),
    PWLayer("V2-P12", 7, 7, 960, 320),
]

MNASNET_A1_DW = [
    DWLayer("A1-D1", 112, 112, 32, 3, 1),
    DWLayer("A1-D2", 112, 112, 96, 3, 2),
    DWLayer("A1-D3", 56, 56, 144, 3, 1),
    DWLayer("A1-D4", 56, 56, 144, 5, 2),      # 5x5 stage
    DWLayer("A1-D5", 28, 28, 240, 5, 1),
    DWLayer("A1-D6", 28, 28, 240, 3, 2),
    DWLayer("A1-D7", 14, 14, 480, 3, 1),
    DWLayer("A1-D8", 14, 14, 672, 5, 1),
    DWLayer("A1-D9", 14, 14, 672, 5, 2),
    DWLayer("A1-D10", 7, 7, 960, 5, 1),
]

MNASNET_A1_PW = [
    PWLayer("A1-P1", 112, 112, 32, 16),
    PWLayer("A1-P2", 56, 56, 96, 24),
    PWLayer("A1-P3", 56, 56, 24, 144),
    PWLayer("A1-P4", 28, 28, 144, 40),
    PWLayer("A1-P5", 28, 28, 40, 240),
    PWLayer("A1-P6", 14, 14, 240, 80),
    PWLayer("A1-P7", 14, 14, 80, 480),
    PWLayer("A1-P8", 14, 14, 672, 112),
    PWLayer("A1-P9", 7, 7, 672, 160),
    PWLayer("A1-P10", 7, 7, 960, 320),
]

SUITES = {
    "mobilenet_v1": (MOBILENET_V1_DW, MOBILENET_V1_PW),
    "mobilenet_v2": (MOBILENET_V2_DW, MOBILENET_V2_PW),
    "mnasnet_a1": (MNASNET_A1_DW, MNASNET_A1_PW),
}

# MobileNetV1 body as whole separable blocks (Table 1): the fused-vs-unfused
# benchmark unit. (c_in, c_out, stride) at each block's input resolution.
MOBILENET_V1_SEP = [
    SepBlock("V1-B1", 112, 112, 32, 64, 1),
    SepBlock("V1-B2", 112, 112, 64, 128, 2),
    SepBlock("V1-B3", 56, 56, 128, 128, 1),
    SepBlock("V1-B4", 56, 56, 128, 256, 2),
    SepBlock("V1-B5", 28, 28, 256, 256, 1),
    SepBlock("V1-B6", 28, 28, 256, 512, 2),
    SepBlock("V1-B7", 14, 14, 512, 512, 1),
    SepBlock("V1-B12", 14, 14, 512, 1024, 2),
    SepBlock("V1-B13", 7, 7, 1024, 1024, 1),
]

# MobileNetV2 inverted-residual tails (DW at expanded width -> PW-project):
# the slice the fused kernel covers inside an inverted residual.
MOBILENET_V2_SEP = [
    SepBlock("V2-T2", 112, 112, 96, 24, 2),
    SepBlock("V2-T3", 56, 56, 144, 32, 2),
    SepBlock("V2-T5", 28, 28, 192, 64, 2),
    SepBlock("V2-T6", 14, 14, 384, 96, 1),
    SepBlock("V2-T7", 7, 7, 960, 320, 1),
]

# High-resolution separable blocks (dense-prediction / segmentation-style
# inputs): Ho*Wo is far above the old ~1.5M-pixel fused-accumulator ceiling,
# so these were fallback-only before row-slab blocking (DESIGN.md §3). The
# fused-vs-unfused tables report coverage here to catch regressions of the
# slab planner.
HIRES_SEP = [
    SepBlock("HR-B1", 1504, 1504, 32, 32, 1),
    SepBlock("HR-B2", 1504, 1504, 32, 64, 2),
    SepBlock("HR-B3", 2048, 2048, 16, 32, 1),
]

SEP_SUITES = {
    "mobilenet_v1": MOBILENET_V1_SEP,
    "mobilenet_v2": MOBILENET_V2_SEP,
    "hires": HIRES_SEP,
}


@dataclasses.dataclass(frozen=True)
class IRBlock:
    """A WHOLE MobileNetV2 inverted residual (PW-expand -> DW -> PW-project
    [+ residual]): the unit the declarative chain API plans and the 3-stage
    fused kernel executes in one pass (DESIGN.md §5)."""
    name: str
    h: int          # input spatial size at the block (square)
    c_in: int       # raw input width (pre-expansion)
    expand: int     # expansion factor (c_mid = c_in * expand)
    c_out: int
    stride: int
    hf: int = 3

    @property
    def c_mid(self) -> int:
        return self.c_in * self.expand


# MobileNetV2 (arXiv:1801.04381, Table 2) bottleneck stages as whole blocks:
# one representative block per stage (first block of the stage; strided
# blocks carry no residual, the 14x14x64/96 stage-1 blocks do).
MOBILENET_V2_IR = [
    IRBlock("V2-IR1", 112, 16, 6, 24, 2),
    IRBlock("V2-IR2", 56, 24, 6, 32, 2),
    IRBlock("V2-IR3", 28, 32, 6, 64, 2),
    IRBlock("V2-IR4", 14, 64, 6, 96, 1),
    IRBlock("V2-IR5", 14, 96, 6, 160, 2),
    IRBlock("V2-IR6", 7, 160, 6, 320, 1),
    IRBlock("V2-IR7", 14, 64, 6, 64, 1),   # residual case (c_in == c_out)
]
