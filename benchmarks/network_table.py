"""Whole-network traffic table (DESIGN.md §7): what the network engine
plans a full MobileNet body to, and what moving the streamed operands at
bf16 saves.

One row per (arch x body-input resolution):

* ``passes`` / ``histo`` / ``single_pass`` — the NetworkPlan's kernel-pass
  count and per-segment-kind histogram; ``single_pass=True`` means every
  block lowers to ONE fused kernel pass.
* ``ir_fused3`` — every 3-stage block (the t=6 inverted residuals) planned
  to the 3-stage fused kernel, under BOTH the fp32 and bf16 policies.
* ``se_fused`` / ``mb_fused`` — every SE-carrying block planned to the
  fused ``dw_se`` segment (no standalone two-GEMM ``se`` pass) and every
  FusedMB-led block planned to the single-pass ``fusedmb`` segment, under
  both policies; vacuously True for archs without those stages
  (DESIGN.md §10).
* ``MB_unfused`` / ``MB_fp32`` / ``MB_bf16`` — modeled HBM bytes of the
  per-block unfused composition (fp32), the fused fp32 network, and the
  bf16-streamed network (``core.intensity.network_traffic`` — bytes at each
  plan's budgeted stream width).
* ``traffic_ok`` — the CI gate predicate, computed here in Python:
  ``MB_bf16 < MB_fp32 < MB_unfused`` strictly.

Dry-run only (shape arithmetic, no compilation): cheap enough to run every
geometry every time.
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import intensity as it
from repro.core import network
from repro.kernels.policy import DtypePolicy, KernelPolicy

#: Body-input resolutions benchmarked (a 224 ImageNet image reaches the
#: body at 112 after the stride-2 stem; 56 and 224 bracket it).
RESOLUTIONS = (56, 112, 224)


def benchmarked_networks():
    """(name, NetworkSpec) per benchmarked arch — the single source the
    trajectory baseline, the analysis sweep and this table share."""
    return (("mobilenet_v1", network.mobilenet_v1_spec()),
            ("mobilenet_v2", network.mobilenet_v2_spec()),
            ("mnasnet_a1", network.mnasnet_a1_spec()),
            ("efficientnet_lite0", network.efficientnet_lite0_spec()))


def _has_stage(spec, attr: str) -> bool:
    return any(hasattr(s, attr) for s in spec.stages)


def network_rows(resolutions=RESOLUTIONS) -> list:
    rows = []
    p32 = KernelPolicy()
    pbf = KernelPolicy(dtype_policy=DtypePolicy(stream="bfloat16"))
    punf = KernelPolicy(fused=False)
    for name, net in benchmarked_networks():
        for res in resolutions:
            shape = (1, res, res, net.c_in)
            n32 = network.plan_network(net, shape, policy=p32)
            nbf = network.plan_network(net, shape, policy=pbf)
            nunf = network.plan_network(net, shape, policy=punf)
            t32 = it.network_traffic(net, n32)
            tbf = it.network_traffic(net, nbf)
            tunf = it.network_traffic(net, nunf)
            # every 3-stage all-separable block must plan fused3 under both
            # dtype policies
            ir_fused3 = all(
                p.segments[0].kind == "fused3"
                for nplan in (n32, nbf)
                for spec, p in zip(net.blocks, nplan.plans)
                if len(spec.stages) == 3
                and not _has_stage(spec, "reduce"))
            # SE blocks fuse the gate onto the DW pass; FusedMB blocks plan
            # the single conv+project pass (vacuously True without them)
            se_fused = all(
                any(s.kind == "dw_se" for s in p.segments)
                for nplan in (n32, nbf)
                for spec, p in zip(net.blocks, nplan.plans)
                if _has_stage(spec, "reduce"))
            mb_fused = all(
                p.segments[0].kind == "fusedmb"
                for nplan in (n32, nbf)
                for spec, p in zip(net.blocks, nplan.plans)
                if any(hasattr(s, "features") and hasattr(s, "stride")
                       for s in spec.stages))
            rows.append({
                "name": f"{name}/res{res}",
                "blocks": net.n_blocks,
                "passes": n32.n_kernel_passes,
                "histo": "+".join(
                    f"{k}:{v}" for k, v in
                    sorted(n32.segment_histogram().items())),
                "single_pass": bool(n32.fully_fused and nbf.fully_fused),
                "ir_fused3": bool(ir_fused3),
                "se_fused": bool(se_fused),
                "mb_fused": bool(mb_fused),
                "mb_unfused": tunf.bytes_hbm / 1e6,
                "mb_fp32": t32.bytes_hbm / 1e6,
                "mb_bf16": tbf.bytes_hbm / 1e6,
                "gflops": t32.flops / 1e9,
                "traffic_ok": bool(
                    tbf.bytes_hbm < t32.bytes_hbm < tunf.bytes_hbm),
            })
    return rows


def csv_network_rows(rows=None) -> list:
    """``network/<arch>/res<N>`` rows for benchmarks/run.py."""
    out = []
    for r in rows if rows is not None else network_rows():
        out.append(
            f"network/{r['name']},0.0,"
            f"blocks={r['blocks']};passes={r['passes']};"
            f"histo={r['histo']};single_pass={r['single_pass']};"
            f"ir_fused3={r['ir_fused3']};"
            f"se_fused={r['se_fused']};mb_fused={r['mb_fused']};"
            f"MB_unfused={r['mb_unfused']:.2f};"
            f"MB_fp32={r['mb_fp32']:.2f};MB_bf16={r['mb_bf16']:.2f};"
            f"GFLOP={r['gflops']:.3f};traffic_ok={r['traffic_ok']}")
    return out


def markdown_table(rows=None) -> str:
    rows = rows if rows is not None else network_rows()
    lines = [
        "| network | blocks | passes | plan | MB unfused | MB fp32 "
        "| MB bf16 | ok |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        lines.append(
            f"| {r['name']} | {r['blocks']} | {r['passes']} | {r['histo']} "
            f"| {r['mb_unfused']:.2f} | {r['mb_fp32']:.2f} "
            f"| {r['mb_bf16']:.2f} | {r['traffic_ok']} |")
    return "\n".join(lines)


if __name__ == "__main__":
    print(markdown_table())
