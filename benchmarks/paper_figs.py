"""Paper figures 4-7: per-layer DWConv/PWConv benchmarks + core scaling.

This container has no ARM core and no TPU, so each figure has two honest
components:

1. **measured**   — CPU wall-time of the *runnable* implementations: the
   XLA-compiled reference ops (the framework's CPU execution path), with the
   unoptimized 5-loop Algorithm-1 oracle timed on the smallest layer to
   anchor the "Unoptimized" point of the paper's Fig. 1.
2. **modeled**    — the paper's own analytical machinery (core/intensity.py):
   per-layer arithmetic intensity of TF-Lite's loop structure vs ours
   (DWConv: T_tf vs eq. 1; PWConv: RTRA vs RTRD), and the TPU-v5e roofline
   time of each variant's HBM traffic. The modeled speedup column is the
   reproduction of the paper's figure bars; the paper's measured ARM
   speedups (2.9-9x over TF-Lite, up to 5.5x over TVM for DWConv) are
   quoted alongside for validation.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.layers import SEP_SUITES, SUITES, sep_geometry
from repro.core import intensity as it
from repro.kernels import blocking, ref

# v5e single-chip constants (roofline/analysis.py)
PEAK = 197e12
HBM = 819e9
# quad-core Cortex-A57 (paper fig. 1): ~32 GFLOP/s fp32 peak, ~25.6 GB/s LPDDR4
ARM_PEAK = 32e9
ARM_BW = 25.6e9


def _time_jit(fn, *args, reps=5, measure=True) -> float:
    """Wall-time ``fn`` in us; with ``measure=False`` (the --dry-run path)
    skip compilation + timing entirely and report 0.0 — the analytical
    columns are the dry-run deliverable."""
    if not measure:
        return 0.0
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6  # us


def bench_dw_layer(layer, rng, measure=True) -> dict:
    us = 0.0
    if measure:   # dry-run needs only shapes — never materialize inputs
        x = jnp.asarray(rng.normal(size=(1, layer.h, layer.w, layer.c))
                        .astype(np.float32))
        f = jnp.asarray(rng.normal(size=(layer.hf, layer.hf, layer.c))
                        .astype(np.float32))
        xla = jax.jit(lambda x, f: ref.dwconv2d_ref(
            x, f, stride=layer.stride, padding="valid"))
        us = _time_jit(xla, x, f)

    # paper-model AI + roofline times (per-variant HBM traffic)
    ours = it.dwconv2d_traffic(1, layer.h, layer.w, layer.c, layer.hf,
                               layer.hf, layer.stride)
    tf4 = it.dwconv2d_traffic_rowpar(1, layer.h, layer.w, layer.c, layer.hf,
                                     layer.hf, layer.stride, p=4)
    t_ours = max(ours.time_s(PEAK, HBM))
    t_tf = max(tf4.time_s(PEAK, HBM))
    ai_ours = it.t_ours_dw_asymptotic(layer.hf, layer.hf)
    ai_tf = it.t_tf_dw(4)
    return {
        "name": layer.name,
        "us_xla_cpu": us,
        "ai_ours": ai_ours,
        "ai_tflite": ai_tf,
        "ai_ratio": ai_ours / ai_tf,
        "bytes_ours": ours.bytes_hbm,
        "bytes_rowpar4": tf4.bytes_hbm,
        "modeled_speedup": t_tf / t_ours,
    }


def bench_pw_layer(layer, rng, measure=True) -> dict:
    g = layer.h * layer.w
    us = us_rtra = 0.0
    if measure:   # dry-run needs only shapes — never materialize inputs
        a = jnp.asarray(rng.normal(size=(g, layer.c_in)).astype(np.float32))
        b = jnp.asarray(rng.normal(size=(layer.c_in, layer.c_out))
                        .astype(np.float32))
        xla = jax.jit(lambda a, b: ref.pwconv_ref(a, b))
        us = _time_jit(xla, a, b)
        rtra_fn = jax.jit(lambda a, b: ref.matmul_rtra_ref(a, b, block_k=128))
        us_rtra = _time_jit(rtra_fn, a, b)

    # (3) model AI/roofline at the planner's blocks — the grid every default
    # ops.pwconv call actually runs at (keeps this table consistent with
    # benchmarks/kernel_vmem.py)
    pw_plan = blocking.plan_pwconv(g, layer.c_in, layer.c_out)
    bg, bco, bci = pw_plan.block_g, pw_plan.block_co, pw_plan.block_c
    rtrd = it.pwconv_traffic_rtrd(g, layer.c_in, layer.c_out, bg, bci, bco)
    rtra = it.pwconv_traffic_rtra(g, layer.c_in, layer.c_out, bg, bci, bco)
    t_rtrd = max(rtrd.time_s(PEAK, HBM))
    t_rtra = max(rtra.time_s(PEAK, HBM))
    return {
        "name": layer.name,
        "us_xla_cpu": us,
        "us_rtra_loop_cpu": us_rtra,
        "ai_rtrd": it.t_rtrd_pw(ci=layer.c_in),
        "ai_rtra": it.t_rtra_pw(co=layer.c_out),
        "bytes_rtrd": rtrd.bytes_hbm,
        "bytes_rtra": rtra.bytes_hbm,
        "modeled_speedup": t_rtra / t_rtrd,
    }


def bench_separable_block(blk, rng, measure=True) -> dict:
    """Fused vs unfused separable block: measured CPU wall-time of both XLA
    paths, plus the modeled HBM traffic of the two kernel strategies — the
    'saved' column is the DW intermediate round-trip (DESIGN.md §3)."""
    us_unfused = us_fused = 0.0
    if measure:   # dry-run needs only shapes — never materialize inputs
        x = jnp.asarray(rng.normal(size=(1, blk.h, blk.w, blk.c_in))
                        .astype(np.float32))
        f = jnp.asarray(rng.normal(size=(blk.hf, blk.hf, blk.c_in))
                        .astype(np.float32) / blk.hf)
        w = jnp.asarray(rng.normal(size=(blk.c_in, blk.c_out))
                        .astype(np.float32) * blk.c_in ** -0.5)
        db = jnp.zeros((blk.c_in,), jnp.float32)
        pb = jnp.zeros((blk.c_out,), jnp.float32)

        def unfused(x, f, w, db, pb):
            y = ref.dwconv2d_ref(x, f, stride=blk.stride, padding="same")
            y = jnp.clip(y + db, 0.0, 6.0)
            return ref.pwconv_ref(y, w, bias=pb, activation="relu6")

        def fused(x, f, w, db, pb):
            return ref.separable_fused_ref(
                x, f, w, db, pb, stride=blk.stride, padding="same",
                dw_activation="relu6", activation="relu6")

        us_unfused = _time_jit(jax.jit(unfused), x, f, w, db, pb)
        us_fused = _time_jit(jax.jit(fused), x, f, w, db, pb)

    # modeled traffic at the fused kernel's chooser-picked blocks, on the
    # SAME-padded (VALID-equivalent) geometry the kernels actually see
    s = blk.stride
    hi, wi, ho, wo = sep_geometry(blk)
    plan = blocking.plan_separable(ho, wo, blk.c_in, blk.c_out, stride=s,
                                   hf=blk.hf, wf=blk.hf)
    bco_fused = plan.block_co if plan else blk.c_out
    unf = it.separable_traffic_unfused(
        1, hi, wi, blk.c_in, blk.c_out, blk.hf, blk.hf, s)
    fus = it.separable_traffic_fused(
        1, hi, wi, blk.c_in, blk.c_out, blk.hf, blk.hf, s,
        block_co=bco_fused, slab_h=plan.slab_h if plan else None)
    t_unf = max(unf.time_s(PEAK, HBM))
    t_fus = max(fus.time_s(PEAK, HBM))
    return {
        "name": blk.name,
        "us_unfused_xla_cpu": us_unfused,
        "us_fused_xla_cpu": us_fused,
        "bytes_unfused": unf.bytes_hbm,
        "bytes_fused": fus.bytes_hbm,
        "bytes_saved": unf.bytes_hbm - fus.bytes_hbm,
        "bytes_intermediate": it.separable_intermediate_bytes(
            1, hi, wi, blk.c_in, blk.c_out, blk.hf, blk.hf, s),
        "fusible": plan is not None,
        "block_co": bco_fused,
        "slab_h": plan.slab_h if plan else 0,
        "n_slabs": plan.n_slabs if plan else 0,
        "ai_unfused": unf.intensity,
        "ai_fused": fus.intensity,
        "modeled_speedup": t_unf / t_fus,
    }


def fig_unoptimized_anchor(measure=True) -> dict:
    """Paper Fig. 1 'Unoptimized' point: Algorithm-1 naive loops vs XLA,
    on a small layer (numpy loops are too slow for the big ones)."""
    rng = np.random.default_rng(0)
    x = rng.normal(size=(1, 16, 16, 32)).astype(np.float32)
    f = rng.normal(size=(3, 3, 32)).astype(np.float32)
    if not measure:
        return {"name": "unoptimized-anchor-16x16x32",
                "us_naive_loops": 0.0, "us_xla_cpu": 0.0, "speedup": 0.0}
    t0 = time.perf_counter()
    ref.dwconv2d_loops_ref(x, f, stride=1)
    t_naive = time.perf_counter() - t0
    xj, fj = jnp.asarray(x), jnp.asarray(f)
    fn = jax.jit(lambda x, f: ref.dwconv2d_ref(x, f, padding="valid"))
    us = _time_jit(fn, xj, fj)
    return {"name": "unoptimized-anchor-16x16x32",
            "us_naive_loops": t_naive * 1e6,
            "us_xla_cpu": us,
            "speedup": t_naive * 1e6 / us}


def fig7_scalability() -> list[dict]:
    """Fig. 7: modeled core scaling — ours (channel-parallel) vs TF-Lite-
    style (row-parallel) on MobileNetV1 D3 (56x56x128) under the paper's
    L1-thrash model; per-core compute + shared-bandwidth roofline."""
    rows = []
    layer = dict(b=1, hi=56, wi=56, c=128, hf=3, wf=3, stride=1)
    ours1 = it.dwconv2d_traffic(**{k: v for k, v in layer.items()})
    for p in (1, 2, 4):
        t_ours = max(ours1.flops / (ARM_PEAK * p / 4),
                     ours1.bytes_hbm / ARM_BW)
        tf = it.dwconv2d_traffic_rowpar(
            layer["b"], layer["hi"], layer["wi"], layer["c"], layer["hf"],
            layer["wf"], layer["stride"], p=p)
        t_tf = max(tf.flops / (ARM_PEAK * p / 4), tf.bytes_hbm / ARM_BW)
        base_ours = max(ours1.flops / (ARM_PEAK / 4),
                        ours1.bytes_hbm / ARM_BW)
        tf1 = it.dwconv2d_traffic_rowpar(
            layer["b"], layer["hi"], layer["wi"], layer["c"], layer["hf"],
            layer["wf"], layer["stride"], p=1)
        base_tf = max(tf1.flops / (ARM_PEAK / 4), tf1.bytes_hbm / ARM_BW)
        rows.append({
            "threads": p,
            "speedup_ours": base_ours / t_ours,
            "speedup_rowpar": base_tf / t_tf,
        })
    return rows


def run_all(quick: bool = False, dry_run: bool = False):
    """All figure/table rows. ``dry_run`` keeps every analytical column
    (traffic, AI, roofline, planner blocks) but skips compilation and wall-
    clock timing — the CI traffic-model regression gate runs this mode. The
    hires sep suite is only *timed* under --full (its XLA CPU reference
    passes are minutes-slow); its model rows are always present."""
    rng = np.random.default_rng(0)
    measure = not dry_run
    results = {}
    for suite, (dws, pws) in SUITES.items():
        if quick:
            dws, pws = dws[:3], pws[:3]
        results[suite] = {
            "dw": [bench_dw_layer(l, rng, measure=measure) for l in dws],
            "pw": [bench_pw_layer(l, rng, measure=measure) for l in pws],
        }
    for suite, blks in SEP_SUITES.items():
        if quick:
            blks = blks[:3]
        m = measure and (suite != "hires" or not quick)
        results.setdefault(suite, {})["sep"] = [
            bench_separable_block(b, rng, measure=m) for b in blks]
    results["fig1_anchor"] = fig_unoptimized_anchor(measure=measure)
    results["fig7"] = fig7_scalability()
    return results
