"""Assemble the EXPERIMENTS.md roofline table from dry-run artifacts, plus
the separable-block fusion accounting table (fused vs unfused HBM bytes,
with the removed DW-intermediate term broken out — DESIGN.md §3)."""
from __future__ import annotations

import glob
import json
import os

from repro.configs.base import SHAPES
from repro.configs.registry import ARCH_IDS
from repro.core import intensity as it

COLUMNS = [
    "arch", "shape", "mesh", "status", "compute_s", "memory_s",
    "collective_s", "dominant", "useful_flop_ratio", "roofline_mfu_bound",
]


def load_records(art_dir: str = "artifacts/dryrun") -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def _ms(x):
    return f"{x*1e3:.2f}" if isinstance(x, (int, float)) else "-"


def markdown_table(recs: list[dict], mesh: str = "single") -> str:
    lines = [
        "| arch | shape | compute (ms) | memory (ms) | collective (ms) | "
        "dominant | useful-FLOP ratio | MFU bound |",
        "|---|---|---|---|---|---|---|---|",
    ]
    order = {a: i for i, a in enumerate(ARCH_IDS)}
    sorder = {s: i for i, s in enumerate(SHAPES)}
    recs = [r for r in recs if r.get("mesh") == mesh]
    recs.sort(key=lambda r: (order.get(r["arch"], 99),
                             sorder.get(r["shape"], 9)))
    for r in recs:
        if r.get("status") == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"skipped (sub-quadratic gate) | — | — |")
            continue
        if r.get("status") != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | FAILED | | | | | |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {_ms(r['compute_s'])} | "
            f"{_ms(r['memory_s'])} | {_ms(r['collective_s'])} | "
            f"{r['dominant'].replace('_s','')} | "
            f"{r['useful_flop_ratio']:.2f} | "
            f"{r['roofline_mfu_bound']*100:.1f}% |")
    return "\n".join(lines)


def separable_fusion_rows(dtype=None) -> list[dict]:
    """Per-block HBM accounting: unfused = fused + intermediate round-trip
    - halo re-reads.

    ``intermediate_mb`` is the term the fused kernel removes (the DW output's
    HBM store + per-Co-panel loads) and ``halo_mb`` the (much smaller) term
    row-slab blocking adds back at slab seams; fused bytes must be strictly
    lower for every block the planner can fuse — including the hires suite,
    which was fallback-only before slabs (asserted by tests/test_intensity.py).
    """
    try:
        from benchmarks.layers import SEP_SUITES, sep_geometry
    except ModuleNotFoundError:  # run as `python benchmarks/roofline_table.py`
        from layers import SEP_SUITES, sep_geometry
    from repro.kernels import blocking

    import jax.numpy as jnp
    dtype = dtype or jnp.float32
    nb = blocking.dtype_bytes(dtype)
    rows = []
    for suite, blks in SEP_SUITES.items():
        for blk in blks:
            s = blk.stride
            hi, wi, ho, wo = sep_geometry(blk)
            plan = blocking.plan_separable(
                ho, wo, blk.c_in, blk.c_out, stride=s, hf=blk.hf,
                wf=blk.hf, dtype=dtype)
            bco = plan.block_co if plan else blk.c_out
            slab_h = plan.slab_h if plan else None
            unf = it.separable_traffic_unfused(
                1, hi, wi, blk.c_in, blk.c_out, blk.hf, blk.hf, s,
                dtype_bytes=nb)
            fus = it.separable_traffic_fused(
                1, hi, wi, blk.c_in, blk.c_out, blk.hf, blk.hf, s,
                block_co=bco, slab_h=slab_h, dtype_bytes=nb)
            inter = it.separable_intermediate_bytes(
                1, hi, wi, blk.c_in, blk.c_out, blk.hf, blk.hf, s,
                dtype_bytes=nb)
            halo = it.separable_slab_halo_bytes(
                1, wi, blk.c_in, blk.hf, s, plan.n_slabs if plan else 1,
                -(-blk.c_out // bco), dtype_bytes=nb)
            rows.append({
                "suite": suite,
                "name": blk.name,
                "fusible": plan is not None,
                "blocks": (f"c{plan.block_c}xco{plan.block_co}"
                           f"xs{plan.slab_h}" if plan else "-"),
                "n_slabs": plan.n_slabs if plan else 0,
                "unfused_mb": unf.bytes_hbm / 1e6,
                "fused_mb": fus.bytes_hbm / 1e6,
                "intermediate_mb": inter / 1e6,
                "halo_mb": halo / 1e6,
                "saved_mb": (unf.bytes_hbm - fus.bytes_hbm) / 1e6,
                "ai_unfused": unf.intensity,
                "ai_fused": fus.intensity,
            })
    return rows


def chain_fusion_rows(dtype=None) -> list[dict]:
    """Per-block ChainPlan traffic table for whole MobileNetV2 inverted
    residuals: what the chain planner actually lowers (its ChainPlan and
    the modeled HBM bytes) vs the PR-2 two-stage lowering (standalone
    expansion GEMM + fused DW->PW) vs fully unfused.  The CI dry-run gate
    asserts every block plans to a single fused3 pass with strictly
    decreasing bytes across the three strategies (DESIGN.md §5)."""
    try:
        from benchmarks.layers import MOBILENET_V2_IR
    except ModuleNotFoundError:  # run as `python benchmarks/roofline_table.py`
        from layers import MOBILENET_V2_IR

    import jax.numpy as jnp
    from repro.core import chain
    from repro.kernels import blocking

    dtype = dtype or jnp.float32
    nb = blocking.dtype_bytes(dtype)
    rows = []
    for blk in MOBILENET_V2_IR:
        spec = chain.inverted_residual_spec(
            blk.c_in, blk.c_out, expand=blk.expand, stride=blk.stride,
            hf=blk.hf)
        shape = (1, blk.h, blk.h, blk.c_in)
        cp = chain.plan(spec, shape, dtype=dtype)
        t_chain = chain.chain_traffic(spec, cp, shape)
        ho = -(-blk.h // blk.stride)
        p2 = blocking.plan_separable(ho, ho, blk.c_mid, blk.c_out,
                                     stride=blk.stride, hf=blk.hf,
                                     wf=blk.hf, dtype=dtype,
                                     residual=cp.residual)
        t_2stage = it.separable_traffic_2stage(
            1, blk.h, blk.h, blk.c_in, blk.c_mid, blk.c_out, blk.hf,
            blk.hf, blk.stride, block_co=p2.block_co if p2 else None,
            slab_h=p2.slab_h if p2 else None, dtype_bytes=nb)
        t_unf = it.separable_traffic_unfused3(
            1, blk.h, blk.h, blk.c_in, blk.c_mid, blk.c_out, blk.hf,
            blk.hf, blk.stride, dtype_bytes=nb)
        mb_2stage = t_2stage.bytes_hbm
        mb_unf = t_unf.bytes_hbm
        if cp.residual:
            # keep the comparison symmetric with chain_traffic's residual
            # terms: the 2-stage lowering folds the residual into its fused
            # tail (one streamed read); the unfused one pays a separate
            # elementwise add (read y, read res, write sum)
            mb_2stage += nb * blk.h * blk.h * blk.c_out
            mb_unf += nb * 3 * blk.h * blk.h * blk.c_out
        seg = cp.segments[0]
        rows.append({
            "name": blk.name,
            "plan": "+".join(s.kind for s in cp.segments),
            "single_pass": cp.fully_fused,
            "residual": cp.residual,
            "blocks": (f"c{seg.plan.block_c}xco{seg.plan.block_co}"
                       f"xs{seg.plan.slab_h}"),
            "mb_3stage": t_chain.bytes_hbm / 1e6,
            "mb_2stage": mb_2stage / 1e6,
            "mb_unfused": mb_unf / 1e6,
            "saved_vs_2stage_mb": (mb_2stage - t_chain.bytes_hbm) / 1e6,
            "ai_3stage": t_chain.intensity,
            "ai_2stage": t_2stage.flops / max(mb_2stage, 1.0),
        })
    return rows


def chain_fusion_markdown() -> str:
    lines = [
        "| block | plan | single pass | blocks | 3-stage HBM (MB) | "
        "2-stage HBM (MB) | unfused HBM (MB) | saved vs 2-stage (MB) | "
        "AI 3-stage | AI 2-stage |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in chain_fusion_rows():
        lines.append(
            f"| {r['name']} | {r['plan']} | {r['single_pass']} | "
            f"{r['blocks']} | {r['mb_3stage']:.2f} | {r['mb_2stage']:.2f} | "
            f"{r['mb_unfused']:.2f} | {r['saved_vs_2stage_mb']:.2f} | "
            f"{r['ai_3stage']:.2f} | {r['ai_2stage']:.2f} |")
    return "\n".join(lines)


def separable_fusion_markdown() -> str:
    lines = [
        "| block | fused blocks | slabs | unfused HBM (MB) | fused HBM (MB) |"
        " intermediate term (MB) | halo term (MB) | saved (MB) | AI unfused |"
        " AI fused |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in separable_fusion_rows():
        lines.append(
            f"| {r['suite']}/{r['name']} | {r['blocks']} | {r['n_slabs']} | "
            f"{r['unfused_mb']:.2f} | {r['fused_mb']:.2f} | "
            f"{r['intermediate_mb']:.2f} | {r['halo_mb']:.2f} | "
            f"{r['saved_mb']:.2f} | "
            f"{r['ai_unfused']:.2f} | {r['ai_fused']:.2f} |")
    return "\n".join(lines)


def csv_rows(recs: list[dict]) -> list[str]:
    out = []
    for r in recs:
        if r.get("status") != "ok":
            continue
        name = f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}"
        dominant_ms = r[r["dominant"]] * 1e3
        out.append(f"{name},{dominant_ms*1e3:.1f},"
                   f"dominant={r['dominant']};"
                   f"mfu_bound={r['roofline_mfu_bound']*100:.1f}%")
    return out


if __name__ == "__main__":
    recs = load_records()
    print(markdown_table(recs, "single"))
    print()
    print(markdown_table(recs, "multi"))
    print()
    print(separable_fusion_markdown())
    print()
    print(chain_fusion_markdown())
