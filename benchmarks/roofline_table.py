"""Assemble the EXPERIMENTS.md roofline table from dry-run artifacts, plus
the separable-block fusion accounting table (fused vs unfused HBM bytes,
with the removed DW-intermediate term broken out — DESIGN.md §3)."""
from __future__ import annotations

import glob
import json
import os

from repro.configs.base import SHAPES
from repro.configs.registry import ARCH_IDS
from repro.core import intensity as it

COLUMNS = [
    "arch", "shape", "mesh", "status", "compute_s", "memory_s",
    "collective_s", "dominant", "useful_flop_ratio", "roofline_mfu_bound",
]


def load_records(art_dir: str = "artifacts/dryrun") -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def _ms(x):
    return f"{x*1e3:.2f}" if isinstance(x, (int, float)) else "-"


def markdown_table(recs: list[dict], mesh: str = "single") -> str:
    lines = [
        "| arch | shape | compute (ms) | memory (ms) | collective (ms) | "
        "dominant | useful-FLOP ratio | MFU bound |",
        "|---|---|---|---|---|---|---|---|",
    ]
    order = {a: i for i, a in enumerate(ARCH_IDS)}
    sorder = {s: i for i, s in enumerate(SHAPES)}
    recs = [r for r in recs if r.get("mesh") == mesh]
    recs.sort(key=lambda r: (order.get(r["arch"], 99),
                             sorder.get(r["shape"], 9)))
    for r in recs:
        if r.get("status") == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"skipped (sub-quadratic gate) | — | — |")
            continue
        if r.get("status") != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | FAILED | | | | | |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {_ms(r['compute_s'])} | "
            f"{_ms(r['memory_s'])} | {_ms(r['collective_s'])} | "
            f"{r['dominant'].replace('_s','')} | "
            f"{r['useful_flop_ratio']:.2f} | "
            f"{r['roofline_mfu_bound']*100:.1f}% |")
    return "\n".join(lines)


def separable_fusion_rows(dtype=None) -> list[dict]:
    """Per-block HBM accounting: unfused = fused + intermediate round-trip
    - halo re-reads.

    ``intermediate_mb`` is the term the fused kernel removes (the DW output's
    HBM store + per-Co-panel loads) and ``halo_mb`` the (much smaller) term
    row-slab blocking adds back at slab seams; fused bytes must be strictly
    lower for every block the planner can fuse — including the hires suite,
    which was fallback-only before slabs (asserted by tests/test_intensity.py).
    """
    try:
        from benchmarks.layers import SEP_SUITES, sep_geometry
    except ModuleNotFoundError:  # run as `python benchmarks/roofline_table.py`
        from layers import SEP_SUITES, sep_geometry
    from repro.kernels import blocking

    import jax.numpy as jnp
    dtype = dtype or jnp.float32
    nb = blocking.dtype_bytes(dtype)
    rows = []
    for suite, blks in SEP_SUITES.items():
        for blk in blks:
            s = blk.stride
            hi, wi, ho, wo = sep_geometry(blk)
            plan = blocking.plan_separable(
                ho, wo, blk.c_in, blk.c_out, stride=s, hf=blk.hf,
                wf=blk.hf, dtype=dtype)
            bco = plan.block_co if plan else blk.c_out
            slab_h = plan.slab_h if plan else None
            unf = it.separable_traffic_unfused(
                1, hi, wi, blk.c_in, blk.c_out, blk.hf, blk.hf, s,
                dtype_bytes=nb)
            fus = it.separable_traffic_fused(
                1, hi, wi, blk.c_in, blk.c_out, blk.hf, blk.hf, s,
                block_co=bco, slab_h=slab_h, dtype_bytes=nb)
            inter = it.separable_intermediate_bytes(
                1, hi, wi, blk.c_in, blk.c_out, blk.hf, blk.hf, s,
                dtype_bytes=nb)
            halo = it.separable_slab_halo_bytes(
                1, wi, blk.c_in, blk.hf, s, plan.n_slabs if plan else 1,
                -(-blk.c_out // bco), dtype_bytes=nb)
            rows.append({
                "suite": suite,
                "name": blk.name,
                "fusible": plan is not None,
                "blocks": (f"c{plan.block_c}xco{plan.block_co}"
                           f"xs{plan.slab_h}" if plan else "-"),
                "n_slabs": plan.n_slabs if plan else 0,
                "unfused_mb": unf.bytes_hbm / 1e6,
                "fused_mb": fus.bytes_hbm / 1e6,
                "intermediate_mb": inter / 1e6,
                "halo_mb": halo / 1e6,
                "saved_mb": (unf.bytes_hbm - fus.bytes_hbm) / 1e6,
                "ai_unfused": unf.intensity,
                "ai_fused": fus.intensity,
            })
    return rows


def separable_fusion_markdown() -> str:
    lines = [
        "| block | fused blocks | slabs | unfused HBM (MB) | fused HBM (MB) |"
        " intermediate term (MB) | halo term (MB) | saved (MB) | AI unfused |"
        " AI fused |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in separable_fusion_rows():
        lines.append(
            f"| {r['suite']}/{r['name']} | {r['blocks']} | {r['n_slabs']} | "
            f"{r['unfused_mb']:.2f} | {r['fused_mb']:.2f} | "
            f"{r['intermediate_mb']:.2f} | {r['halo_mb']:.2f} | "
            f"{r['saved_mb']:.2f} | "
            f"{r['ai_unfused']:.2f} | {r['ai_fused']:.2f} |")
    return "\n".join(lines)


def csv_rows(recs: list[dict]) -> list[str]:
    out = []
    for r in recs:
        if r.get("status") != "ok":
            continue
        name = f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}"
        dominant_ms = r[r["dominant"]] * 1e3
        out.append(f"{name},{dominant_ms*1e3:.1f},"
                   f"dominant={r['dominant']};"
                   f"mfu_bound={r['roofline_mfu_bound']*100:.1f}%")
    return out


if __name__ == "__main__":
    recs = load_records()
    print(markdown_table(recs, "single"))
    print()
    print(markdown_table(recs, "multi"))
    print()
    print(separable_fusion_markdown())
