"""Assemble the EXPERIMENTS.md roofline table from dry-run artifacts."""
from __future__ import annotations

import glob
import json
import os

from repro.configs.base import SHAPES
from repro.configs.registry import ARCH_IDS

COLUMNS = [
    "arch", "shape", "mesh", "status", "compute_s", "memory_s",
    "collective_s", "dominant", "useful_flop_ratio", "roofline_mfu_bound",
]


def load_records(art_dir: str = "artifacts/dryrun") -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def _ms(x):
    return f"{x*1e3:.2f}" if isinstance(x, (int, float)) else "-"


def markdown_table(recs: list[dict], mesh: str = "single") -> str:
    lines = [
        "| arch | shape | compute (ms) | memory (ms) | collective (ms) | "
        "dominant | useful-FLOP ratio | MFU bound |",
        "|---|---|---|---|---|---|---|---|",
    ]
    order = {a: i for i, a in enumerate(ARCH_IDS)}
    sorder = {s: i for i, s in enumerate(SHAPES)}
    recs = [r for r in recs if r.get("mesh") == mesh]
    recs.sort(key=lambda r: (order.get(r["arch"], 99),
                             sorder.get(r["shape"], 9)))
    for r in recs:
        if r.get("status") == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"skipped (sub-quadratic gate) | — | — |")
            continue
        if r.get("status") != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | FAILED | | | | | |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {_ms(r['compute_s'])} | "
            f"{_ms(r['memory_s'])} | {_ms(r['collective_s'])} | "
            f"{r['dominant'].replace('_s','')} | "
            f"{r['useful_flop_ratio']:.2f} | "
            f"{r['roofline_mfu_bound']*100:.1f}% |")
    return "\n".join(lines)


def csv_rows(recs: list[dict]) -> list[str]:
    out = []
    for r in recs:
        if r.get("status") != "ok":
            continue
        name = f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}"
        dominant_ms = r[r["dominant"]] * 1e3
        out.append(f"{name},{dominant_ms*1e3:.1f},"
                   f"dominant={r['dominant']};"
                   f"mfu_bound={r['roofline_mfu_bound']*100:.1f}%")
    return out


if __name__ == "__main__":
    recs = load_records()
    print(markdown_table(recs, "single"))
    print()
    print(markdown_table(recs, "multi"))
