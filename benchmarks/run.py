"""Benchmark orchestrator. One section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
* figs 4-6 — per-layer DW/PW benchmarks (measured CPU wall time of the XLA
  path + the paper's analytical AI model and modeled TPU-roofline speedup).
* fig 7 — modeled core-scalability curves (channel- vs row-parallel).
* fig 1 anchor — Algorithm-1 naive loops vs compiled (the paper's
  "Unoptimized" point).
* roofline — dominant-term summary per (arch x shape) from the dry-run
  artifacts (if present; run ``python -m repro.launch.dryrun --all`` first).

Flags:
* ``--full``     — benchmark every layer (default: first 3 per suite).
* ``--dry-run``  — model-only mode: skip compilation and wall-clock timing
  (all ``us`` columns are 0.0) but emit every analytical row — planner
  blocks, traffic, AI, roofline bounds. CI runs this as the traffic-model
  regression gate.
* ``--out PATH`` — additionally dump the raw results dict as JSON to PATH
  (e.g. ``artifacts/bench_results.json``). Without it nothing is written.
* ``--autotune`` — append the analytic-vs-measured ChainPlan table
  (``autotune/mobilenet_v2/...`` rows, benchmarks/autotune_table.py): each
  V2 inverted residual is tuned with the measured autotuner and the row
  reports cache=miss|hit, both blockings and both timings. Unlike the
  other sections this MEASURES even under ``--dry-run`` (measurement is
  the feature under test); quick mode uses tiny stand-in geometries so the
  interpret-mode ladder stays in CI seconds, ``--full`` tunes the real V2
  shapes.
* ``--tune-cache PATH`` — persistent tune-cache JSON for ``--autotune``
  (default: $REPRO_TUNE_CACHE or ~/.cache/repro/autotune.json). Re-running
  with the same PATH must print every row as cache=hit with n_cand=0 —
  CI's replay gate.
* ``--fault-inject POINTS`` — run the three-phase runtime-hardening matrix
  (``benchmarks/runtime_faults.py``, DESIGN.md §9): arm the comma-separated
  injection points (``point[:times]``, persistent by default) against the
  full V1/V2 bodies, assert oracle parity + exact injected-fallback
  telemetry, then prove the quarantined replay and a clean run report zero
  fallbacks. Like ``--autotune`` this EXECUTES even under ``--dry-run``
  (fault recovery is the feature under test); quick mode runs @16x16,
  ``--full`` at the paper's 112x112.
* ``--runtime-report PATH`` — write the three phase telemetry snapshots as
  JSON (requires ``--fault-inject``).
* ``--baseline`` — (re)write the committed benchmark-trajectory baseline
  (``BENCH_baseline.json``: geometry-keyed traffic + per-block plan rows,
  sorted keys) and exit. ``--check-baseline`` re-collects and diffs
  against the committed baseline, exiting 1 on any traffic regression or
  plan downgrade — the CI ``bench-gate`` job (benchmarks/trajectory.py).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true",
                    help="benchmark every layer, and time the hires suite")
    ap.add_argument("--dry-run", action="store_true",
                    help="model-only: no compilation or timing")
    ap.add_argument("--out", default=None, metavar="PATH",
                    help="write raw results JSON to PATH")
    ap.add_argument("--autotune", action="store_true",
                    help="append the analytic-vs-measured ChainPlan table")
    ap.add_argument("--tune-cache", default=None, metavar="PATH",
                    help="tune-cache JSON for --autotune")
    ap.add_argument("--fault-inject", default=None, metavar="POINTS",
                    help="comma-separated injection points (point[:times]) "
                         "for the runtime-hardening matrix (DESIGN.md §9)")
    ap.add_argument("--runtime-report", default=None, metavar="PATH",
                    help="write the fault-injection telemetry report here")
    ap.add_argument("--baseline", nargs="?", const="", default=None,
                    metavar="PATH",
                    help="write the trajectory baseline JSON (default: "
                         "BENCH_baseline.json at the repo root) and exit")
    ap.add_argument("--check-baseline", nargs="?", const="", default=None,
                    metavar="PATH",
                    help="diff the trajectory against the committed "
                         "baseline and exit 1 on regression (bench-gate)")
    args = ap.parse_args()

    if args.baseline is not None:
        from benchmarks import trajectory
        path = trajectory.write_baseline(
            args.baseline or trajectory.DEFAULT_BASELINE)
        print(f"trajectory baseline written to {os.path.normpath(path)}")
        return
    if args.check_baseline is not None:
        from benchmarks import trajectory
        sys.exit(trajectory.check_baseline(
            args.check_baseline or trajectory.DEFAULT_BASELINE))

    from benchmarks.paper_figs import run_all
    from benchmarks.roofline_table import csv_rows, load_records

    results = run_all(quick=not args.full, dry_run=args.dry_run)
    rows = []
    for suite in ("mobilenet_v1", "mobilenet_v2", "mnasnet_a1"):
        for r in results[suite]["dw"]:
            rows.append(
                f"dwconv/{suite}/{r['name']},{r['us_xla_cpu']:.1f},"
                f"AI_ours={r['ai_ours']:.3f};AI_tflite={r['ai_tflite']:.3f};"
                f"modeled_tpu_speedup={r['modeled_speedup']:.2f}x")
        for r in results[suite]["pw"]:
            rows.append(
                f"pwconv/{suite}/{r['name']},{r['us_xla_cpu']:.1f},"
                f"AI_rtrd={r['ai_rtrd']:.3f};AI_rtra={r['ai_rtra']:.3f};"
                f"modeled_tpu_speedup={r['modeled_speedup']:.2f}x")
    for suite in ("mobilenet_v1", "mobilenet_v2", "hires"):
        for r in results[suite].get("sep", []):
            if not r["fusible"]:
                # no fused block plan fits VMEM: the op takes the unfused
                # fallback, so a fused-traffic claim would be fiction
                rows.append(
                    f"sepfused/{suite}/{r['name']},"
                    f"{r['us_fused_xla_cpu']:.1f},fusible=False;"
                    f"MB_unfused={r['bytes_unfused']/1e6:.2f}")
                continue
            rows.append(
                f"sepfused/{suite}/{r['name']},{r['us_fused_xla_cpu']:.1f},"
                f"us_unfused={r['us_unfused_xla_cpu']:.1f};"
                f"slabs={r['n_slabs']}x{r['slab_h']};"
                f"MB_unfused={r['bytes_unfused']/1e6:.2f};"
                f"MB_fused={r['bytes_fused']/1e6:.2f};"
                f"MB_saved={r['bytes_saved']/1e6:.2f};"
                f"modeled_tpu_speedup={r['modeled_speedup']:.2f}x")
    # per-block ChainPlan traffic table: what the declarative chain planner
    # lowers a WHOLE V2 inverted residual to (3-stage fused), vs the PR-2
    # 2-stage lowering, vs fully unfused (DESIGN.md §5)
    from benchmarks.roofline_table import chain_fusion_rows
    for r in chain_fusion_rows():
        rows.append(
            f"chain/mobilenet_v2/{r['name']},0.0,"
            f"plan={r['plan']};single_pass={r['single_pass']};"
            f"residual={r['residual']};blocks={r['blocks']};"
            f"MB_3stage={r['mb_3stage']:.2f};"
            f"MB_2stage={r['mb_2stage']:.2f};"
            f"MB_unfused={r['mb_unfused']:.2f};"
            f"MB_saved_vs_2stage={r['saved_vs_2stage_mb']:.2f}")

    # whole-network table (DESIGN.md §7): the network engine's plan for the
    # full V1/V2 bodies and the bf16-streaming traffic reduction — CI gates
    # traffic_ok (bf16 < fp32 fused < per-block unfused, strict) per row
    from benchmarks.network_table import csv_network_rows, network_rows
    net_rows = network_rows()
    rows.extend(csv_network_rows(net_rows))
    results["network"] = net_rows

    a = results["fig1_anchor"]
    rows.append(f"fig1/{a['name']},{a['us_xla_cpu']:.1f},"
                f"naive_loops_us={a['us_naive_loops']:.0f};"
                f"speedup_vs_naive={a['speedup']:.0f}x")
    for r in results["fig7"]:
        rows.append(f"fig7/scaling/p{r['threads']},0.0,"
                    f"speedup_ours={r['speedup_ours']:.2f};"
                    f"speedup_rowpar={r['speedup_rowpar']:.2f}")

    from benchmarks.kernel_vmem import csv_rows as vmem_rows
    rows.extend(vmem_rows())

    if args.autotune:
        from benchmarks.autotune_table import autotune_rows
        tune_rows, tune_recs = autotune_rows(args.tune_cache,
                                             full=args.full)
        rows.extend(tune_rows)
        results["autotune"] = tune_recs

    if args.fault_inject:
        from benchmarks.runtime_faults import runtime_rows
        rt_rows, rt_recs = runtime_rows(args.fault_inject, full=args.full,
                                        report_path=args.runtime_report)
        rows.extend(rt_rows)
        results["runtime"] = rt_recs

    recs = load_records()
    rows.extend(csv_rows(recs))

    print("name,us_per_call,derived")
    for row in rows:
        print(row)

    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            # sorted keys + trailing newline: byte-stable across runs with
            # identical results, so CI artifacts diff cleanly
            json.dump(results, f, indent=2, default=str, sort_keys=True)
            f.write("\n")


if __name__ == "__main__":
    main()
