"""Fault-injection benchmark (DESIGN.md §9): the runtime ladder end to end.

Three self-asserting phases over the full MobileNetV1/V2 bodies, driven by
``benchmarks/run.py --fault-inject POINTS``:

* **faulted** — arm the requested injection points against a FRESH
  tune-cache/quarantine store, run ``execute_network`` per (arch x dtype),
  and assert (a) the output still matches the fp32 per-block reference
  oracle (bitwise for fp32 when every lowering point is armed — every
  block then lands on the reference rung, which IS the oracle's execution;
  tolerance otherwise) and (b) the telemetry records exactly the injected
  fallbacks (``fallbacks == injected_fallbacks > 0``).
* **quarantined replay** — disarm everything, keep the store, re-run: the
  persisted quarantine must steer every plan around the banned rungs with
  ZERO fallback events (``quarantine_hits > 0`` proves it was consulted).
* **clean** — a fresh store with nothing armed: zero fallbacks, zero
  quarantine hits — the steady-state guarantee that the ladder costs
  nothing when nothing fails.

Emits ``runtime/...`` CSV rows for the benchmark table and (optionally) a
``runtime_report.json`` with the three phase snapshots for the CI artifact.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

FP32_REL_TOL = 1e-5
#: Matches examples/mobilenet_inference.BF16_REL_TOL (DESIGN.md §7).
BF16_REL_TOL = 5e-2

#: The lowering points; when ALL are armed persistently every block is
#: forced down to the reference rung, making fp32 outputs bitwise-equal to
#: the per-block oracle.  Includes the DESIGN §10 stage-algebra points so
#: the MnasNet-A1 (dw_se/se) and EfficientNet-Lite0 (fusedmb/mb) blocks
#: fault and quarantine like the separable ones.
_LOWERING_POINTS = ("lowering:separable_fused", "lowering:fused_mbconv",
                    "lowering:se_epilogue", "lowering:pwconv",
                    "lowering:dwconv2d")


def _configs():
    from repro.core import network
    from repro.kernels.policy import DtypePolicy
    return [
        (arch, dname, net, DtypePolicy(stream="bfloat16")
         if dname == "bf16" else DtypePolicy())
        for arch, net in (("v1", network.mobilenet_v1_spec()),
                          ("v2", network.mobilenet_v2_spec()),
                          ("mnasnet_a1", network.mnasnet_a1_spec()),
                          ("enlite0", network.efficientnet_lite0_spec()))
        for dname in ("fp32", "bf16")
    ]


def _oracle(net, params, x, tune_cache):
    """fp32 per-block reference (the pre-network-engine path), computed
    with injection suppressed so armed persistent faults cannot poison
    the yardstick itself."""
    from repro.core import chain
    from repro.kernels.policy import KernelPolicy
    from repro.runtime import faultinject
    pol = KernelPolicy(impl="xla", on_failure="raise",
                       tune_cache=tune_cache)
    with faultinject.suppressed():
        y = x
        for spec, p in zip(net.blocks, params):
            y = chain.execute(spec, p, y, policy=pol)
    return np.asarray(y, np.float32)


def _run_config(net, params, x, policy, oracle, *, bitwise: bool,
                tol: float) -> dict:
    import warnings

    from repro.core import network
    from repro.runtime import telemetry

    telemetry.reset_runtime_telemetry()
    t0 = time.perf_counter()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        y = network.execute_network(net, params, x, policy=policy)
    jax.block_until_ready(y)
    ms = (time.perf_counter() - t0) * 1e3
    got = np.asarray(y, np.float32)
    rel = float(np.abs(got - oracle).max() / (np.abs(oracle).max() + 1e-30))
    if bitwise:
        np.testing.assert_array_equal(got, oracle)
    assert rel < tol, f"parity {rel} >= {tol}"
    rep = telemetry.runtime_report()
    rep["rel_err"] = rel
    rep["ms"] = ms
    rep["bitwise_checked"] = bool(bitwise)
    return rep


def runtime_rows(points_spec: str, *, res: int = 16, full: bool = False,
                 store_dir: str = "artifacts/runtime",
                 report_path=None):
    """The three-phase matrix described in the module docstring; returns
    ``(csv_rows, results_dict)`` like the other benchmark table modules.
    Raises AssertionError on any violated invariant — CI just runs it."""
    from repro.core import network
    from repro.kernels.policy import KernelPolicy
    from repro.runtime import faultinject
    from repro.runtime import quarantine as Q

    if full:
        res = 112
    os.makedirs(store_dir, exist_ok=True)
    faulted_store = os.path.join(store_dir, "faulted")
    clean_store = os.path.join(store_dir, "clean")
    for d in (faulted_store, clean_store):
        os.makedirs(d, exist_ok=True)
        for f in ("tune.json", "quarantine.json"):
            try:
                os.remove(os.path.join(d, f))
            except FileNotFoundError:
                pass
    Q.clear_memo()

    rows, results = [], {"points": None, "res": res, "phases": {}}
    configs = _configs()
    data = {}
    for arch, dname, net, dp in configs:
        kx = jax.random.PRNGKey(1)
        x = jax.random.normal(kx, (1, res, res, net.c_in))
        params = network.init_network(jax.random.PRNGKey(0), net)
        data[(arch, dname)] = (net, dp, params, x)

    def policy(dp, store):
        return KernelPolicy(impl="xla", numeric_guard=True,
                            dtype_policy=dp,
                            tune_cache=os.path.join(store, "tune.json"))

    def phase(name, store, *, want_fallbacks, want_hits):
        network.clear_network_cache()
        Q.clear_memo()
        phase_reps = {}
        for arch, dname, net, dp in configs:
            net_, dp_, params, x = data[(arch, dname)]
            pol = policy(dp_, store)
            oracle = _oracle(net_, params, x,
                             os.path.join(store, "tune.json"))
            # fp32 + every lowering point armed -> every block executes the
            # reference rung, which is exactly the oracle's computation
            bitwise = (name == "faulted" and dname == "fp32"
                       and all(p in faultinject.armed_points()
                               for p in _LOWERING_POINTS))
            tol = BF16_REL_TOL if dname == "bf16" else FP32_REL_TOL
            rep = _run_config(net_, params, x, pol, oracle,
                              bitwise=bitwise, tol=tol)
            if want_fallbacks:
                assert rep["fallbacks"] > 0, (name, arch, dname, rep)
                assert rep["fallbacks"] == rep["injected_fallbacks"], \
                    (name, arch, dname, rep)
            else:
                assert rep["fallbacks"] == 0, (name, arch, dname, rep)
            if want_hits is True:
                assert rep["quarantine_hits"] > 0, (name, arch, dname, rep)
            elif want_hits is False:
                assert rep["quarantine_hits"] == 0, (name, arch, dname, rep)
            phase_reps[f"{arch}/{dname}"] = rep
            rows.append(
                f"runtime/{name}/{arch}/{dname},{rep['ms'] * 1e3:.1f},"
                f"fallbacks={rep['fallbacks']};"
                f"injected={rep['injected_fallbacks']};"
                f"recoveries={rep['recoveries']};"
                f"quarantine_hits={rep['quarantine_hits']};"
                f"rel_err={rep['rel_err']:.2e};"
                f"bitwise={rep['bitwise_checked']}")
        results["phases"][name] = phase_reps

    # phase 1: faulted — every requested point armed persistently
    points = faultinject.arm_from_spec(points_spec)
    results["points"] = list(points)
    try:
        phase("faulted", faulted_store, want_fallbacks=True, want_hits=None)
    finally:
        faultinject.disarm_all()

    # phase 2: quarantined replay — same store, nothing armed: the
    # persisted bans must be honored with ZERO retries
    phase("quarantined_replay", faulted_store,
          want_fallbacks=False, want_hits=True)

    # phase 3: clean — fresh store, nothing armed, nothing quarantined
    phase("clean", clean_store, want_fallbacks=False, want_hits=False)

    if report_path:
        d = os.path.dirname(report_path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(report_path, "w") as f:
            json.dump(results, f, indent=2, sort_keys=True)
            f.write("\n")
    return rows, results
