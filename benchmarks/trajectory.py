"""Benchmark-trajectory gate (DESIGN.md §10): the committed baseline the
CI ``bench-gate`` job diffs every PR against.

The repo's perf story is ANALYTIC — traffic models and plan shapes, not
wall clocks — so it can be gated exactly: a PR that silently regresses
modeled HBM traffic, adds kernel passes, or downgrades a plan (fused3 ->
fused2, fusedmb -> mb+pw, dw_se -> dw+se) fails CI against
``BENCH_baseline.json`` at the repo root, deterministically, on any host.

Baseline schema (``collect``): one record per (arch x resolution) from
``benchmarks/network_table.benchmarked_networks``:

* ``traffic`` — modeled HBM MB for the unfused / fused-fp32 / bf16-stream
  plans and the fp32 GFLOPs (``core/intensity`` models; byte-exact).
* ``blocks``  — per-block plan rows: the ``+``-joined segment kinds, the
  kernel-pass count and the segment count under the default fp32 policy.

Comparison (``compare``):

* traffic regression — any byte metric strictly above baseline fails
  (a small relative tolerance absorbs float formatting, nothing else);
  improvements pass with a note, prompting a ``--baseline`` refresh.
* plan downgrade — per block, ``(n_passes, n_segments)`` lexicographically
  above baseline fails: every degradation (fused3 -> pw+fused2, dw_se ->
  dw+se, fusedmb -> mb+pw) grows passes or splits segments.  A changed
  plan that is no worse (more fusion) passes with a note.
* coverage loss — a baseline row or block missing from the current run
  fails; NEW rows (a new arch/resolution) pass with a note.

``python benchmarks/run.py --baseline`` rewrites the baseline;
``--check-baseline`` runs this gate (exit 1 on failure).
"""
from __future__ import annotations

import json
import os
import sys
from typing import List, Tuple

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

#: Committed at the repo root — the PR-visible perf contract.
DEFAULT_BASELINE = os.path.join(os.path.dirname(__file__), "..",
                                "BENCH_baseline.json")

#: Relative slack on byte metrics: absorbs float round-tripping through
#: JSON, NOT model changes (the models are integer-exact in bytes).
TRAFFIC_RTOL = 1e-9

SCHEMA_VERSION = 1


def collect(resolutions=None) -> dict:
    """The canonical trajectory record — pure shape arithmetic (plans and
    traffic models), no compilation, deterministic on any host."""
    from repro.core import network
    from repro.kernels.policy import KernelPolicy

    from benchmarks import network_table

    res = resolutions if resolutions is not None \
        else network_table.RESOLUTIONS
    pol = KernelPolicy()
    records = {}
    for row in network_table.network_rows(res):
        records[row["name"]] = {
            "traffic": {
                "mb_unfused": round(row["mb_unfused"], 6),
                "mb_fp32": round(row["mb_fp32"], 6),
                "mb_bf16": round(row["mb_bf16"], 6),
                "gflops": round(row["gflops"], 6),
            },
            "flags": {
                "single_pass": row["single_pass"],
                "ir_fused3": row["ir_fused3"],
                "se_fused": row["se_fused"],
                "mb_fused": row["mb_fused"],
                "traffic_ok": row["traffic_ok"],
            },
        }
    for name, net in network_table.benchmarked_networks():
        for r in res:
            nplan = network.plan_network(net, (1, r, r, net.c_in),
                                         policy=pol)
            records[f"{name}/res{r}"]["blocks"] = [
                {
                    "kinds": "+".join(s.kind for s in p.segments),
                    "passes": p.n_kernel_passes,
                    "segments": len(p.segments),
                }
                for p in nplan.plans
            ]
    return {"schema": SCHEMA_VERSION, "networks": records}


def write_baseline(path: str = DEFAULT_BASELINE,
                   baseline: dict = None) -> str:
    data = baseline if baseline is not None else collect()
    with open(path, "w") as f:
        # sorted keys + trailing newline: byte-stable, clean diffs
        json.dump(data, f, indent=2, sort_keys=True)
        f.write("\n")
    return path


def compare(baseline: dict, current: dict) -> Tuple[List[str], List[str]]:
    """(failures, notes): empty failures == the gate passes."""
    failures, notes = [], []
    base_nets = baseline.get("networks", {})
    cur_nets = current.get("networks", {})
    for name in sorted(set(cur_nets) - set(base_nets)):
        notes.append(f"{name}: new row (not in baseline) — refresh with "
                     "--baseline to start gating it")
    for name, base in sorted(base_nets.items()):
        cur = cur_nets.get(name)
        if cur is None:
            failures.append(f"{name}: row missing from the current run — "
                            "benchmark coverage regressed")
            continue
        bt, ct = base.get("traffic", {}), cur.get("traffic", {})
        for metric in ("mb_unfused", "mb_fp32", "mb_bf16"):
            b, c = bt.get(metric), ct.get(metric)
            if b is None or c is None:
                continue
            if c > b * (1 + TRAFFIC_RTOL):
                failures.append(
                    f"{name}: {metric} regressed {b:.3f} -> {c:.3f} MB")
            elif c < b * (1 - TRAFFIC_RTOL):
                notes.append(
                    f"{name}: {metric} improved {b:.3f} -> {c:.3f} MB — "
                    "refresh the baseline to lock it in")
        bf, cf = base.get("flags", {}), cur.get("flags", {})
        for flag, bv in sorted(bf.items()):
            cv = cf.get(flag)
            if bv is True and cv is not True:
                failures.append(f"{name}: flag {flag} dropped "
                                f"{bv} -> {cv}")
            elif bv is False and cv is True:
                notes.append(f"{name}: flag {flag} improved to True — "
                             "refresh the baseline")
        bb, cb = base.get("blocks", []), cur.get("blocks", [])
        if len(bb) != len(cb):
            failures.append(f"{name}: block count changed "
                            f"{len(bb)} -> {len(cb)}")
            continue
        for i, (old, new) in enumerate(zip(bb, cb)):
            ok = (old["passes"], old["segments"])
            nk = (new["passes"], new["segments"])
            if nk > ok:
                failures.append(
                    f"{name}/block{i}: plan downgraded "
                    f"{old['kinds']} -> {new['kinds']} "
                    f"(passes {old['passes']}->{new['passes']}, "
                    f"segments {old['segments']}->{new['segments']})")
            elif new["kinds"] != old["kinds"]:
                notes.append(
                    f"{name}/block{i}: plan changed (no worse) "
                    f"{old['kinds']} -> {new['kinds']} — refresh the "
                    "baseline to lock it in")
    return failures, notes


def check_baseline(path: str = DEFAULT_BASELINE, current: dict = None,
                   ) -> int:
    """Run the gate against the committed baseline; prints the verdict and
    returns a process exit code (0 pass, 1 fail/missing)."""
    if not os.path.exists(path):
        print(f"bench-gate: baseline {path} not found — generate it with "
              "`python benchmarks/run.py --baseline` and commit it")
        return 1
    with open(path) as f:
        baseline = json.load(f)
    cur = current if current is not None else collect()
    failures, notes = compare(baseline, cur)
    for n in notes:
        print(f"bench-gate NOTE  {n}")
    for x in failures:
        print(f"bench-gate FAIL  {x}")
    if failures:
        print(f"bench-gate: {len(failures)} regression(s) vs {path}")
        return 1
    print(f"bench-gate: ok ({len(baseline.get('networks', {}))} rows vs "
          f"{path}, {len(notes)} note(s))")
    return 0


if __name__ == "__main__":
    sys.exit(check_baseline())
