"""The paper's own workload: full MobileNet V1/V2 bodies through the
whole-network chain engine (NetworkSpec -> NetworkPlan -> ONE jitted
execute_network call, DESIGN.md §7) with per-segment mixed-precision
streaming.

  PYTHONPATH=src python examples/mobilenet_inference.py \
      [--pallas] [--res N] [--dtype fp32|bf16] [--arch v1|v2|both] [--verify]

--dtype bf16 streams activations and weights as bf16 while every kernel
accumulates in fp32 (the DtypePolicy of DESIGN.md §7) — the modeled HBM
traffic halves, which is the whole game for these memory-bound ops.
--pallas runs the Pallas kernels in interpret mode (slow, CPU) instead of
the XLA path, and cross-checks outputs.
--res N runs at an NxN body input instead of 112x112 (a 224 image after
the stem).  CI smokes --res 16 (fp32, interpret) and --res 32 --dtype bf16.
--fused is accepted for compatibility; fusion is a planner decision now
and always on (KernelPolicy(fused=False) remains the opt-out).
--fault-inject POINTS arms the runtime fault-injection harness (DESIGN.md
§9) at the named points (comma-separated ``point[:times]``, persistent by
default) before executing: the degradation ladder recovers, the oracle
parity assertion still holds, and the fallback telemetry is printed at the
end.  The quarantine store defaults to artifacts/runtime/quarantine.json
for this mode (override with $REPRO_QUARANTINE).
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import KernelPolicy, chain, network
from repro.core import intensity as it
from repro.kernels.policy import DtypePolicy

#: bf16-vs-fp32 network tolerance (documented in DESIGN.md §7 and asserted
#: by tests/test_network.py): one bf16 rounding per streamed operand per
#: block, compounded over 13-17 blocks, lands ~1e-2; 5e-2 is the gate.
BF16_REL_TOL = 5e-2


def _policy(args, dtype_policy):
    return KernelPolicy(impl="pallas" if args.pallas else "xla",
                        interpret=args.pallas, dtype_policy=dtype_policy)


def run_network(name, net, args):
    dp = (DtypePolicy(stream="bfloat16") if args.dtype == "bf16"
          else DtypePolicy())
    pol = _policy(args, dp)
    res = args.res
    x = jax.random.normal(jax.random.PRNGKey(1), (1, res, res, net.c_in))
    params = network.init_network(jax.random.PRNGKey(0), net)
    if args.dtype == "bf16":
        # deployment-style: store the weights once at the stream width
        params = network.cast_network_params(params, jnp.bfloat16)

    nplan = network.plan_network(net, x.shape, policy=pol)
    if args.verify:
        from repro import analysis
        report = analysis.analyze_network(net, nplan, policy=pol,
                                          jaxpr=False)
        print(f"  planlint: {report.summary()}"
              + ("" if report.ok else
                 " -> " + ",".join(report.rules(analysis.ERROR))))
        analysis.verify_or_raise(report)
    histo = ",".join(f"{k}:{v}"
                     for k, v in sorted(nplan.segment_histogram().items()))
    print(f"\n{name} body @{res}x{res} ({args.dtype}, {pol.impl}"
          f"{' interpret' if pol.interpret else ''}):")
    print(f"  plan: {net.n_blocks} blocks -> {nplan.n_kernel_passes} kernel "
          f"passes ({histo}), fully fused: {nplan.fully_fused}")

    t = it.network_traffic(net, nplan)
    n32 = network.plan_network(net, x.shape, policy=_policy(args,
                                                            DtypePolicy()))
    t32 = it.network_traffic(net, n32)
    nunf = network.plan_network(
        net, x.shape,
        policy=KernelPolicy(impl=pol.impl, interpret=pol.interpret,
                            fused=False))
    tunf = it.network_traffic(net, nunf)
    print(f"  modeled HBM: {t.bytes_hbm/1e6:.2f} MB "
          f"(fp32 fused {t32.bytes_hbm/1e6:.2f} MB, per-block unfused "
          f"{tunf.bytes_hbm/1e6:.2f} MB); AI {t.intensity:.1f} FLOPs/B")

    # ONE jitted call for the whole backbone; plan resolved once above.
    # Under --fault-inject the plan is left to the engine so re-plans after
    # a quarantine write take effect between repetitions.
    nplan_arg = None if args.fault_inject else nplan
    y = network.execute_network(net, params, x, policy=pol,
                                network_plan=nplan_arg)
    jax.block_until_ready(y)
    reps = 2 if args.pallas else 10
    t0 = time.perf_counter()
    for _ in range(reps):
        y = network.execute_network(net, params, x, policy=pol,
                                    network_plan=nplan_arg)
    jax.block_until_ready(y)
    ms = (time.perf_counter() - t0) / reps * 1e3
    print(f"  end-to-end: {ms:.2f} ms/image -> features {y.shape} {y.dtype}")

    # Parity vs the fp32 per-block oracle (XLA, native dtype, fresh fp32
    # weights — the pre-network-engine execution path).  Injection is
    # suppressed around it: the yardstick itself must not degrade.
    from repro.runtime import faultinject
    p32 = network.init_network(jax.random.PRNGKey(0), net)
    oracle = KernelPolicy(impl="xla", on_failure="raise")
    with faultinject.suppressed():
        ref = x
        for spec, p in zip(net.blocks, p32):
            ref = chain.execute(spec, p, ref, policy=oracle)
    ref = np.asarray(ref, np.float32)
    got = np.asarray(y, np.float32)
    rel = float(np.abs(got - ref).max() / (np.abs(ref).max() + 1e-30))
    tol = BF16_REL_TOL if args.dtype == "bf16" else 1e-5
    print(f"  vs fp32 per-block oracle: max rel err {rel:.2e} "
          f"(tol {tol:g})")
    assert rel < tol, f"{name}: {rel} >= {tol}"
    return ms


def main():
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--pallas", action="store_true",
                    help="run the Pallas kernels in interpret mode (slow, "
                         "CPU) and cross-check against the XLA path")
    ap.add_argument("--fused", action="store_true",
                    help="(compat no-op) fusion is a planner decision and "
                         "always on; KernelPolicy(fused=False) opts out")
    ap.add_argument("--res", type=int, default=112, metavar="N",
                    help="body input resolution NxN (a 224 image after the "
                         "stem is 112; CI smokes 16 and 32)")
    ap.add_argument("--dtype", choices=("fp32", "bf16"), default="fp32",
                    help="streaming dtype policy: bf16 halves the streamed "
                         "HBM bytes, accumulation stays fp32 (DESIGN.md §7)")
    ap.add_argument("--arch", choices=("v1", "v2", "both"), default="both")
    ap.add_argument("--verify", action="store_true",
                    help="run the static plan verifier (repro.analysis, "
                         "DESIGN.md §8) on the resolved NetworkPlan before "
                         "executing; raises on any error diagnostic")
    ap.add_argument("--fault-inject", default=None, metavar="POINTS",
                    help="arm runtime fault-injection points "
                         "(comma-separated point[:times], DESIGN.md §9) "
                         "and print the fallback telemetry")
    args = ap.parse_args()

    if args.fault_inject:
        os.environ.setdefault(
            "REPRO_QUARANTINE",
            os.path.join("artifacts", "runtime", "quarantine.json"))
        from repro.runtime import faultinject
        points = faultinject.arm_from_spec(args.fault_inject)
        print(f"fault injection armed: {', '.join(points)}")

    nets = []
    if args.arch in ("v1", "both"):
        nets.append(("MobileNetV1", network.mobilenet_v1_spec()))
    if args.arch in ("v2", "both"):
        nets.append(("MobileNetV2", network.mobilenet_v2_spec()))
    for name, net in nets:
        run_network(name, net, args)

    if args.fault_inject:
        from repro.runtime import faultinject, telemetry
        rep = telemetry.runtime_report()
        print(f"\nruntime telemetry: {rep['fallbacks']} fallbacks "
              f"({rep['injected_fallbacks']} injected), "
              f"{rep['recoveries']} recoveries, "
              f"{rep['quarantine_hits']} quarantine hits; fired: "
              f"{faultinject.fired_counts()}")
        assert rep["fallbacks"] == rep["injected_fallbacks"], rep
        faultinject.disarm_all()

    print("\nper-layer AI bounds (paper's analysis, DESIGN.md §2): "
          f"DW ours {it.t_ours_dw_asymptotic(3, 3):.3f} vs TF-Lite "
          f"{it.t_tf_dw(4):.3f}; PW RTRD {it.t_rtrd_pw(ci=1024):.3f} vs "
          f"RTRA {it.t_rtra_pw(co=1024):.3f}")


if __name__ == "__main__":
    main()
