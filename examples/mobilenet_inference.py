"""The paper's own workload: MobileNetV1 inference built entirely from the
paper's two ops (core.depthwise2d + core.pointwise), with the per-layer
arithmetic-intensity report that drives the paper's analysis.

  PYTHONPATH=src python examples/mobilenet_inference.py [--pallas] [--fused]

--pallas runs the Pallas kernels in interpret mode (slow, CPU) instead of
the XLA path, and cross-checks outputs.
--fused routes every separable block through the single-pass fused DW+PW
kernel (KernelPolicy.fused, DESIGN.md §3), cross-checks it against the
unfused composition, and reports the modeled HBM bytes the fusion removes.
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import KernelPolicy
from repro.core.separable import init_separable, separable_block
from repro.core.pwconv import pointwise
from repro.core import intensity as it

# MobileNetV1 body: (c_in, c_out, stride) per separable block (Table 1)
V1_BLOCKS = [
    (32, 64, 1), (64, 128, 2), (128, 128, 1), (128, 256, 2),
    (256, 256, 1), (256, 512, 2),
    (512, 512, 1), (512, 512, 1), (512, 512, 1), (512, 512, 1),
    (512, 512, 1), (512, 1024, 2), (1024, 1024, 1),
]


def build(key):
    params = []
    for i, (ci, co, s) in enumerate(V1_BLOCKS):
        params.append(init_separable(jax.random.fold_in(key, i), ci, co))
    return params


def forward(params, x, policy):
    for p, (ci, co, s) in zip(params, V1_BLOCKS):
        x = separable_block(p, x, stride=s, policy=policy)
    x = jnp.mean(x, axis=(1, 2))  # global average pool
    return x


def main():
    use_pallas = "--pallas" in sys.argv
    use_fused = "--fused" in sys.argv
    key = jax.random.PRNGKey(0)
    params = build(key)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 112, 112, 32))

    xla = KernelPolicy(impl="xla")
    fn = jax.jit(lambda p, x: forward(p, x, xla))
    out = fn(params, x)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    out = fn(params, x)
    jax.block_until_ready(out)
    dt = time.perf_counter() - t0
    print(f"MobileNetV1 body fwd (XLA CPU): {dt*1e3:.1f} ms, "
          f"features {out.shape}")

    if use_pallas:
        pal = KernelPolicy(impl="pallas", interpret=True)
        out_p = forward(params, x, pal)
        err = float(jnp.abs(out - out_p).max())
        print(f"Pallas(interpret) vs XLA maxerr: {err:.2e}")

    if use_fused:
        fused = KernelPolicy(impl="pallas" if use_pallas else "xla",
                             interpret=use_pallas, fused=True)
        fn_f = jax.jit(lambda p, x: forward(p, x, fused))
        out_f = fn_f(params, x)
        jax.block_until_ready(out_f)
        t0 = time.perf_counter()
        out_f = fn_f(params, x)
        jax.block_until_ready(out_f)
        dtf = time.perf_counter() - t0
        err = float(jnp.abs(out - out_f).max())
        print(f"fused separable blocks ({fused.impl}): {dtf*1e3:.1f} ms, "
              f"maxerr vs unfused: {err:.2e}")
        h2 = 112
        saved = 0.0
        for ci, co, s in V1_BLOCKS:
            ho = -(-h2 // s)
            hi_p = (ho - 1) * s + 3
            saved += it.separable_intermediate_bytes(
                1, hi_p, hi_p, ci, co, 3, 3, s)
            h2 = ho
        print(f"modeled HBM bytes removed by fusion (whole body): "
              f"{saved/1e6:.1f} MB (the DW intermediate round-trips, "
              f"DESIGN.md §3)")

    print("\nper-layer AI report (paper's analysis, DESIGN.md §2):")
    print(f"{'block':8s} {'HxW':>9s} {'C':>5s} {'DW AI ours':>11s} "
          f"{'DW AI tflite':>13s} {'PW AI rtrd':>11s} {'PW AI rtra':>11s}")
    h = 112
    for i, (ci, co, s) in enumerate(V1_BLOCKS):
        ho = h // s
        print(f"B{i:<7d} {h:>4d}x{ho:<4d} {ci:>5d} "
              f"{it.t_ours_dw_asymptotic(3, 3):>11.3f} "
              f"{it.t_tf_dw(4):>13.3f} "
              f"{it.t_rtrd_pw(ci=ci):>11.3f} "
              f"{it.t_rtra_pw(co=co):>11.3f}")
        h = ho
    print("\n(T_ours >= 9/22 = 0.409 vs TF-Lite < 1/6; RTRD ~1.5x RTRA — "
          "the paper's claims)")


if __name__ == "__main__":
    main()
