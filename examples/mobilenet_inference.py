"""The paper's own workload: MobileNet inference built entirely from the
paper's two ops, driven by the declarative chain API (spec -> plan ->
lower -> execute, DESIGN.md §5) — with the per-layer arithmetic-intensity
report that drives the paper's analysis.

  PYTHONPATH=src python examples/mobilenet_inference.py \
      [--pallas] [--fused] [--res N]

--pallas runs the Pallas kernels in interpret mode (slow, CPU) instead of
the XLA path, and cross-checks outputs.
--fused lets the chain planner fuse every block (the default policy): each
V1 separable block plans to one DW->PW kernel pass, and each V2 inverted
residual to ONE 3-stage pass (PW-expand computed on the fly -> DW ->
PW-project, residual folded into the store) — neither intermediate touches
HBM.  The demo prints each block's ChainPlan, cross-checks fused against
the unfused composition (KernelPolicy(fused=False), the legacy opt-out),
and reports the modeled HBM bytes the planner's fusion removes.
--res N runs at an NxN input instead of 112x112 (CI smoke-tests the fused
interpret path at --res 16).
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import KernelPolicy, chain
from repro.core.separable import init_separable, separable_block
from repro.core import intensity as it

# MobileNetV1 body: (c_in, c_out, stride) per separable block (Table 1)
V1_BLOCKS = [
    (32, 64, 1), (64, 128, 2), (128, 128, 1), (128, 256, 2),
    (256, 256, 1), (256, 512, 2),
    (512, 512, 1), (512, 512, 1), (512, 512, 1), (512, 512, 1),
    (512, 512, 1), (512, 1024, 2), (1024, 1024, 1),
]


def build(key):
    params = []
    for i, (ci, co, s) in enumerate(V1_BLOCKS):
        params.append(init_separable(jax.random.fold_in(key, i), ci, co))
    return params


def forward(params, x, policy):
    for p, (ci, co, s) in zip(params, V1_BLOCKS):
        x = separable_block(p, x, stride=s, policy=policy)
    x = jnp.mean(x, axis=(1, 2))  # global average pool
    return x


def v2_single_pass_demo(policy, res):
    """A whole MobileNetV2 inverted residual through the chain API: spec ->
    plan (one fused3 pass) -> execute, checked against the unfused plan."""
    spec = chain.inverted_residual_spec(32, 32, expand=6, stride=1)
    shape = (1, res, res, 32)
    cp = chain.plan(spec, shape, policy=policy)
    t = chain.chain_traffic(spec, cp, shape)
    cp_unf = chain.plan(spec, shape, policy=KernelPolicy(
        impl=policy.impl, interpret=policy.interpret, fused=False))
    t_unf = chain.chain_traffic(spec, cp_unf, shape)
    print(f"V2 inverted residual {res}x{res}x32 (expand 6): plan = "
          f"{'+'.join(s.kind for s in cp.segments)}, "
          f"kernel passes = {cp.n_kernel_passes} "
          f"(residual {'folded' if cp.residual_fused else 'separate'})")
    print(f"  modeled HBM: fused chain {t.bytes_hbm/1e6:.2f} MB vs "
          f"unfused {t_unf.bytes_hbm/1e6:.2f} MB "
          f"(neither the expanded tensor nor the DW output leaves VMEM)")
    params = chain.init_chain(jax.random.PRNGKey(7), spec, 32)
    x = jax.random.normal(jax.random.PRNGKey(8), shape)
    y = chain.execute(spec, params, x, policy=policy, chain_plan=cp)
    y_unf = chain.execute(spec, params, x, policy=KernelPolicy(
        impl=policy.impl, interpret=policy.interpret, fused=False))
    err = float(jnp.abs(y - y_unf).max())
    print(f"  single-pass vs unfused-composition maxerr: {err:.2e}")
    assert err < 1e-3, "fused V2 chain diverged from the unfused oracle"


def main():
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--pallas", action="store_true",
                    help="run the Pallas kernels in interpret mode (slow, "
                         "CPU) and cross-check against the XLA path")
    ap.add_argument("--fused", action="store_true",
                    help="let the chain planner fuse every block (V1: one "
                         "DW->PW pass; V2: ONE 3-stage expand->DW->project "
                         "pass, DESIGN.md §5) and cross-check against the "
                         "unfused composition")
    ap.add_argument("--res", type=int, default=112, metavar="N",
                    help="input resolution NxN (CI smokes --res 16)")
    args = ap.parse_args()
    use_pallas, use_fused, res = args.pallas, args.fused, args.res
    key = jax.random.PRNGKey(0)
    params = build(key)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, res, res, 32))

    # fused=False pins the legacy unfused composition as the baseline
    xla = KernelPolicy(impl="xla", fused=False)
    fn = jax.jit(lambda p, x: forward(p, x, xla))
    out = fn(params, x)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    out = fn(params, x)
    jax.block_until_ready(out)
    dt = time.perf_counter() - t0
    print(f"MobileNetV1 body fwd (XLA CPU, unfused): {dt*1e3:.1f} ms, "
          f"features {out.shape}")

    if use_pallas:
        pal = KernelPolicy(impl="pallas", interpret=True, fused=False)
        out_p = forward(params, x, pal)
        err = float(jnp.abs(out - out_p).max())
        print(f"Pallas(interpret) vs XLA maxerr: {err:.2e}")

    if use_fused:
        # default policy: the chain planner fuses whatever fits its budget
        fused = KernelPolicy(impl="pallas" if use_pallas else "xla",
                             interpret=use_pallas)
        fn_f = jax.jit(lambda p, x: forward(p, x, fused))
        out_f = fn_f(params, x)
        jax.block_until_ready(out_f)
        t0 = time.perf_counter()
        out_f = fn_f(params, x)
        jax.block_until_ready(out_f)
        dtf = time.perf_counter() - t0
        err = float(jnp.abs(out - out_f).max())
        print(f"planner-fused separable blocks ({fused.impl}): "
              f"{dtf*1e3:.1f} ms, maxerr vs unfused: {err:.2e}")
        h2 = res
        saved = 0.0
        for ci, co, s in V1_BLOCKS:
            ho = -(-h2 // s)
            hi_p = (ho - 1) * s + 3
            saved += it.separable_intermediate_bytes(
                1, hi_p, hi_p, ci, co, 3, 3, s)
            h2 = ho
        print(f"modeled HBM bytes removed by fusion (whole body): "
              f"{saved/1e6:.1f} MB (the DW intermediate round-trips, "
              f"DESIGN.md §3)")
        v2_single_pass_demo(fused, min(res, 28))

    print("\nper-layer AI report (paper's analysis, DESIGN.md §2):")
    print(f"{'block':8s} {'HxW':>9s} {'C':>5s} {'DW AI ours':>11s} "
          f"{'DW AI tflite':>13s} {'PW AI rtrd':>11s} {'PW AI rtra':>11s}")
    h = res
    for i, (ci, co, s) in enumerate(V1_BLOCKS):
        ho = h // s
        print(f"B{i:<7d} {h:>4d}x{ho:<4d} {ci:>5d} "
              f"{it.t_ours_dw_asymptotic(3, 3):>11.3f} "
              f"{it.t_tf_dw(4):>13.3f} "
              f"{it.t_rtrd_pw(ci=ci):>11.3f} "
              f"{it.t_rtra_pw(co=co):>11.3f}")
        h = ho
    print("\n(T_ours >= 9/22 = 0.409 vs TF-Lite < 1/6; RTRD ~1.5x RTRA — "
          "the paper's claims)")


if __name__ == "__main__":
    main()
