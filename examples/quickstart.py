"""Quickstart: the whole stack in one minute on CPU.

  PYTHONPATH=src python examples/quickstart.py

1. The paper's two ops directly (DWConv + PWConv, Pallas-interpret vs oracle).
2. Build a small LM from the registry, train a few steps, watch loss drop.
3. Prefill + greedy decode from the trained model.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import depthwise2d, pointwise
from repro.kernels import ops, ref
from repro.configs.registry import get_config
from repro.data.pipeline import DataConfig, DataIterator
from repro.optim.adamw import AdamWConfig
from repro.serve import serve_step as S
from repro.serve.sampler import generate
from repro.train.train_step import TrainConfig, init_train_state, \
    make_train_step


def demo_paper_ops():
    print("== 1. the paper's ops ==")
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(1, 28, 28, 64)).astype(np.float32))
    f = jnp.asarray(rng.normal(size=(3, 3, 64)).astype(np.float32))
    y_pallas = ops.dwconv2d(x, f, impl="pallas", interpret=True)
    y_ref = ref.dwconv2d_ref(x, f, padding="same")
    print(f" dwconv2d pallas-vs-oracle maxerr: "
          f"{float(jnp.abs(y_pallas - y_ref).max()):.2e}")
    w = jnp.asarray(rng.normal(size=(64, 128)).astype(np.float32))
    z_pallas = ops.pwconv(y_ref, w, activation="relu6", impl="pallas",
                          interpret=True)
    z_ref = ref.pwconv_ref(y_ref, w, activation="relu6")
    print(f" pwconv  pallas-vs-oracle maxerr: "
          f"{float(jnp.abs(z_pallas - z_ref).max()):.2e}")
    print(f" separable output: {z_pallas.shape}")


def demo_train_and_serve():
    print("== 2. train a small LM ==")
    cfg = get_config("qwen3-1.7b", smoke=True)
    tcfg = TrainConfig(optimizer=AdamWConfig(lr=5e-3, warmup_steps=2,
                                             total_steps=60,
                                             weight_decay=0.0))
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=4)
    state = init_train_state(cfg, tcfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, tcfg))
    it = DataIterator(dcfg, prefetch=0)
    for i in range(30):
        state, m = step(state, next(it))
        if i % 10 == 0 or i == 29:
            print(f" step {i:3d} loss {float(m['loss']):.4f}")

    print("== 3. serve it ==")
    params = state["params"]
    prompts = jnp.asarray(next(it)["tokens"][:2, :16])
    logits, cache = S.prefill(cfg, params, prompts, max_len=64)
    first = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    fn = jax.jit(lambda c, t: S.decode_step(cfg, params, c, t))
    toks, _ = generate(fn, cache, first, 12, jax.random.PRNGKey(0))
    print(" generated:", toks[0].tolist())


if __name__ == "__main__":
    demo_paper_ops()
    demo_train_and_serve()
    print("quickstart OK")
