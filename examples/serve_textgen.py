"""Batched serving example: prefill a batch of prompts, then decode with
temperature sampling — across three architecture families (dense KV-cache,
hybrid SWA+SSM, xLSTM recurrent-state).

  PYTHONPATH=src python examples/serve_textgen.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config
from repro.models import transformer as T
from repro.serve import serve_step as S
from repro.serve.sampler import generate


def run(arch: str, batch=4, prompt_len=24, gen=24):
    cfg = get_config(arch, smoke=True)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (batch, prompt_len), 0, cfg.vocab_size)
    frontend = None
    if cfg.encdec is not None:
        frontend = jnp.zeros((batch, cfg.encdec.enc_seq, cfg.d_model),
                             cfg.jax_dtype)

    t0 = time.perf_counter()
    logits, cache = jax.jit(
        lambda p, t: S.prefill(cfg, p, t, max_len=256, frontend=frontend)
    )(params, prompts)
    logits.block_until_ready()
    t_pre = time.perf_counter() - t0

    first = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    step = jax.jit(lambda c, t: S.decode_step(cfg, params, c, t))
    t0 = time.perf_counter()
    toks, _ = generate(step, cache, first, gen, jax.random.PRNGKey(2),
                       temperature=0.8, top_k=40)
    toks.block_until_ready()
    t_gen = time.perf_counter() - t0
    print(f"{arch:28s} prefill {t_pre*1e3:7.0f} ms | "
          f"{batch * gen / t_gen:7.1f} tok/s | sample {toks[0, :8].tolist()}")


if __name__ == "__main__":
    for arch in ("smollm-360m", "hymba-1.5b", "xlstm-125m"):
        run(arch)
    print("serve example OK")
