"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps on
the synthetic structured corpus, with checkpointing and fault tolerance —
the (b) deliverable's "train ~100M model" scenario, CPU-sized.

  PYTHONPATH=src python examples/train_e2e.py --steps 300

The config is a 12L x 768 smollm-family decoder (~103M params + embeddings).
On this 1-core container a step is a few seconds; the full 300-step run is
launched in the background by the maintainer workflow and its loss curve is
recorded in EXPERIMENTS.md §Examples.
"""
import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses

import jax

from repro.configs.base import ModelConfig
from repro.data.pipeline import DataConfig
from repro.optim.adamw import AdamWConfig
from repro.train.train_step import TrainConfig, init_train_state, \
    make_train_step
from repro.train.trainer import LoopConfig, train_loop

CONFIG_100M = ModelConfig(
    name="repro-103m",
    family="dense",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=4,
    d_ff=2048,
    vocab_size=32768,
    tie_embeddings=True,
    dtype="float32",
    loss_chunk=128,
    attn_chunk=256,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=4)
    ap.add_argument("--ckpt-dir", default="artifacts/e2e_ckpt")
    ap.add_argument("--out", default="artifacts/e2e_history.json")
    args = ap.parse_args()

    cfg = CONFIG_100M
    n = cfg.n_params()
    print(f"[e2e] {cfg.name}: {n/1e6:.1f}M params (analytical)")
    tcfg = TrainConfig(optimizer=AdamWConfig(
        lr=3e-4, warmup_steps=20, total_steps=args.steps))
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                      global_batch=args.global_batch, seed=11)
    state = init_train_state(cfg, tcfg, jax.random.PRNGKey(0))
    real = sum(x.size for x in jax.tree_util.tree_leaves(state["params"]))
    print(f"[e2e] actual params: {real/1e6:.1f}M")
    step = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0,))
    state, info = train_loop(
        step, state, dcfg,
        LoopConfig(total_steps=args.steps, ckpt_every=50, log_every=10),
        args.ckpt_dir,
    )
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(info["history"], f)
    losses = [h["loss"] for h in info["history"]]
    print(f"[e2e] loss: first10={sum(losses[:10])/10:.4f} "
          f"last10={sum(losses[-10:])/10:.4f}")


if __name__ == "__main__":
    main()
