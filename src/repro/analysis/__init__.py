"""repro.analysis — static plan/kernel verifier (DESIGN.md §8).

Proves the repo's resource claims BEFORE anything runs: fused blocks fit
VMEM (at the actual BlockSpecs the lowering emits, not the planner's
model), slabs + halos tile the output exactly once with in-bounds input
windows, blocks respect the TPU lane/sublane layout, and every cast in the
traced program is owned by the dtype policy.  Three passes:

* ``planlint``     — plan-field + derived-VMEM + grid-enumeration proofs
  (PL1xx rules) over the shared :class:`~repro.kernels.gridspec.
  KernelModel` each kernel builds its ``pl.BlockSpec``s from.
* ``mosaic_check`` — TPU tiling lint (MC2xx) over the same models.
* ``jaxpr_audit``  — fusion/cast audits (JX3xx) over the traced lowering.

Entry points: :func:`analyze_chain` / :func:`analyze_network` return a
:class:`~repro.analysis.diagnostics.Report`; :func:`verify_or_raise` turns
error diagnostics into :class:`PlanVerificationError` (the
``KernelPolicy(verify=True)`` debug knob); ``python -m repro.analysis``
runs the CI sweep over every benchmarked geometry and the full
MobileNetV1/V2 network plans.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax.numpy as jnp

from repro.analysis import jaxpr_audit, mosaic_check, planlint
from repro.analysis.diagnostics import (ERROR, INFO, WARNING, Diagnostic,
                                        Report)
from repro.kernels.blocking import ChainPlan
from repro.kernels.policy import DEFAULT_POLICY, KernelPolicy

__all__ = [
    "Diagnostic", "Report", "PlanVerificationError",
    "analyze_chain", "analyze_network", "verify_or_raise",
    "ERROR", "WARNING", "INFO",
]


class PlanVerificationError(AssertionError):
    """A plan failed static verification; ``.report`` holds the findings."""

    def __init__(self, report: Report):
        self.report = report
        rules = ", ".join(report.rules(ERROR))
        super().__init__(
            f"plan verification failed ({rules}):\n"
            + "\n".join(d.format() for d in report.errors))


def analyze_chain(spec, chain_plan: ChainPlan, x_shape: Sequence[int], *,
                  dtype=jnp.float32,
                  policy: KernelPolicy = DEFAULT_POLICY,
                  label: str = "chain", jaxpr: bool = True) -> Report:
    """All passes over one planned chain.  ``jaxpr=False`` skips the trace
    audit (used at plan time, where tracing has not happened yet and the
    static passes are the cheap invariant gate)."""
    report = Report()
    report.extend(planlint.lint_chain(spec, chain_plan, x_shape,
                                      label=label))
    for seg_label, _geom, model in planlint.chain_models(spec, chain_plan,
                                                         x_shape):
        if model is not None:
            report.extend(mosaic_check.lint_model(model,
                                                  f"{label}/{seg_label}"))
    if jaxpr:
        report.extend(jaxpr_audit.lint_chain_jaxpr(
            spec, chain_plan, x_shape, dtype=dtype, policy=policy,
            label=label))
    return report


def analyze_network(net, nplan, *,
                    policy: KernelPolicy = DEFAULT_POLICY,
                    block_dtype_policies=None, jaxpr: bool = True,
                    ) -> Report:
    """All passes over a resolved NetworkPlan: each block analyzed at the
    shape/dtype the plan walk recorded, under its effective policy."""
    from repro.core.network import resolve_block_policies
    policies = resolve_block_policies(net, policy, block_dtype_policies)
    report = Report()
    for i, (spec, cp, shape, dt, pol) in enumerate(zip(
            net.blocks, nplan.plans, nplan.block_shapes,
            nplan.block_dtypes, policies)):
        report.extend(analyze_chain(
            spec, cp, shape, dtype=jnp.dtype(dt), policy=pol,
            label=f"block{i}", jaxpr=jaxpr).diagnostics)
    return report


def verify_or_raise(report: Report) -> Report:
    """Raise :class:`PlanVerificationError` on any error diagnostic."""
    if not report.ok:
        raise PlanVerificationError(report)
    return report


def lint_cached_plan(spec, chain_plan: ChainPlan, x_shape: Sequence[int],
                     *, label: str = "cache") -> Optional[str]:
    """Static-only validation for replayed tune-cache entries: the error
    rule ids as one string, or None when the plan is clean.  Kept tiny and
    import-light — ``kernels/autotune.py`` calls this lazily on every
    cache hit."""
    diags = planlint.lint_chain(spec, chain_plan, x_shape, label=label)
    rules = sorted({d.rule for d in diags if d.severity == ERROR})
    return ", ".join(rules) if rules else None
