"""``python -m repro.analysis`` — the CI planlint sweep (DESIGN.md §8).

Plans and statically verifies every benchmarked geometry
(``benchmarks/layers.py``: the separable-block suites incl. the
high-resolution slabbed blocks, and the whole inverted residuals) plus the
full MobileNetV1/V2, MnasNet-A1 and EfficientNet-Lite0 network plans (the
latter two exercising the SE and fused-MBConv stage kinds, DESIGN.md §10)
under BOTH dtype policies (native fp32
and bf16 streaming), then prints the diagnostics summary and exits 1 on
any error-severity finding.  ``--json PATH`` writes the structured report
(sorted keys, trailing newline — stable diffs) for the CI artifact.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import jax.numpy as jnp

from repro import analysis
from repro.analysis.diagnostics import INFO, Diagnostic, Report
from repro.core import chain, network
from repro.kernels.policy import BF16_STREAM, NATIVE, KernelPolicy


def _bench_layers():
    """Import benchmarks/layers.py from the repo root; None when the
    benchmarks tree is not present (installed-package use)."""
    try:
        from benchmarks import layers
        return layers
    except ImportError:
        pass
    root = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))))
    sys.path.insert(0, root)
    try:
        from benchmarks import layers
        return layers
    except ImportError:
        return None


def _policies() -> dict:
    """The two CI dtype policies, on the Pallas interpret path so the jaxpr
    audit sees the real kernel lowering structure on any host."""
    base = KernelPolicy(impl="pallas", interpret=True)
    import dataclasses
    return {
        "fp32": base,
        "bf16": dataclasses.replace(base, dtype_policy=BF16_STREAM),
    }


def quarantine_diagnostic(spec, shape, dtype, pol, label):
    """RT401: the problem is quarantined on this backend (DESIGN.md §9) —
    the static sweep REPORTS it instead of re-verifying a plan the runtime
    ladder will degrade at execute time anyway.  None when not quarantined
    (or the policy opted out of the ladder)."""
    if pol.on_failure != "degrade":
        return None
    from repro.runtime import quarantine
    banned = quarantine.banned_kinds(spec, shape, dtype, pol)
    if not banned:
        return None
    return Diagnostic(
        rule="RT401", severity=INFO, segment=label,
        message=f"plan quarantined on this backend (banned rungs: "
                f"{sorted(banned)}); the runtime ladder degrades it at "
                "execute time — static re-verification skipped",
        hint="inspect/clear the quarantine store "
             "(runtime.quarantine.quarantine_path) to re-verify the full "
             "ladder")


def sweep(batch: int = 1, res: int = 112, jaxpr: bool = True,
          verbose: bool = False) -> Report:
    report = Report()
    policies = _policies()
    layers = _bench_layers()

    def run(label, spec, shape, dtype, pol):
        qd = quarantine_diagnostic(spec, shape, dtype, pol, label)
        if qd is not None:
            report.extend([qd])
            print(f"  {label:44s} QUARANTINED "
                  f"(RT401 — runtime ladder degrades it)")
            return
        cp = chain.plan(spec, shape, dtype=dtype, policy=pol)
        r = analysis.analyze_chain(spec, cp, shape, dtype=dtype, policy=pol,
                                   label=label, jaxpr=jaxpr)
        report.extend(r.diagnostics)
        status = "ok" if r.ok else "FAIL " + ",".join(r.rules("error"))
        print(f"  {label:44s} {status}")
        if verbose and r.diagnostics:
            print(r.format())

    if layers is not None:
        for pname, pol in policies.items():
            print(f"# separable-block suites ({pname})")
            for suite, blocks in layers.SEP_SUITES.items():
                for blk in blocks:
                    spec = chain.separable_block_spec(blk.c_out,
                                                      stride=blk.stride,
                                                      hf=blk.hf)
                    run(f"sep/{suite}/{blk.name}/{pname}", spec,
                        (batch, blk.h, blk.w, blk.c_in), jnp.float32, pol)
            print(f"# inverted residuals ({pname})")
            for ir in layers.MOBILENET_V2_IR:
                spec = chain.inverted_residual_spec(
                    ir.c_in, ir.c_out, expand=ir.expand, stride=ir.stride,
                    hf=ir.hf)
                run(f"ir/{ir.name}/{pname}", spec,
                    (batch, ir.h, ir.h, ir.c_in), jnp.float32, pol)
    else:
        print("# benchmarks/layers.py not importable — network plans only")

    for pname, pol in policies.items():
        for net in (network.mobilenet_v1_spec(),
                    network.mobilenet_v2_spec(),
                    network.mnasnet_a1_spec(),
                    network.efficientnet_lite0_spec()):
            label = f"network/{net.name}/res{res}/{pname}"
            x_shape = (batch, res, res, net.c_in)
            bpols = network.resolve_block_policies(net, pol)
            problems, _ = network._block_problems(net, x_shape,
                                                  jnp.float32, bpols)
            qds = [qd for i, (spec, (shape, dt), bp) in enumerate(
                       zip(net.blocks, problems, bpols))
                   for qd in [quarantine_diagnostic(
                       spec, shape, jnp.dtype(dt), bp,
                       f"{label}/block{i}")]
                   if qd is not None]
            if qds:
                report.extend(qds)
                print(f"  {label:44s} QUARANTINED ({len(qds)} blocks, "
                      f"RT401 — runtime ladder degrades them)")
                continue
            nplan = network.plan_network(
                net, x_shape, dtype=jnp.float32, policy=pol)
            r = analysis.analyze_network(net, nplan, policy=pol,
                                         jaxpr=jaxpr)
            report.extend(r.diagnostics)
            status = "ok" if r.ok else "FAIL " + ",".join(r.rules("error"))
            print(f"  {label:44s} {status}  ({nplan.n_blocks} blocks, "
                  f"{nplan.n_kernel_passes} passes)")
            if verbose and r.diagnostics:
                print(r.format())
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static plan/kernel verifier over benchmarked "
                    "geometries and full network plans.")
    ap.add_argument("--batch", type=int, default=1)
    ap.add_argument("--res", type=int, default=112,
                    help="network-plan input resolution (default 112)")
    ap.add_argument("--json", metavar="PATH",
                    help="write the structured report here")
    ap.add_argument("--no-jaxpr", action="store_true",
                    help="skip the (slower) traced-jaxpr audits")
    ap.add_argument("--verbose", action="store_true",
                    help="print every diagnostic, not just failures")
    args = ap.parse_args(argv)

    report = sweep(batch=args.batch, res=args.res,
                   jaxpr=not args.no_jaxpr, verbose=args.verbose)
    print(report.format(max_lines=None if args.verbose else 40))
    if args.json:
        d = os.path.dirname(args.json)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(args.json, "w") as f:
            json.dump(report.to_json(), f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"report written to {args.json}")
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
