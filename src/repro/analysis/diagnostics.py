"""Structured diagnostics for the static plan/kernel verifier (DESIGN.md §8).

Every analysis pass (``planlint``, ``mosaic_check``, ``jaxpr_audit``) answers
with a list of :class:`Diagnostic`s — rule id, severity, the segment and
geometry it fired on, and a fix hint — collected into a :class:`Report` that
the CLI serializes for CI and ``verify_or_raise`` turns into a hard error.

Severities:

* ``error``   — the plan is infeasible or provably wrong (over the physical
  VMEM ceiling, out-of-bounds halo window, overlapping output tiles, a cast
  the dtype policy does not own).  CI fails; ``verify_or_raise`` raises.
* ``warning`` — legal but suspicious (over the *soft* planner budget,
  lane-misaligned blocks that cost utilization, a stale tune-cache entry).
* ``info``    — facts worth surfacing (unblocked indexing pending hardware
  validation — the ROADMAP item the static half of which this closes).
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, List

ERROR = "error"
WARNING = "warning"
INFO = "info"

SEVERITIES = (ERROR, WARNING, INFO)


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One finding: which rule, how bad, where, and how to fix it."""
    rule: str           # e.g. "PL101"
    severity: str       # one of SEVERITIES
    message: str        # what is wrong, with the numbers
    segment: str = ""   # which chain/network segment (e.g. "block3/fused3")
    geometry: str = ""  # the shapes the rule evaluated
    hint: str = ""      # how to fix it

    def __post_init__(self):
        assert self.severity in SEVERITIES, self.severity

    def format(self) -> str:
        loc = f" [{self.segment}]" if self.segment else ""
        geo = f" ({self.geometry})" if self.geometry else ""
        hint = f"  hint: {self.hint}" if self.hint else ""
        return (f"{self.severity.upper():7s} {self.rule}{loc}: "
                f"{self.message}{geo}{hint}")


@dataclasses.dataclass
class Report:
    """All diagnostics of one analysis run, CI-serializable."""
    diagnostics: List[Diagnostic] = dataclasses.field(default_factory=list)

    def extend(self, diags: Iterable[Diagnostic]) -> "Report":
        self.diagnostics.extend(diags)
        return self

    @property
    def errors(self) -> List[Diagnostic]:
        return [d for d in self.diagnostics if d.severity == ERROR]

    @property
    def warnings(self) -> List[Diagnostic]:
        return [d for d in self.diagnostics if d.severity == WARNING]

    @property
    def ok(self) -> bool:
        """No error-severity diagnostics (warnings/info do not fail)."""
        return not self.errors

    def rules(self, severity: str | None = None) -> List[str]:
        return sorted({d.rule for d in self.diagnostics
                       if severity is None or d.severity == severity})

    def summary(self) -> str:
        n = {s: sum(1 for d in self.diagnostics if d.severity == s)
             for s in SEVERITIES}
        return (f"{n[ERROR]} error(s), {n[WARNING]} warning(s), "
                f"{n[INFO]} info")

    def to_json(self) -> dict:
        return {
            "ok": self.ok,
            "summary": self.summary(),
            "diagnostics": [dataclasses.asdict(d) for d in self.diagnostics],
        }

    def format(self, *, max_lines: int | None = None) -> str:
        order = {ERROR: 0, WARNING: 1, INFO: 2}
        diags = sorted(self.diagnostics, key=lambda d: order[d.severity])
        lines = [d.format() for d in diags]
        if max_lines is not None and len(lines) > max_lines:
            lines = lines[:max_lines] + [
                f"... {len(lines) - max_lines} more"]
        return "\n".join(lines + [self.summary()])
