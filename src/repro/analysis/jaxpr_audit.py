"""jaxpr_audit — trace planned segments and audit the fusion/cast claims
(DESIGN.md §8).

``ChainPlan.fully_fused`` and the per-segment ``single_pass`` claims are
the whole point of the fused lowering (neither intermediate in HBM); the
dtype policy's contract is that EVERY cast is owned by the lowering
boundary and accumulation stays fp32.  Parity tests check values, not
these structural claims — this pass checks them on the traced jaxpr:

* JX301 (error) — pass-count mismatch: the traced chain contains a
  different number of ``pallas_call``s than the plan's segment count (a
  fused plan that silently lowered to multiple passes, or re-planning
  inside the lowering).
* JX302 (error) — HBM intermediate: a ``fully_fused`` chain whose traced
  program runs compute primitives OUTSIDE the kernel — any such op
  materializes an intermediate the fusion claim says does not exist.
  (Data movement/layout prep — pad, slice, reshape, transpose, casts — is
  allowed: it feeds the one kernel.)
* JX310 (error) — rogue cast: a ``convert_element_type`` outside kernels
  to a dtype the :class:`~repro.kernels.policy.DtypePolicy` does not own
  (allowed: the stream dtype, the out dtype, and float32 — the
  accumulation width).
* JX311 (error) — accumulation not fp32: an in-kernel ``dot_general``
  whose ``preferred_element_type`` is not float32.

Tracing uses ``jax.make_jaxpr`` over the lowered runner with
``ShapeDtypeStruct`` params — no data, no compilation, works in interpret
mode.  The audit functions are granular (each takes a jaxpr) so the
seeded-violation tests can corrupt a callable and audit the trace.
"""
from __future__ import annotations

from typing import Iterable, List, Sequence, Set, Tuple

import jax
import jax.numpy as jnp

from repro.analysis.diagnostics import ERROR, Diagnostic
from repro.kernels import lowering
from repro.kernels.blocking import ChainPlan
from repro.kernels.policy import KernelPolicy

#: Primitives a fully-fused chain may run OUTSIDE the kernel: data prep for
#: the one kernel pass (padding, layout, casts) — never compute.
ALLOWED_OUTSIDE = frozenset({
    "pallas_call", "pjit", "closed_call", "custom_jvp_call",
    "custom_vjp_call", "convert_element_type", "pad", "slice",
    "dynamic_slice", "reshape", "broadcast_in_dim", "transpose", "squeeze",
    "concatenate", "iota", "copy",
})


def param_structs(spec, c_in: int, dtype) -> list:
    """``ShapeDtypeStruct`` params mirroring ``core/chain.init_chain``
    (duck-typed on the stage objects, like the lowering)."""
    d = jnp.dtype(dtype)
    params = []
    c = c_in
    for s in spec.stages:
        if hasattr(s, "reduce"):            # SE
            p = {"w1": jax.ShapeDtypeStruct((c, s.reduce), d),
                 "b1": jax.ShapeDtypeStruct((s.reduce,), d),
                 "w2": jax.ShapeDtypeStruct((s.reduce, c), d),
                 "b2": jax.ShapeDtypeStruct((c,), d)}
        elif hasattr(s, "features") and hasattr(s, "stride"):  # FusedMB
            p = {"f": jax.ShapeDtypeStruct((s.hf, s.wf, c, s.features), d)}
            if s.bias:
                p["b"] = jax.ShapeDtypeStruct((s.features,), d)
            c = s.features
        elif hasattr(s, "features"):        # PW
            p = {"w": jax.ShapeDtypeStruct((c, s.features), d)}
            if s.bias:
                p["b"] = jax.ShapeDtypeStruct((s.features,), d)
            c = s.features
        else:                               # DW
            p = {"f": jax.ShapeDtypeStruct((s.hf, s.wf, c), d)}
            if s.bias:
                p["b"] = jax.ShapeDtypeStruct((c,), d)
        params.append(p)
    return params


def trace_chain(spec, chain_plan: ChainPlan, x_shape: Sequence[int],
                dtype, policy: KernelPolicy):
    """The closed jaxpr of the lowered chain at these shapes (trace only —
    no data, no compile)."""
    run = lowering.lower(spec, chain_plan, policy)
    params = param_structs(spec, int(x_shape[-1]), dtype)
    x = jax.ShapeDtypeStruct(tuple(int(v) for v in x_shape),
                             jnp.dtype(dtype))
    return jax.make_jaxpr(run)(params, x)


def iter_eqns(jaxpr, in_kernel: bool = False) -> Iterable[Tuple[object,
                                                                bool]]:
    """Yield (eqn, in_kernel) over a jaxpr and every sub-jaxpr in its
    params; ``in_kernel`` is True inside a ``pallas_call`` body."""
    inner = getattr(jaxpr, "jaxpr", jaxpr)   # ClosedJaxpr -> Jaxpr
    for eqn in inner.eqns:
        yield eqn, in_kernel
        child_in_kernel = in_kernel or eqn.primitive.name == "pallas_call"
        for v in eqn.params.values():
            for sub in _sub_jaxprs(v):
                yield from iter_eqns(sub, child_in_kernel)


def _sub_jaxprs(v):
    if hasattr(v, "eqns") or hasattr(v, "jaxpr"):
        yield v
    elif isinstance(v, (tuple, list)):
        for item in v:
            yield from _sub_jaxprs(item)


# ---------------------------------------------------------------------------
# Granular audits (each over one traced jaxpr)
# ---------------------------------------------------------------------------

def audit_passes(jaxpr, n_expected: int, fully_fused: bool,
                 segment: str = "") -> List[Diagnostic]:
    """JX301 (pass count) and JX302 (HBM intermediates of a fused chain)."""
    diags: List[Diagnostic] = []
    n_calls = 0
    outside_compute = []
    for eqn, in_kernel in iter_eqns(jaxpr):
        if in_kernel:
            continue
        name = eqn.primitive.name
        if name == "pallas_call":
            n_calls += 1
        elif name not in ALLOWED_OUTSIDE:
            outside_compute.append(name)
    if n_calls != n_expected:
        diags.append(Diagnostic(
            "JX301", ERROR,
            f"traced chain runs {n_calls} kernel pass(es) but the plan "
            f"has {n_expected} segment(s)", segment,
            hint="the lowering re-planned or a fused segment silently "
                 "split"))
    if fully_fused and outside_compute:
        names = sorted(set(outside_compute))
        diags.append(Diagnostic(
            "JX302", ERROR,
            f"fully_fused chain runs compute outside the kernel: "
            f"{', '.join(names)} — an intermediate reaches HBM", segment,
            hint="every stage of a fused segment must execute inside the "
                 "single pallas_call"))
    return diags


def audit_casts(jaxpr, allowed_dtypes: Set[str],
                segment: str = "") -> List[Diagnostic]:
    """JX310: every outside-kernel ``convert_element_type`` must target a
    dtype the policy owns (stream, out, or the fp32 accumulation width)."""
    diags: List[Diagnostic] = []
    flagged = set()
    for eqn, in_kernel in iter_eqns(jaxpr):
        if in_kernel or eqn.primitive.name != "convert_element_type":
            continue
        new = jnp.dtype(eqn.params["new_dtype"]).name
        if new not in allowed_dtypes and new not in flagged:
            flagged.add(new)
            diags.append(Diagnostic(
                "JX310", ERROR,
                f"cast to {new} outside any kernel, not attributable to "
                f"the dtype policy (owns: {sorted(allowed_dtypes)})",
                segment,
                hint="all casts belong to the lowering boundary "
                     "(kernels/lowering.py, DESIGN.md §7)"))
    return diags


def audit_accumulation(jaxpr, segment: str = "") -> List[Diagnostic]:
    """JX311: in-kernel matmuls must accumulate fp32
    (``preferred_element_type=float32`` — what the MXU widens to)."""
    diags: List[Diagnostic] = []
    for eqn, in_kernel in iter_eqns(jaxpr):
        if not in_kernel or eqn.primitive.name != "dot_general":
            continue
        pref = eqn.params.get("preferred_element_type")
        if pref is None or jnp.dtype(pref) != jnp.float32:
            diags.append(Diagnostic(
                "JX311", ERROR,
                f"in-kernel dot_general accumulates at "
                f"{jnp.dtype(pref).name if pref is not None else 'input'} "
                "width, not float32", segment,
                hint="pass preferred_element_type=jnp.float32 "
                     "(blocking.ACC_BYTES is the fp32 contract)"))
            break
    return diags


# ---------------------------------------------------------------------------
# The whole pass over one planned chain
# ---------------------------------------------------------------------------

def lint_chain_jaxpr(spec, chain_plan: ChainPlan, x_shape: Sequence[int],
                     *, dtype, policy: KernelPolicy,
                     label: str = "chain") -> List[Diagnostic]:
    """Trace the lowered chain and run every jaxpr audit.  Pass-structure
    rules (JX301/JX302) only apply on the Pallas backend — the XLA
    reference path has no kernel passes to count."""
    jaxpr = trace_chain(spec, chain_plan, x_shape, dtype, policy)
    dp = policy.dtype_policy
    allowed = {dp.stream_dtype(dtype).name, dp.out_dtype(dtype).name,
               "float32"}
    diags = audit_casts(jaxpr, allowed, label)
    diags.extend(audit_accumulation(jaxpr, label))
    if policy.resolved() == "pallas":
        # se lowers to TWO pwconv passes (reduce + expand GEMMs); mb lowers
        # to the XLA convolution on every impl (ZERO Pallas passes)
        n_expected = sum({"se": 2, "mb": 0}.get(s.kind, 1)
                         for s in chain_plan.segments)
        diags.extend(audit_passes(jaxpr, n_expected,
                                  chain_plan.fully_fused, label))
    return diags
