"""mosaic_check — TPU tiling lint over derived KernelModels (DESIGN.md §8).

Mosaic lays VMEM out in (sublane, lane) tiles: the minor dimension in
128-lane vectors, the second-minor in sublanes whose count depends on the
element width (fp32 8, bf16 16, int8/fp8 32).  Interpret-mode parity tests
(the whole test suite on CPU) cannot see these constraints — ROADMAP open
item 1 is precisely that the in-kernel collapsing reshapes and the
``pl.unblocked`` row offsets are unvalidated against them.  This pass
encodes the statically checkable half as lint rules:

* MC201 (warning) — a block's minor dimension is not a multiple of 128
  lanes (legal, but pads every vector: lane utilization cost).
* MC202 (info) — second-minor dimension off the sublane count for the
  element width (Mosaic pads; cheap but worth seeing).
* MC203 (warning) — an in-kernel collapsing reshape
  (``(Sh, Wo, Cb) -> (Sh·Wo, Cb)``) whose collapsed second-minor is not
  sublane-aligned, or that changes the minor dimension — the shapes Mosaic
  may refuse or spill on.
* MC204 — ``pl.unblocked`` element offsets: misaligned offsets in the
  TILED (last two) dimensions are a warning; any unblocked use at all is
  an info (the dynamic half of the ROADMAP item still needs hardware).
* MC205 (error) — an "arbitrary" (reduction) grid dimension that is not
  innermost: the revisiting-accumulator pattern every kernel here relies
  on requires reduction dims after all parallel dims.

The dtype->sublane table is :data:`SUBLANES`; rules receive the SAME
``KernelModel`` the kernels build their BlockSpecs from.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

from repro.analysis.diagnostics import (ERROR, INFO, WARNING, Diagnostic)
from repro.kernels.gridspec import BlockRef, KernelModel

#: itemsize (bytes) -> minimum sublane count of the second-minor dimension.
SUBLANES = {4: 8, 2: 16, 1: 32}

LANES = 128


def _sublanes(itemsize: int) -> int:
    return SUBLANES.get(itemsize, 8)


def _check_block_alignment(br: BlockRef, segment: str) -> List[Diagnostic]:
    """MC201/MC202 for one operand's block shape."""
    diags: List[Diagnostic] = []
    shape = br.block_shape
    geo = f"{br.name} block={shape}"
    if len(shape) >= 1 and shape[-1] % LANES:
        sev = WARNING if shape[-1] != br.array_shape[-1] else INFO
        diags.append(Diagnostic(
            "MC201", sev,
            f"minor dim {shape[-1]} is not a multiple of {LANES} lanes",
            segment, geo,
            "lane utilization drops; prefer 128-multiples (or all of the "
            "dim when it is small)"))
    sub = _sublanes(br.itemsize)
    if len(shape) >= 2 and shape[-2] % sub:
        diags.append(Diagnostic(
            "MC202", INFO,
            f"second-minor dim {shape[-2]} off the {sub}-sublane tile for "
            f"{br.itemsize}-byte elements", segment, geo))
    return diags


def check_reshapes(reshapes: Sequence[Tuple[Tuple[int, ...],
                                            Tuple[int, ...]]],
                   itemsize: int, segment: str = "") -> List[Diagnostic]:
    """MC203 over the in-kernel reshape list a model records."""
    diags: List[Diagnostic] = []
    sub = _sublanes(itemsize)
    for src, dst in reshapes:
        geo = f"reshape {src} -> {dst}"
        if src[-1] != dst[-1]:
            diags.append(Diagnostic(
                "MC203", WARNING,
                "reshape changes the minor (lane) dimension — Mosaic "
                "lowers this as a relayout", segment, geo,
                "keep the channel dim minor through in-kernel reshapes"))
        elif len(dst) < len(src) and src[-2] % sub:
            diags.append(Diagnostic(
                "MC203", WARNING,
                f"sublane-collapsing reshape with second-minor {src[-2]} "
                f"off the {sub}-sublane tile", segment, geo,
                "Mosaic may refuse or pad the collapse; pick Wo-aligned "
                "blocks or validate on hardware"))
    return diags


def check_unblocked(model: KernelModel, segment: str = "",
                    ) -> List[Diagnostic]:
    """MC204 for every ``pl.unblocked`` operand: evaluate the index map at
    the grid origin and the last cell of each dimension, and flag element
    offsets in the tiled (last two) dims that are off the tile grid."""
    diags: List[Diagnostic] = []
    sub = _sublanes(4)  # offsets land in fp32-tiled VMEM windows
    for br in model.inputs:
        if not br.unblocked:
            continue
        geo = f"{br.name} block={br.block_shape}"
        diags.append(Diagnostic(
            "MC204", INFO,
            "unblocked (element-offset) indexing — statically bounds-"
            "checked here (PL120); runtime Mosaic behavior still needs "
            "hardware validation (ROADMAP)", segment, geo))
        probes = [tuple(0 for _ in model.grid)]
        for d, g in enumerate(model.grid):
            probes.append(tuple(g - 1 if i == d else 0
                                for i in range(len(model.grid))))
        flagged = False
        for idx in probes:
            pos = br.index_map(*idx)
            if len(pos) >= 1 and pos[-1] % LANES:
                flagged = True
            if len(pos) >= 2 and pos[-2] % sub:
                flagged = True
        if flagged:
            diags.append(Diagnostic(
                "MC204", WARNING,
                "unblocked offsets in the tiled (last two) dims are not "
                "tile-aligned", segment, geo,
                "Mosaic must realign every fetch; prefer sublane-aligned "
                "slab offsets"))
    return diags


def check_semantics(model: KernelModel, segment: str = "",
                    ) -> List[Diagnostic]:
    """MC205: reduction ("arbitrary") dims must be innermost."""
    sem = model.dimension_semantics
    seen_arbitrary = False
    for s in sem:
        if s == "arbitrary":
            seen_arbitrary = True
        elif seen_arbitrary:
            return [Diagnostic(
                "MC205", ERROR,
                f"dimension_semantics {sem} has a parallel dim after an "
                "arbitrary (reduction) dim", segment, f"grid={model.grid}",
                "the VMEM accumulator is only revisited when reduction "
                "dims are innermost (RTRD)")]
    return []


def lint_model(model: KernelModel, segment: str = "") -> List[Diagnostic]:
    """All mosaic rules over one derived kernel model."""
    diags: List[Diagnostic] = []
    for br in list(model.inputs) + [model.output]:
        diags.extend(_check_block_alignment(br, segment))
    diags.extend(check_reshapes(model.reshapes, model.inputs[0].itemsize,
                                segment))
    diags.extend(check_unblocked(model, segment))
    diags.extend(check_semantics(model, segment))
    return diags
