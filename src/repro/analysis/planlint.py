"""planlint — static verification of ChainPlans against the ACTUAL kernel
lowering geometry (DESIGN.md §8).

Parity tests catch wrong *values*; this pass catches infeasible or silently
degraded *plans* before anything runs — the class of planner<->lowering
drift PR 4 had to fix by hand.  Three layers of checks per segment:

1. **Plan-field checks** (PL101-PL113): the planner's own VMEM model
   recomputed at the plan's block fields must match ``BlockPlan.vmem_bytes``
   exactly (drift detection), stay within the policy budget, and every
   block field must be a value the §4 ladders can produce (snapped channel
   blocks, valid Co panels, consistent slab fields).
2. **Derived-VMEM check** (PL103): the working set re-derived from the
   BlockSpecs the lowering will emit — via the same ``*_kernel_model``
   builders the kernels construct their ``pl.BlockSpec``s from
   (``kernels/gridspec.py``) — must stay under the 16 MiB physical ceiling
   (error) and the soft planner budget (warning).  Because the kernels
   consume the identical model, this is not a parallel re-derivation.
3. **Grid enumeration** (PL120-PL123): statically enumerate the grid and
   evaluate every ``index_map`` to prove halo input windows stay in-bounds,
   output blocks cover every output tile exactly once (no gaps), tile
   disjointly across parallel grid coordinates (write-race detection), and
   the output map never depends on a reduction ("arbitrary") dimension —
   the RTRD accumulator contract.

Entry point: :func:`lint_chain`; :func:`chain_models` exposes the derived
``KernelModel``s for the mosaic pass; :func:`check_grid` is public so the
seeded-violation tests can corrupt a model directly.
"""
from __future__ import annotations

import itertools
from typing import List, Optional, Sequence, Tuple

from repro.analysis.diagnostics import (ERROR, INFO, WARNING, Diagnostic)
from repro.kernels import blocking
from repro.kernels.autotune import _SegGeom, _segment_geoms
from repro.kernels.blocking import BlockPlan, ChainPlan
from repro.kernels.dwconv2d import dw_kernel_model
from repro.kernels.fused_mbconv import fused_mb_kernel_model
from repro.kernels.gridspec import VMEM_HARD_BYTES, KernelModel
from repro.kernels.pwconv import pw_clamp_blocks, pw_kernel_model
from repro.kernels.se_epilogue import dw_se_kernel_model
from repro.kernels.separable_fused import fused_kernel_model

#: Segment kinds with no Pallas kernel of their own: ``se`` lowers to two
#: pwconv passes (linted as GEMMs at their own geometry would be, but
#: composed by the lowering) + XLA pool/scale; ``mb`` lowers to the XLA
#: convolution on every impl.  ``segment_kernel_model`` returns None for
#: these BY DESIGN — not plan corruption.
XLA_COMPOSED_KINDS = ("se", "mb")

#: Grid-cell ceiling for exhaustive enumeration; larger grids are checked at
#: per-dimension boundary samples (first/last/middle) and coverage checks
#: are skipped with an INFO diagnostic — never silently.
MAX_GRID_POINTS = 200_000


def walk_segments(spec, chain_plan: ChainPlan,
                  x_shape: Sequence[int]) -> List[_SegGeom]:
    """Per-segment kernel geometry — the same shape walk the autotuner's
    candidate enumeration uses (duck-typed on the stage objects)."""
    return _segment_geoms(spec.stages, chain_plan, x_shape)


def _geom_str(geom: _SegGeom) -> str:
    if geom.kind == "pw":
        return f"pw g={geom.g} ci={geom.ci} co={geom.co}"
    return (f"{geom.kind} ho={geom.ho} wo={geom.wo} ci={geom.ci} "
            f"c={geom.c} co={geom.co} stride={geom.stride} "
            f"hf={geom.hf}x{geom.wf}")


def segment_kernel_model(geom: _SegGeom, plan: BlockPlan,
                         b: int) -> Optional[KernelModel]:
    """The KernelModel this segment's kernel will lower to — built by the
    SAME ``*_kernel_model`` function the kernel itself consumes.  The
    output itemsize is taken at the stream width (``plan.dtype_bytes``);
    a wider final store only grows the output buffer, which PL103's hard
    ceiling still bounds via the fp32 accumulator/value terms.  Returns
    None for :data:`XLA_COMPOSED_KINDS` (no single Pallas kernel)."""
    nb = plan.dtype_bytes
    if geom.kind in XLA_COMPOSED_KINDS:
        return None
    if geom.kind == "fusedmb":
        return fused_mb_kernel_model(
            b=b, ho=geom.ho, wo=geom.wo, c_in=geom.ci, c=geom.c,
            co=geom.co, hf=geom.hf, wf=geom.wf, stride=geom.stride,
            block_c=plan.block_c, block_co=plan.block_co,
            slab_h=plan.slab_h, itemsize=nb, out_itemsize=nb,
            has_mb_bias=True, has_pw_bias=True,
            has_residual=geom.residual,
        )
    if geom.kind == "dw_se":
        hiu = (geom.ho - 1) * geom.stride + geom.hf
        wiu = (geom.wo - 1) * geom.stride + geom.wf
        return dw_se_kernel_model(
            b=b, hiu=hiu, wiu=wiu, ho=geom.ho, wo=geom.wo, c=geom.c,
            c_se=geom.g, hf=geom.hf, wf=geom.wf,
            itemsize=nb, out_itemsize=nb, has_dw_bias=True,
        )
    if geom.kind in ("fused3", "fused2"):
        return fused_kernel_model(
            b=b, ho=geom.ho, wo=geom.wo, c_in=geom.ci, c=geom.c, co=geom.co,
            hf=geom.hf, wf=geom.wf, stride=geom.stride,
            block_c=plan.block_c, block_co=plan.block_co,
            slab_h=plan.slab_h, itemsize=nb, out_itemsize=nb,
            has_expand=geom.kind == "fused3", has_dw_bias=True,
            has_pw_bias=True, has_residual=geom.residual,
        )
    if geom.kind == "dw":
        hiu = (geom.ho - 1) * geom.stride + geom.hf
        wiu = (geom.wo - 1) * geom.stride + geom.wf
        return dw_kernel_model(
            b=b, hiu=hiu, wiu=wiu, ho=geom.ho, wo=geom.wo, c=geom.c,
            block_c=plan.block_c, hf=geom.hf, wf=geom.wf,
            itemsize=nb, out_itemsize=nb,
        )
    assert geom.kind == "pw", geom.kind
    bg, bco, bci = pw_clamp_blocks(geom.g, geom.ci, geom.co,
                                   plan.block_g, plan.block_co, plan.block_c)
    return pw_kernel_model(
        g=geom.g, ci=geom.ci, co=geom.co, bg=bg, bci=bci, bco=bco,
        has_bias=True, itemsize=nb, out_itemsize=nb,
    )


# ---------------------------------------------------------------------------
# PL101-PL113: plan-field checks
# ---------------------------------------------------------------------------

def _claimed_vmem(geom: _SegGeom, plan: BlockPlan,
                  b: Optional[int] = None, budget: Optional[int] = None,
                  ) -> int:
    """The planner's own model recomputed at the plan's block fields."""
    nb = plan.dtype_bytes
    if geom.kind == "fused3":
        return blocking.fused3_vmem_bytes(
            geom.wo, plan.slab_h, geom.ci, plan.block_c, plan.block_co,
            geom.hf, geom.wf, geom.stride, nb, geom.residual)
    if geom.kind == "fused2":
        return blocking.fused_vmem_bytes(
            geom.wo, plan.slab_h, plan.block_c, plan.block_co,
            geom.hf, geom.wf, geom.stride, nb, geom.residual)
    if geom.kind == "fusedmb":
        return blocking.fused_mb_vmem_bytes(
            geom.wo, plan.slab_h, geom.ci, plan.block_c, plan.block_co,
            geom.hf, geom.wf, geom.stride, nb, geom.residual)
    if geom.kind == "dw_se":
        hiu = (geom.ho - 1) * geom.stride + geom.hf
        wiu = (geom.wo - 1) * geom.stride + geom.wf
        return blocking.dw_se_vmem_bytes(
            hiu, wiu, geom.ho, geom.wo, geom.c, geom.g,
            geom.hf, geom.wf, nb)
    if geom.kind == "mb":
        # lowers to the XLA convolution on every impl: no Pallas working
        # set to claim (plan_mb)
        return 0
    if geom.kind == "se":
        # the claim is the larger inner pwconv plan's working set; the
        # GEMM's G dimension is the BATCH, which the shape walk does not
        # carry — recompute only when the caller supplies it
        if b is None:
            return plan.vmem_bytes
        dtype = "bfloat16" if nb == 2 else "float32"
        kw = {} if budget is None else {"vmem_budget": budget}
        return blocking.plan_se(b, geom.c, geom.g, dtype=dtype,
                                **kw).vmem_bytes
    if geom.kind == "dw":
        hiu = (geom.ho - 1) * geom.stride + geom.hf
        wiu = (geom.wo - 1) * geom.stride + geom.wf
        return blocking.dwconv2d_vmem_bytes(
            hiu, wiu, geom.ho, geom.wo, plan.block_c, geom.hf, geom.wf, nb)
    return blocking.pwconv_vmem_bytes(
        plan.block_g, plan.block_c, plan.block_co, nb)


def lint_segment_fields(geom: _SegGeom, plan: BlockPlan, budget: int,
                        segment: str,
                        b: Optional[int] = None) -> List[Diagnostic]:
    """PL101/PL102 (VMEM claim), PL110-PL114 (block-field validity).
    ``b`` (the batch) tightens the PL102 recompute for ``se`` segments,
    whose GEMM rows are the batch dimension."""
    diags: List[Diagnostic] = []
    geo = _geom_str(geom)

    def err(rule, msg, hint=""):
        diags.append(Diagnostic(rule, ERROR, msg, segment, geo, hint))

    if geom.kind == "pw":
        # PL113: splitting G/Ci/Co at a boundary the (8, 128) tile cannot
        # express (the kernel clamps oversized blocks, so only
        # misaligned SPLITS are wrong, not large requests).
        bg, bco, bci = pw_clamp_blocks(geom.g, geom.ci, geom.co,
                                       plan.block_g, plan.block_co,
                                       plan.block_c)
        if bg <= 0 or bco <= 0 or bci <= 0:
            err("PL113", f"degenerate GEMM blocks (bg={bg}, bco={bco}, "
                f"bci={bci})", "use plan_pwconv / PW_G_CANDIDATES")
        else:
            if bg < geom.g and bg % 8:
                err("PL113", f"G panel {bg} splits g={geom.g} off the "
                    "8-sublane tile", "pick block_g from PW_G_CANDIDATES")
            if bci < geom.ci and bci % blocking.LANES:
                err("PL113", f"Ci block {bci} splits the reduction off the "
                    f"{blocking.LANES}-lane tile",
                    "use a multiple of 128 for block_ci")
            if bco < geom.co and bco % blocking.LANES:
                err("PL113", f"Co block {bco} splits co={geom.co} off the "
                    f"{blocking.LANES}-lane tile",
                    "use a multiple of 128 for block_co")
    elif geom.kind in XLA_COMPOSED_KINDS:
        # se / mb compose XLA (+pwconv) passes — no kernel blocks to
        # validate, but degenerate slab fields must still hold.
        if plan.n_slabs != 1 or plan.halo_rows != 0:
            err("PL112", f"{geom.kind} segment carries slab fields "
                f"(n_slabs={plan.n_slabs}, halo_rows={plan.halo_rows})",
                "XLA-composed segments have no spatial slab dimension")
    elif geom.kind == "dw_se":
        # PL114: the SE gate mixes ALL channels of a pool over ALL spatial
        # positions — partial residency is a WRONG answer, not a slower
        # one (kernels/se_epilogue.py residency contract).
        if plan.block_c != geom.c:
            err("PL114", f"block_c={plan.block_c} != C={geom.c} on a dw_se "
                "segment — the SE gate would be computed from a partial "
                "channel set",
                "dw_se requires full-channel residency; degrade to "
                "standalone dw + se instead of shrinking block_c")
        if plan.n_slabs != 1 or plan.halo_rows != 0 or plan.slab_h != geom.ho:
            err("PL114", f"spatial slabbing (slab_h={plan.slab_h}, "
                f"n_slabs={plan.n_slabs}, halo_rows={plan.halo_rows}) on a "
                "dw_se segment — the pooled mean would span one slab, not "
                "the image",
                "dw_se requires full-spatial residency (slab_h=ho, "
                "n_slabs=1); degrade to standalone dw + se")
        if plan.block_g != geom.g:
            err("PL114", f"block_g={plan.block_g} does not carry the SE "
                f"reduced width c_se={geom.g}",
                "dw_se plans store c_se in block_g (blocking.plan_dw_se)")
    else:
        # PL110: channel block must be a value snap_channels can produce.
        cb = plan.block_c
        if cb <= 0 or cb != blocking.snap_channels(cb, geom.c):
            err("PL110", f"block_c={cb} is not snapped for c={geom.c} "
                f"(want {blocking.snap_channels(max(cb, 1), geom.c)})",
                "channel blocks must be all-of-C, a multiple of 128, or a "
                "power of two (blocking.snap_channels)")
        if geom.kind in ("fused2", "fused3", "fusedmb"):
            # PL111: Co panel must come from the co_candidates ladder.
            if plan.block_co not in blocking.co_candidates(geom.co):
                err("PL111", f"block_co={plan.block_co} is not a valid Co "
                    f"panel for co={geom.co}",
                    "panels are all-of-Co, multiples of 128, or powers of "
                    "two (blocking.co_candidates)")
            # PL112: slab fields must be mutually consistent.
            sh = plan.slab_h
            if sh <= 0 or sh > geom.ho:
                err("PL112", f"slab_h={sh} outside [1, ho={geom.ho}]")
            else:
                n_slabs = -(-geom.ho // sh)
                if plan.n_slabs != n_slabs:
                    err("PL112", f"n_slabs={plan.n_slabs} but ceil(ho/"
                        f"slab_h)={n_slabs}")
                halo = max(geom.hf - geom.stride, 0) if n_slabs > 1 else 0
                if plan.halo_rows != halo:
                    err("PL112", f"halo_rows={plan.halo_rows}, expected "
                        f"{halo} (hf-stride at interior seams)")
        else:  # dw
            if plan.n_slabs != 1 or plan.halo_rows != 0:
                err("PL112", f"dw segment carries slab fields (n_slabs="
                    f"{plan.n_slabs}, halo_rows={plan.halo_rows})",
                    "dwconv2d has no spatial slab dimension")

    if not diags:
        # PL102 only when the fields themselves are coherent — recomputing
        # the model at corrupted fields would double-report.
        claimed = _claimed_vmem(geom, plan, b, budget)
        if plan.vmem_bytes != claimed:
            diags.append(Diagnostic(
                "PL102", ERROR,
                f"vmem_bytes={plan.vmem_bytes} but the planner model at "
                f"these blocks gives {claimed}", segment, geo,
                "the plan was hand-edited or the VMEM model changed under "
                "a persisted plan — re-plan or re-tune"))
    if plan.vmem_bytes > budget:
        diags.append(Diagnostic(
            "PL101", ERROR,
            f"claimed vmem_bytes={plan.vmem_bytes} exceeds the policy "
            f"budget {budget}", segment, geo,
            "shrink blocks (smaller slab_h / block_co) or raise "
            "policy.vmem_budget"))
    return diags


# ---------------------------------------------------------------------------
# PL103 + PL120-PL123: derived VMEM and grid enumeration
# ---------------------------------------------------------------------------

def check_vmem_derived(model: KernelModel, budget: int,
                       segment: str = "", geometry: str = "",
                       ) -> List[Diagnostic]:
    """PL103: the working set derived from the actual BlockSpecs (every
    streamed operand double-buffered + output + scratch + in-kernel values)
    against the 16 MiB physical ceiling (error) and the soft budget
    (warning — the derived count adds double-buffering terms the planner's
    model intentionally amortizes, so near-budget plans are legal)."""
    derived = model.vmem_bytes()
    if derived > VMEM_HARD_BYTES:
        return [Diagnostic(
            "PL103", ERROR,
            f"derived working set {derived} B exceeds physical VMEM "
            f"({VMEM_HARD_BYTES} B)", segment, geometry,
            "this plan cannot lower on real hardware — shrink blocks")]
    if derived > budget:
        return [Diagnostic(
            "PL103", WARNING,
            f"derived working set {derived} B exceeds the soft budget "
            f"{budget} B (physical ceiling ok)", segment, geometry,
            "Mosaic headroom is reduced; consider smaller blocks")]
    return []


def _grid_samples(grid: Tuple[int, ...]):
    """Full enumeration when affordable, else per-dim boundary samples."""
    total = 1
    for g in grid:
        total *= g
    if total <= MAX_GRID_POINTS:
        return itertools.product(*(range(g) for g in grid)), True
    dims = []
    for g in grid:
        pts = {0, g - 1, g // 2, min(1, g - 1), max(g - 2, 0)}
        dims.append(sorted(p for p in pts if 0 <= p < g))
    return itertools.product(*dims), False


def check_grid(model: KernelModel, *, segment: str = "",
               geometry: str = "") -> List[Diagnostic]:
    """PL120-PL123 by static grid enumeration.

    For every (sampled) grid point, every input ``index_map`` is evaluated:
    block-mode maps return block indices (in-bounds iff
    ``(idx+1)*block <= array``), ``pl.unblocked`` maps return ELEMENT
    offsets (in-bounds iff ``offset + block <= array``) — this is what
    proves the overlapping halo windows never read past the padded input.
    The output map must tile the output exactly: every output block
    covered (PL121), no two distinct parallel coordinates writing the same
    block (PL122 — a write race), and no dependence on reduction
    dimensions (PL123 — the accumulator contract).
    """
    diags: List[Diagnostic] = []
    geometry = geometry or f"grid={model.grid}"
    points, full = _grid_samples(model.grid)
    if not full:
        diags.append(Diagnostic(
            "PL121", INFO,
            f"grid {model.grid} too large for exhaustive coverage check; "
            "bounds checked at boundary samples only", segment, geometry))
    red_dims = [i for i, s in enumerate(model.dimension_semantics)
                if s == "arbitrary"]

    out = model.output
    out_blocks = tuple(-(-a // blk) for a, blk
                       in zip(out.array_shape, out.block_shape))
    seen: dict = {}
    oob_reported = set()
    overlap = gap_possible = red_dep = False
    for idx in points:
        for br in model.inputs:
            if br.name in oob_reported:
                continue
            pos = br.index_map(*idx)
            for d, (p, blk, arr) in enumerate(zip(pos, br.block_shape,
                                                  br.array_shape)):
                start = p if br.unblocked else p * blk
                if start < 0 or start + blk > arr:
                    diags.append(Diagnostic(
                        "PL120", ERROR,
                        f"input '{br.name}' window out of bounds at grid "
                        f"{idx}: dim {d} reads [{start}, {start + blk}) of "
                        f"array extent {arr}", segment, geometry,
                        "the index_map or the operand padding is wrong"))
                    oob_reported.add(br.name)
                    break
        opos = out.index_map(*idx)
        for d, (p, blk, arr) in enumerate(zip(opos, out.block_shape,
                                              out.array_shape)):
            if p < 0 or p * blk + blk > arr:
                if "out" not in oob_reported:
                    diags.append(Diagnostic(
                        "PL120", ERROR,
                        f"output block out of bounds at grid {idx}: dim "
                        f"{d} writes block {p} of {arr // blk}",
                        segment, geometry))
                    oob_reported.add("out")
        par = tuple(v for i, v in enumerate(idx) if i not in red_dims)
        prev = seen.get(opos)
        if prev is None:
            seen[opos] = par
        elif prev != par:
            if not overlap:
                diags.append(Diagnostic(
                    "PL122", ERROR,
                    f"output block {opos} written by distinct parallel "
                    f"coordinates {prev} and {par} — a write race",
                    segment, geometry,
                    "output blocks must tile disjointly across parallel "
                    "grid dimensions"))
                overlap = True
        # PL123: reduction-dim dependence — vary each reduction dim by one.
        if not red_dep:
            for rd in red_dims:
                if idx[rd] + 1 < model.grid[rd]:
                    bumped = tuple(v + 1 if i == rd else v
                                   for i, v in enumerate(idx))
                    if out.index_map(*bumped) != opos:
                        diags.append(Diagnostic(
                            "PL123", ERROR,
                            f"output index map depends on reduction dim "
                            f"{rd}: grid {idx} -> {opos} but {bumped} -> "
                            f"{out.index_map(*bumped)}", segment, geometry,
                            "the accumulator tile must be revisited across "
                            "the whole reduction (RTRD)"))
                        red_dep = True
                    break
    if full:
        n_out = 1
        for nb_ in out_blocks:
            n_out *= nb_
        if len(seen) < n_out and not gap_possible:
            missing = next(
                idx for idx in itertools.product(*(range(nb_)
                                                   for nb_ in out_blocks))
                if idx not in seen)
            diags.append(Diagnostic(
                "PL121", ERROR,
                f"output coverage gap: block {missing} of {out_blocks} is "
                "never written", segment, geometry,
                "the grid does not tile the output — check n_slabs / "
                "panel counts"))
    return diags


# ---------------------------------------------------------------------------
# lint_chain: the whole pass over one planned chain
# ---------------------------------------------------------------------------

def chain_models(spec, chain_plan: ChainPlan, x_shape: Sequence[int],
                 ) -> List[Tuple[str, _SegGeom, Optional[KernelModel]]]:
    """(segment label, geometry, derived KernelModel) per segment; the model
    is None when the plan's fields are too corrupted to derive one — or,
    for :data:`XLA_COMPOSED_KINDS` (se, mb), by design."""
    b = int(x_shape[0])
    out = []
    for si, (geom, seg) in enumerate(zip(
            walk_segments(spec, chain_plan, x_shape), chain_plan.segments)):
        label = f"seg{si}/{seg.kind}"
        try:
            model = segment_kernel_model(geom, seg.plan, b)
        except (AssertionError, ArithmeticError, ValueError):
            model = None
        out.append((label, geom, model))
    return out


def lint_chain(spec, chain_plan: ChainPlan, x_shape: Sequence[int], *,
               label: str = "chain") -> List[Diagnostic]:
    """The full planlint pass: field checks, derived VMEM, grid proofs."""
    diags: List[Diagnostic] = []
    budget = chain_plan.vmem_budget
    b = int(x_shape[0])
    for (seg_label, geom, model), seg in zip(
            chain_models(spec, chain_plan, x_shape), chain_plan.segments):
        segment = f"{label}/{seg_label}"
        field_diags = lint_segment_fields(geom, seg.plan, budget, segment,
                                          b=b)
        diags.extend(field_diags)
        if any(d.severity == ERROR for d in field_diags):
            continue  # grid checks on corrupted fields would only cascade
        if model is None:
            if geom.kind not in XLA_COMPOSED_KINDS:
                diags.append(Diagnostic(
                    "PL112", ERROR,
                    "cannot derive the kernel geometry from this plan",
                    segment, _geom_str(geom)))
            continue
        diags.extend(check_vmem_derived(model, budget, segment,
                                        _geom_str(geom)))
        diags.extend(check_grid(model, segment=segment,
                                geometry=_geom_str(geom)))
    return diags
