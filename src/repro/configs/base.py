"""Config schema for every architecture in the framework.

One frozen dataclass covers the ten assigned architectures; family-specific
sub-configs (MoE / SSM / xLSTM / enc-dec) are optional fields. Each
``configs/<arch>.py`` exports ``CONFIG`` (the exact assigned config) and
``smoke_config()`` (a reduced same-family variant for CPU tests).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0            # shared (always-on) experts
    capacity_factor: float = 2.0
    norm_topk: bool = True       # renormalize top-k router weights
    router_aux_weight: float = 1e-2


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 16
    conv_k: int = 4
    expand: int = 2
    dt_min: float = 1e-3
    dt_max: float = 1e-1
    chunk: int = 128             # selective-scan time chunk


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    slstm_every: int = 2         # every Nth block is sLSTM (others mLSTM)
    proj_factor: float = 2.0     # mLSTM up-projection
    conv_k: int = 4


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    n_enc_layers: int
    enc_seq: int                 # stubbed frontend frames (whisper: 1500)
    enc_bidirectional: bool = True


# ---------------------------------------------------------------------------
# Main config
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0              # 0 -> d_model // n_heads

    # attention flavor
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 1e4
    sliding_window: Optional[int] = None    # None = global attention
    global_every: int = 0        # >0: every Nth layer is global (llama4 iRoPE)
    nope_on_global: bool = False # no RoPE on global layers (llama4)

    # block flavor
    norm_type: str = "rms"       # rms | layer
    parallel_block: bool = False # command-r: attn & mlp in parallel
    tie_embeddings: bool = False
    scan_layers: bool = True     # lax.scan over stacked homogeneous layers

    # stubs / extras
    fusion_tokens: int = 0       # precomputed frontend embeds prepended (vlm/moe-mm)
    meta_tokens: int = 0         # hymba learnable meta tokens

    moe: Optional[MoEConfig] = None
    moe_every: int = 1           # every Nth layer is MoE (llama4: 2)
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    encdec: Optional[EncDecConfig] = None

    dtype: str = "bfloat16"      # activation/param dtype (fp32 accumulate)
    kv_quant: bool = False       # int8 KV cache (per-vector scales)

    # training-time knobs
    remat: str = "block"         # none | block — checkpoint each layer block
    loss_chunk: int = 512        # chunked cross-entropy sequence chunk
    attn_chunk: int = 1024       # blockwise-attention chunk (q and kv)

    # --- derived -----------------------------------------------------------
    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def sub_quadratic(self) -> bool:
        """True iff long-context decode is O(1)/O(window) per token."""
        if self.family in ("ssm",):
            return True
        if self.family == "hybrid":
            return self.sliding_window is not None and self.global_every == 0
        return False

    @property
    def jax_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    def n_params(self) -> int:
        """Analytical parameter count (embedding included once if tied)."""
        d, dh = self.d_model, self.head_dim
        attn = d * (self.n_heads * dh) * 2 + d * (self.n_kv_heads * dh) * 2
        if self.moe is not None:
            ff = 3 * d * self.moe.d_ff_expert
            moe_l = (self.moe.n_experts * ff
                     + self.moe.n_shared * 3 * d * self.d_ff
                     + d * self.moe.n_experts)          # router
            dense_l = 3 * d * self.d_ff
            frac = 1.0 / self.moe_every
            mlp = int(moe_l * frac + dense_l * (1 - frac))
        elif self.d_ff:
            mlp = 3 * d * self.d_ff
        else:
            mlp = 0
        if self.xlstm is not None:
            pf = self.xlstm.proj_factor
            mlp = 0
            attn = int(d * d * pf * 2 + (d * pf) * dh * 3 + d * d * pf)
        if self.ssm is not None and self.family in ("ssm", "hybrid"):
            di = d * self.ssm.expand
            ssm_p = d * 2 * di + di * (self.ssm.d_state * 2 + 2) + di * d
            attn = attn + ssm_p if self.family == "hybrid" else ssm_p
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        layers = self.n_layers
        if self.encdec is not None:
            layers += self.encdec.n_enc_layers
            attn = attn * 2  # cross-attention adds a second attn per dec layer
        return layers * (attn + mlp) + emb

    def n_active_params(self) -> int:
        """Params touched per token (MoE: only routed/shared experts)."""
        if self.moe is None:
            return self.n_params()
        d = self.d_model
        full = self.n_params()
        n_moe_layers = self.n_layers // self.moe_every
        all_experts = n_moe_layers * self.moe.n_experts * 3 * d * self.moe.d_ff_expert
        active = n_moe_layers * self.moe.top_k * 3 * d * self.moe.d_ff_expert
        return full - all_experts + active


# ---------------------------------------------------------------------------
# Input shapes (assigned shape set) + ShapeDtypeStruct stand-ins
# ---------------------------------------------------------------------------

SHAPES = {
    "train_4k": dict(kind="train", seq_len=4096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq_len=32768, global_batch=128),
    "long_500k": dict(kind="decode", seq_len=524288, global_batch=1),
}


def shape_skip_reason(cfg: ModelConfig, shape: str) -> Optional[str]:
    """None if the (arch, shape) cell runs; else reason (recorded in docs)."""
    if shape == "long_500k" and not cfg.sub_quadratic:
        return (
            "full-attention arch: 500k-token decode needs sub-quadratic "
            "attention (DESIGN.md §Arch-applicability)"
        )
    return None


def input_specs(cfg: ModelConfig, shape: str) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    Training: {tokens, labels [, frontend]}.
    Prefill:  {tokens [, frontend]}.
    Decode:   {tokens (B,1), pos (B,)} — the KV cache is built separately via
              serve.init_cache_specs (it is carried state, not an input here).
    """
    meta = SHAPES[shape]
    b, s = meta["global_batch"], meta["seq_len"]
    i32 = jnp.int32
    act = cfg.jax_dtype
    if meta["kind"] == "train":
        specs = {
            "tokens": jax.ShapeDtypeStruct((b, s), i32),
            "labels": jax.ShapeDtypeStruct((b, s), i32),
        }
        if cfg.family in ("vlm",) or (cfg.fusion_tokens and cfg.family == "moe"):
            specs["frontend"] = jax.ShapeDtypeStruct(
                (b, cfg.fusion_tokens, cfg.d_model), act
            )
        if cfg.encdec is not None:
            specs["frontend"] = jax.ShapeDtypeStruct(
                (b, cfg.encdec.enc_seq, cfg.d_model), act
            )
        return specs
    if meta["kind"] == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
        if cfg.fusion_tokens:
            specs["frontend"] = jax.ShapeDtypeStruct(
                (b, cfg.fusion_tokens, cfg.d_model), act
            )
        if cfg.encdec is not None:
            specs["frontend"] = jax.ShapeDtypeStruct(
                (b, cfg.encdec.enc_seq, cfg.d_model), act
            )
        return specs
    # decode: one new token against a cache of seq_len
    return {
        "tokens": jax.ShapeDtypeStruct((b, 1), i32),
        "pos": jax.ShapeDtypeStruct((b,), i32),
    }
