"""command-r-35b [dense] — GQA, no-bias (hf:CohereForAI/c4ai-command-r-v01).
40L d_model=8192 64H (kv=8) d_ff=22528 vocab=256000. Cohere flavor:
LayerNorm (no bias), parallel attention+FFN residual block, tied embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b",
    family="dense",
    n_layers=40,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22528,
    vocab_size=256000,
    norm_type="layer",
    parallel_block=True,
    tie_embeddings=True,
    rope_theta=10_000.0,
)


def smoke_config():
    return ModelConfig(
        name="command-r-35b-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=160,
        vocab_size=128,
        norm_type="layer",
        parallel_block=True,
        tie_embeddings=True,
        dtype="float32",
        loss_chunk=16,
        attn_chunk=64,
    )
