"""hymba-1.5b [hybrid] — parallel attn+mamba heads (arXiv:2411.13676).
32L d_model=1600 25H (kv=5) d_ff=5504 vocab=32001, ssm_state=16.
Hymba signature features: 128 learnable meta tokens (attention sinks) +
sliding-window attention; every layer fuses a SWA attention branch and a
Mamba branch (outputs per-branch normalized then averaged). We use uniform
SWA+meta (Hymba's few global layers folded into the meta-token mechanism;
noted in DESIGN.md) — this keeps long_500k decode O(window) per token.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab_size=32001,
    sliding_window=1024,
    meta_tokens=128,
    ssm=SSMConfig(d_state=16, conv_k=4, expand=2),
    tie_embeddings=True,
)


def smoke_config():
    return ModelConfig(
        name="hymba-1.5b-smoke",
        family="hybrid",
        n_layers=2,
        d_model=40,
        n_heads=5,
        n_kv_heads=1,
        d_ff=96,
        vocab_size=128,
        sliding_window=32,
        meta_tokens=8,
        ssm=SSMConfig(d_state=4, conv_k=4, expand=2, chunk=16),
        tie_embeddings=True,
        dtype="float32",
        loss_chunk=16,
        attn_chunk=64,
    )
