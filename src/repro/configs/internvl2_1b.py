"""internvl2-1b [vlm] — InternViT frontend (stub) + Qwen2-0.5B-family LM
(arXiv:2404.16821). 24L d_model=896 14H (kv=2) d_ff=4864 vocab=151655.
Frontend: input_specs provides 256 precomputed patch embeddings, prepended
(early fusion). Qwen2 LM flavor: QKV bias, RMSNorm, theta=1e6, tied.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    family="vlm",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab_size=151655,
    qkv_bias=True,
    rope_theta=1e6,
    tie_embeddings=True,
    fusion_tokens=256,
)


def smoke_config():
    return ModelConfig(
        name="internvl2-1b-smoke",
        family="vlm",
        n_layers=2,
        d_model=48,
        n_heads=4,
        n_kv_heads=2,
        d_ff=96,
        vocab_size=128,
        qkv_bias=True,
        rope_theta=1e6,
        tie_embeddings=True,
        fusion_tokens=8,
        dtype="float32",
        loss_chunk=16,
        attn_chunk=64,
    )
