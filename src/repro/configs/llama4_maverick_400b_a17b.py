"""llama4-maverick-400b-a17b [moe] — MoE 128e top-1, early fusion
(hf:meta-llama/Llama-4-Maverick flavor). 48L d_model=5120 40H (kv=8)
d_ff=8192 vocab=202048. Llama4 signatures: shared expert + top-1 routed
expert on every *other* layer (interleave_moe_layer_step=2 -> ~400B total,
17B active); iRoPE — 3 chunked-attention layers (approximated as SWA 8192;
DESIGN.md) per 1 global NoPE layer; early-fusion multimodal (stub: 64
precomputed fusion embeddings prepended).
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    d_head=128,
    sliding_window=8192,
    global_every=4,
    nope_on_global=True,
    rope_theta=5e5,
    fusion_tokens=64,
    moe=MoEConfig(n_experts=128, top_k=1, d_ff_expert=8192, n_shared=1),
    moe_every=2,
)


def smoke_config():
    return ModelConfig(
        name="llama4-maverick-smoke",
        family="moe",
        n_layers=4,
        d_model=48,
        n_heads=4,
        n_kv_heads=2,
        d_ff=96,
        vocab_size=128,
        d_head=16,
        sliding_window=32,
        global_every=4,
        nope_on_global=True,
        fusion_tokens=8,
        moe=MoEConfig(n_experts=4, top_k=1, d_ff_expert=96, n_shared=1,
                      capacity_factor=4.0),
        moe_every=2,
        dtype="float32",
        loss_chunk=16,
        attn_chunk=64,
    )
