"""qwen1.5-110b [dense] — QKV bias (hf:Qwen/Qwen1.5-110B flavor).
80L d_model=8192 64H (kv=8) d_ff=49152 vocab=152064. Untied embeddings,
QKV bias, RMSNorm, theta=1e6.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-110b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=49152,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1e6,
)


def smoke_config():
    return ModelConfig(
        name="qwen1.5-110b-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=192,
        vocab_size=128,
        qkv_bias=True,
        rope_theta=1e6,
        dtype="float32",
        loss_chunk=16,
        attn_chunk=64,
    )
