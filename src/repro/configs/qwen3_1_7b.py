"""qwen3-1.7b [dense] — qk_norm, GQA (hf:Qwen/Qwen3-1.7B flavor).
28L d_model=2048 16H (kv=8) d_ff=6144 vocab=151936. head_dim=128, qk-norm,
no QKV bias (dropped in qwen3), theta=1e6, tied embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-1.7b",
    family="dense",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=6144,
    vocab_size=151936,
    d_head=128,
    qk_norm=True,
    rope_theta=1e6,
    tie_embeddings=True,
)


def smoke_config():
    return ModelConfig(
        name="qwen3-1.7b-smoke",
        family="dense",
        n_layers=2,
        d_model=48,
        n_heads=4,
        n_kv_heads=2,
        d_ff=96,
        vocab_size=128,
        d_head=16,
        qk_norm=True,
        rope_theta=1e6,
        tie_embeddings=True,
        dtype="float32",
        loss_chunk=16,
        attn_chunk=64,
    )
