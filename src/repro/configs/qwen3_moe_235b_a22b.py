"""qwen3-moe-235b-a22b [moe] — 128 experts top-8 (hf:Qwen/Qwen3-235B-A22B
flavor). 94L d_model=4096 64H (kv=4) d_ff=1536 (per expert) vocab=151936.
qk-norm, head_dim=128, no shared expert, normalized top-k router weights.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    d_ff=1536,
    vocab_size=151936,
    d_head=128,
    qk_norm=True,
    rope_theta=1e6,
    moe=MoEConfig(n_experts=128, top_k=8, d_ff_expert=1536, n_shared=0),
)


def smoke_config():
    return ModelConfig(
        name="qwen3-moe-smoke",
        family="moe",
        n_layers=2,
        d_model=48,
        n_heads=4,
        n_kv_heads=2,
        d_ff=64,
        vocab_size=128,
        d_head=16,
        qk_norm=True,
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=64, n_shared=0,
                      capacity_factor=4.0),
        dtype="float32",
        loss_chunk=16,
        attn_chunk=64,
    )
