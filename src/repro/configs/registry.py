"""Architecture registry: ``--arch <id>`` -> ModelConfig."""
from __future__ import annotations

import dataclasses
import importlib

ARCH_IDS = [
    "xlstm-125m",
    "internvl2-1b",
    "smollm-360m",
    "command-r-35b",
    "qwen3-1.7b",
    "qwen1.5-110b",
    "whisper-small",
    "hymba-1.5b",
    "llama4-maverick-400b-a17b",
    "qwen3-moe-235b-a22b",
]


def _module(arch_id: str):
    mod = arch_id.replace("-", "_").replace(".", "_")
    return importlib.import_module(f"repro.configs.{mod}")


def get_config(arch_id: str, smoke: bool = False):
    if arch_id not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    m = _module(arch_id)
    return m.smoke_config() if smoke else m.CONFIG


def list_archs():
    return list(ARCH_IDS)
