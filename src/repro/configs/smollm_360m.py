"""smollm-360m [dense] — llama-arch small (hf:HuggingFaceTB/SmolLM-360M).
32L d_model=960 15H (kv=5) d_ff=2560 vocab=49152. Tied embeddings, RMSNorm,
no biases.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m",
    family="dense",
    n_layers=32,
    d_model=960,
    n_heads=15,
    n_kv_heads=5,
    d_ff=2560,
    vocab_size=49152,
    tie_embeddings=True,
)


def smoke_config():
    return ModelConfig(
        name="smollm-360m-smoke",
        family="dense",
        n_layers=2,
        d_model=48,
        n_heads=3,
        n_kv_heads=1,
        d_ff=96,
        vocab_size=128,
        tie_embeddings=True,
        dtype="float32",
        loss_chunk=16,
        attn_chunk=64,
    )
