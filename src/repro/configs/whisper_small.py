"""whisper-small [audio] — enc-dec, conv frontend stubbed (arXiv:2212.04356).
12L (decoder) + 12L encoder, d_model=768 12H (kv=12) d_ff=3072 vocab=51865.
The mel/conv frontend is a STUB: input_specs provides precomputed frame
embeddings (B, 1500, d). LayerNorm+bias as in whisper; RoPE replaces the
decoder's learned positional embedding (TPU-native stand-in; DESIGN.md).
"""
from repro.configs.base import EncDecConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="audio",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,
    norm_type="layer",
    qkv_bias=True,
    tie_embeddings=True,
    encdec=EncDecConfig(n_enc_layers=12, enc_seq=1500),
)


def smoke_config():
    return ModelConfig(
        name="whisper-small-smoke",
        family="audio",
        n_layers=2,
        d_model=48,
        n_heads=4,
        n_kv_heads=4,
        d_ff=96,
        vocab_size=128,
        norm_type="layer",
        qkv_bias=True,
        tie_embeddings=True,
        encdec=EncDecConfig(n_enc_layers=2, enc_seq=24),
        dtype="float32",
        loss_chunk=16,
        attn_chunk=64,
    )
