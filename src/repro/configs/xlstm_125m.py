"""xlstm-125m [ssm] — sLSTM + mLSTM blocks (arXiv:2405.04517).

12L d_model=768 4H (kv=4) d_ff=0 vocab=50304. d_ff=0: xLSTM blocks carry
their own up/down projections (mLSTM pre-up-projection ×2; sLSTM post-FFN
×4/3) — no separate transformer FFN. Blocks alternate [mLSTM, sLSTM]
(slstm_every=2); DESIGN.md notes this 1:1 ratio choice.
"""
from repro.configs.base import ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    tie_embeddings=True,
    xlstm=XLSTMConfig(slstm_every=2, proj_factor=2.0, conv_k=4),
)


def smoke_config():
    return ModelConfig(
        name="xlstm-125m-smoke",
        family="ssm",
        n_layers=4,
        d_model=32,
        n_heads=2,
        n_kv_heads=2,
        d_ff=0,
        vocab_size=128,
        tie_embeddings=True,
        xlstm=XLSTMConfig(slstm_every=2, proj_factor=2.0, conv_k=4),
        dtype="float32",
        loss_chunk=16,
        attn_chunk=64,
    )
