"""Core: the paper's DWConv/PWConv contributions as composable framework ops."""
from repro.core.dwconv import (
    depthwise1d_causal,
    depthwise1d_step,
    depthwise2d,
    init_conv_state,
)
from repro.core.pwconv import DEFAULT_POLICY, KernelPolicy, pointwise
from repro.core.separable import (
    init_inverted_residual,
    init_separable,
    inverted_residual,
    separable_block,
)

__all__ = [
    "DEFAULT_POLICY",
    "KernelPolicy",
    "depthwise1d_causal",
    "depthwise1d_step",
    "depthwise2d",
    "init_conv_state",
    "init_inverted_residual",
    "init_separable",
    "inverted_residual",
    "pointwise",
    "separable_block",
]
