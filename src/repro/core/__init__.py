"""Core: the paper's DWConv/PWConv contributions as composable framework ops,
plus the declarative separable-chain API (spec -> plan -> lower -> execute)."""
from repro.core.chain import (
    DW,
    PW,
    SeparableSpec,
    execute,
    init_chain,
    inverted_residual_spec,
    lower,
    plan,
    separable_block_spec,
)
from repro.core.dwconv import (
    depthwise1d_causal,
    depthwise1d_step,
    depthwise2d,
    init_conv_state,
)
from repro.core.pwconv import DEFAULT_POLICY, KernelPolicy, pointwise
from repro.core.separable import (
    init_inverted_residual,
    init_separable,
    inverted_residual,
    separable_block,
)

__all__ = [
    "DEFAULT_POLICY",
    "DW",
    "KernelPolicy",
    "PW",
    "SeparableSpec",
    "depthwise1d_causal",
    "depthwise1d_step",
    "depthwise2d",
    "execute",
    "init_chain",
    "init_conv_state",
    "init_inverted_residual",
    "init_separable",
    "inverted_residual",
    "inverted_residual_spec",
    "lower",
    "plan",
    "pointwise",
    "separable_block",
    "separable_block_spec",
]
