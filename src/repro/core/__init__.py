"""Core: the paper's DWConv/PWConv contributions as composable framework ops."""
from repro.core.dwconv import (
    depthwise1d_causal,
    depthwise1d_step,
    depthwise2d,
    init_conv_state,
)
from repro.core.pwconv import DEFAULT_POLICY, KernelPolicy, pointwise

__all__ = [
    "DEFAULT_POLICY",
    "KernelPolicy",
    "depthwise1d_causal",
    "depthwise1d_step",
    "depthwise2d",
    "init_conv_state",
    "pointwise",
]
