"""Core: the paper's DWConv/PWConv contributions as composable framework ops,
the declarative separable-chain API (spec -> plan -> lower -> execute), and
the whole-network engine (NetworkSpec -> NetworkPlan -> execute_network)."""
from repro.core.chain import (
    DW,
    PW,
    SeparableSpec,
    execute,
    init_chain,
    inverted_residual_spec,
    lower,
    plan,
    separable_block_spec,
)
from repro.core.dwconv import (
    depthwise1d_causal,
    depthwise1d_step,
    depthwise2d,
    init_conv_state,
)
from repro.core.network import (
    NetworkPlan,
    NetworkSpec,
    cast_network_params,
    execute_network,
    init_network,
    mobilenet_v1_spec,
    mobilenet_v2_spec,
    plan_network,
    tune_network,
)
from repro.core.pwconv import DEFAULT_POLICY, KernelPolicy, pointwise
from repro.core.separable import (
    init_inverted_residual,
    init_separable,
    inverted_residual,
    separable_block,
)
from repro.kernels.policy import BF16_STREAM, DtypePolicy

__all__ = [
    "BF16_STREAM",
    "DEFAULT_POLICY",
    "DW",
    "DtypePolicy",
    "KernelPolicy",
    "NetworkPlan",
    "NetworkSpec",
    "PW",
    "SeparableSpec",
    "cast_network_params",
    "execute_network",
    "init_network",
    "mobilenet_v1_spec",
    "mobilenet_v2_spec",
    "plan_network",
    "tune_network",
    "depthwise1d_causal",
    "depthwise1d_step",
    "depthwise2d",
    "execute",
    "init_chain",
    "init_conv_state",
    "init_inverted_residual",
    "init_separable",
    "inverted_residual",
    "inverted_residual_spec",
    "lower",
    "plan",
    "pointwise",
    "separable_block",
    "separable_block_spec",
]
