"""Declarative separable-chain API: spec -> plan -> lower -> execute.

The paper's whole argument is about orchestrating data movement across the
DW/PW pair; this module makes the *block* — not the op — the schedulable
unit (DESIGN.md §5).  A `SeparableSpec` declares an ordered chain of stages
(`PW` expand, `DW`, `PW` project, optional residual); `plan()` budgets the
whole chain against the policy's VMEM budget and answers with a
`ChainPlan` naming which contiguous stages fuse (and at which block
shapes); `kernels/lowering.lower()` maps that onto kernel passes;
`execute()` runs it.  Fusion is a planner decision, not a user boolean:
the planner fuses the longest run that fits and degrades
3-fused -> 2-fused -> unfused on its own.

The capability this unlocks (ROADMAP): a MobileNetV2 inverted residual
lowers to ONE kernel pass — the expansion GEMM is computed on the fly per
row slab inside the fused kernel, so neither the expanded tensor (6x the
input at the usual expansion factor) nor the DW output ever touches HBM.

    spec = inverted_residual_spec(c_in=32, c_out=32, expand=6)
    params = init_chain(key, spec, c_in=32)
    cp = plan(spec, x.shape)           # ChainPlan: [fused3] at MobileNet shapes
    y = execute(spec, params, x)       # or lower(spec, cp)(params, x)

`separable_block` / `inverted_residual` in ``core/separable.py`` are thin
shims over this API.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core import intensity as it
from repro.kernels import autotune, blocking, lowering
from repro.kernels.blocking import ChainPlan, ChainSegment
from repro.kernels.epilogue import ACTIVATIONS
from repro.kernels.policy import DEFAULT_POLICY, KernelPolicy


# ---------------------------------------------------------------------------
# Spec: the declarative description of a separable block
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PW:
    """Pointwise stage: 1x1 conv / GEMM to ``features`` output channels.

    ``bias=False`` on an *expansion* PW is what makes it eligible for
    3-stage fusion (a biased expansion cannot commute with the zero SAME
    padding the fused kernel applies to the raw input —
    kernels/separable_fused.py).
    """
    features: int
    activation: Optional[str] = None
    bias: bool = False

    def __post_init__(self):
        assert self.activation is None or self.activation in ACTIVATIONS


@dataclasses.dataclass(frozen=True)
class DW:
    """Depthwise stage: ``hf x wf`` spatial conv at the incoming width."""
    stride: int = 1
    activation: Optional[str] = "relu6"
    hf: int = 3
    wf: int = 3
    padding: str = "same"
    bias: bool = False

    def __post_init__(self):
        assert self.activation is None or self.activation in ACTIVATIONS
        assert self.padding.lower() in ("same", "valid"), self.padding

    def out_dims(self, h: int, w: int) -> Tuple[int, int]:
        if self.padding.lower() == "same":
            return -(-h // self.stride), -(-w // self.stride)
        return ((h - self.hf) // self.stride + 1,
                (w - self.wf) // self.stride + 1)


@dataclasses.dataclass(frozen=True)
class SE:
    """Squeeze-excite stage: global-avg-pool -> FC-reduce (``reduce``
    hidden units, ``activation``) -> FC-expand back to the incoming width
    -> sigmoid -> channelwise scale of the stage input.

    ``reduce`` is the explicit reduced width (builders compute it, e.g.
    ``max(1, c_block_input // 4)`` for MnasNet's se_ratio=0.25 counted on
    the *block* input, not the expanded width).  SE stages are always
    biased — both FCs carry a bias vector, per the reference networks.

    Note the sigmoid gate does NOT map 0 -> 0, so SE can never join the
    shared fused-kernel epilogue set (``kernels/epilogue.ACTIVATIONS`` is
    the zero-padding-commuting family); it gets its own lowering paths:
    fused as the ``dw_se`` segment epilogue (padded channels carry zero DW
    output, and 0 * sigmoid(gate) == 0 regardless of the gate), or the
    standalone two-GEMM ``se`` segment.
    """
    reduce: int
    activation: str = "relu"

    def __post_init__(self):
        assert self.reduce >= 1, self.reduce
        assert self.activation in ACTIVATIONS


@dataclasses.dataclass(frozen=True)
class FusedMB:
    """Fused-MBConv stage: a full ``hf x wf`` dense conv straight to
    ``features`` output channels — the EfficientNet-Lite edge block that
    replaces PW-expand + DW with one MXU-shaped convolution.  When followed
    by a PW projection the planner fuses the pair into ONE kernel pass
    (segment kind ``fusedmb``): conv-on-the-fly per row slab, projection
    GEMM accumulating in VMEM, the expanded tensor never touching HBM.
    """
    features: int
    stride: int = 1
    hf: int = 3
    wf: int = 3
    activation: Optional[str] = "relu6"
    padding: str = "same"
    bias: bool = False

    def __post_init__(self):
        assert self.activation is None or self.activation in ACTIVATIONS
        assert self.padding.lower() in ("same", "valid"), self.padding

    def out_dims(self, h: int, w: int) -> Tuple[int, int]:
        if self.padding.lower() == "same":
            return -(-h // self.stride), -(-w // self.stride)
        return ((h - self.hf) // self.stride + 1,
                (w - self.wf) // self.stride + 1)


Stage = Union[PW, DW, SE, FusedMB]


@dataclasses.dataclass(frozen=True)
class SeparableSpec:
    """An ordered chain of PW/DW stages + residual declaration.

    ``residual``: ``False`` (none), ``True`` (always add the chain input to
    the chain output), or ``"auto"`` (add it exactly when shapes allow —
    total stride 1 and c_out == c_in; the MobileNetV2 rule).
    """
    stages: Tuple[Stage, ...]
    residual: Union[bool, str] = False

    def __post_init__(self):
        assert self.stages, "empty chain"
        assert self.residual in (True, False, "auto"), self.residual
        assert all(isinstance(s, (PW, DW, SE, FusedMB))
                   for s in self.stages)

    def out_channels(self, c_in: int) -> int:
        c = c_in
        for s in self.stages:
            if isinstance(s, (PW, FusedMB)):
                c = s.features
        return c

    def stride_product(self) -> int:
        p = 1
        for s in self.stages:
            if isinstance(s, (DW, FusedMB)):
                p *= s.stride
        return p

    def residual_active(self, c_in: int) -> bool:
        if self.residual == "auto":
            return (self.stride_product() == 1
                    and self.out_channels(c_in) == c_in)
        return bool(self.residual)


def separable_block_spec(c_out: int, *, stride: int = 1,
                         activation: str = "relu6",
                         hf: int = 3) -> SeparableSpec:
    """MobileNetV1 separable block: DW(+bias) -> PW(+bias), both activated."""
    return SeparableSpec(stages=(
        DW(stride=stride, activation=activation, hf=hf, wf=hf, bias=True),
        PW(c_out, activation=activation, bias=True),
    ))


def inverted_residual_spec(c_in: int, c_out: int, *, expand: int = 6,
                           stride: int = 1, hf: int = 3) -> SeparableSpec:
    """MobileNetV2 inverted residual: bias-free PW-expand (relu6) -> DW
    (relu6) -> linear PW-project, residual when shapes allow."""
    return SeparableSpec(stages=(
        PW(c_in * expand, activation="relu6"),
        DW(stride=stride, activation="relu6", hf=hf, wf=hf),
        PW(c_out),
    ), residual="auto")


def mbconv_se_spec(c_in: int, c_out: int, *, expand: int = 6,
                   stride: int = 1, hf: int = 3, se_ratio: float = 0.25,
                   activation: str = "relu") -> SeparableSpec:
    """MnasNet-A1 MBConv block with squeeze-excite: bias-free PW-expand ->
    DW -> SE -> linear PW-project, residual when shapes allow.  The SE
    reduced width is ``se_ratio`` of the *block input* width (the MnasNet /
    EfficientNet convention — NOT of the expanded width)."""
    return SeparableSpec(stages=(
        PW(c_in * expand, activation=activation),
        DW(stride=stride, activation=activation, hf=hf, wf=hf),
        SE(max(1, int(c_in * se_ratio))),
        PW(c_out),
    ), residual="auto")


def fused_mbconv_spec(c_in: int, c_out: int, *, expand: int = 6,
                      stride: int = 1, hf: int = 3,
                      activation: str = "relu6") -> SeparableSpec:
    """EfficientNet-Lite fused-MBConv block: a full ``hf x wf`` conv to the
    expanded width -> linear PW-project, residual when shapes allow."""
    return SeparableSpec(stages=(
        FusedMB(c_in * expand, stride=stride, hf=hf, wf=hf,
                activation=activation),
        PW(c_out),
    ), residual="auto")


def init_chain(key, spec: SeparableSpec, c_in: int,
               dtype=jnp.float32) -> list:
    """He-style init for a chain; one params dict per stage, aligned with
    ``spec.stages`` (see kernels/lowering.PARAM_KEYS)."""
    params = []
    c = c_in
    keys = jax.random.split(key, len(spec.stages))
    for k, s in zip(keys, spec.stages):
        if isinstance(s, PW):
            p = {"w": (jax.random.normal(k, (c, s.features), dtype)
                       / jnp.sqrt(c).astype(dtype))}
            if s.bias:
                p["b"] = jnp.zeros((s.features,), dtype)
            c = s.features
        elif isinstance(s, SE):
            k1, k2 = jax.random.split(k)
            p = {"w1": (jax.random.normal(k1, (c, s.reduce), dtype)
                        / jnp.sqrt(c).astype(dtype)),
                 "b1": jnp.zeros((s.reduce,), dtype),
                 "w2": (jax.random.normal(k2, (s.reduce, c), dtype)
                        / jnp.sqrt(s.reduce).astype(dtype)),
                 "b2": jnp.zeros((c,), dtype)}
        elif isinstance(s, FusedMB):
            p = {"f": (jax.random.normal(k, (s.hf, s.wf, c, s.features),
                                         dtype)
                       / jnp.sqrt(s.hf * s.wf * c).astype(dtype))}
            if s.bias:
                p["b"] = jnp.zeros((s.features,), dtype)
            c = s.features
        else:
            p = {"f": (jax.random.normal(k, (s.hf, s.wf, c), dtype)
                       / jnp.sqrt(s.hf * s.wf).astype(dtype))}
            if s.bias:
                p["b"] = jnp.zeros((c,), dtype)
        params.append(p)
    return params


# ---------------------------------------------------------------------------
# plan: budget the whole chain, decide what fuses (DESIGN.md §5)
# ---------------------------------------------------------------------------

def _fusable3(stages: Tuple[Stage, ...], i: int) -> bool:
    """stages[i:i+3] is a (bias-free PW-expand, DW, PW) run."""
    return (i + 2 < len(stages)
            and isinstance(stages[i], PW) and not stages[i].bias
            and isinstance(stages[i + 1], DW)
            and isinstance(stages[i + 2], PW))


def _fusable2(stages: Tuple[Stage, ...], i: int) -> bool:
    """stages[i:i+2] is a (DW, PW) run."""
    return (i + 1 < len(stages)
            and isinstance(stages[i], DW)
            and isinstance(stages[i + 1], PW))


def _fusable_mb(stages: Tuple[Stage, ...], i: int) -> bool:
    """stages[i:i+2] is a (FusedMB, PW) run — the fused-MBConv window."""
    return (i + 1 < len(stages)
            and isinstance(stages[i], FusedMB)
            and isinstance(stages[i + 1], PW))


def _fusable_dw_se(stages: Tuple[Stage, ...], i: int) -> bool:
    """stages[i:i+2] is a (DW, SE) run — the SE-as-epilogue window."""
    return (i + 1 < len(stages)
            and isinstance(stages[i], DW)
            and isinstance(stages[i + 1], SE))


def plan(spec: SeparableSpec, x_shape: Sequence[int], *,
         dtype=jnp.float32,
         policy: KernelPolicy = DEFAULT_POLICY) -> ChainPlan:
    """Budget the whole chain at ``x_shape`` and decide which contiguous
    stages fuse.

    Greedy longest-run-first with per-run VMEM feasibility, degrading
    3-fused -> 2-fused -> unfused: at each position try the 3-stage window
    (bias-free PW-expand -> DW -> PW, ``plan_separable3``), then the
    2-stage window (DW -> PW, ``plan_separable``), else lower a standalone
    stage and move on.  The residual is folded into the final segment's
    kernel when that segment is fused (the kernels' residual operand);
    otherwise it lowers to a separate add.  Deterministic, shape-only
    arithmetic — the returned ChainPlan is a cacheable, comparable unit.

    With ``policy.autotune`` the persistent tune cache
    (``kernels/autotune.py``) is consulted first and a measured winner for
    this exact problem signature wins over the analytic walk; on a cache
    miss this function still answers analytically (measurement needs data
    and happens in :func:`execute`).

    Mixed precision (DESIGN.md §7): all VMEM budgeting happens at the
    policy's STREAM dtype, not the input's native dtype — a bf16-streaming
    policy halves the streamed working set, so the same budget affords
    larger blocks (fewer panels, less input re-fetch).  The returned
    ``ChainPlan.dtype_bytes`` is likewise the stream width, which makes
    :func:`chain_traffic` model the streamed bytes automatically.

    Runtime hardening (DESIGN.md §9): under the default
    ``policy.on_failure == "degrade"`` the persistent plan quarantine is
    consulted (keyed like the tune cache, on the NATIVE input dtype) and
    fusion rungs a previous run failed at on this backend are excluded from
    the walk — the plan degrades at plan time, with zero retries.
    """
    banned: frozenset = frozenset()
    if policy.on_failure == "degrade":
        from repro.runtime import quarantine  # lazy: runtime sits above core
        banned = quarantine.banned_kinds(spec, x_shape, dtype, policy)
    if policy.autotune:
        cached = autotune.lookup_cached_plan(spec, x_shape, dtype, policy)
        if cached is not None:
            return _maybe_verify(spec, cached, x_shape, policy)
    b, h, w, c = x_shape
    dtype = policy.dtype_policy.stream_dtype(dtype)
    stages = spec.stages
    n = len(stages)
    # The residual also needs the spatial dims preserved (a valid-padded DW
    # shrinks them even at stride 1, which the channel/stride rule alone
    # would miss).
    ho_f, wo_f = h, w
    for s in stages:
        if isinstance(s, (DW, FusedMB)):
            ho_f, wo_f = s.out_dims(ho_f, wo_f)
    spatial_ok = (ho_f, wo_f) == (h, w)
    if spec.residual is True and not spatial_ok:
        raise ValueError(
            f"residual=True but the chain maps {h}x{w} -> {ho_f}x{wo_f}")
    res_active = spec.residual_active(c) and spatial_ok
    allowed = policy.fusion_allowed
    budget = policy.vmem_budget
    nb = blocking.dtype_bytes(dtype)

    segments: list = []
    i = 0
    while i < n:
        s = stages[i]
        if allowed and "fused3" not in banned and _fusable3(stages, i):
            d, proj = stages[i + 1], stages[i + 2]
            ho, wo = d.out_dims(h, w)
            with_res = res_active and i + 3 == n
            p3 = blocking.plan_separable3(
                ho, wo, c, stages[i].features, proj.features,
                stride=d.stride, hf=d.hf, wf=d.wf, dtype=dtype,
                vmem_budget=budget, residual=with_res)
            if p3 is not None:
                segments.append(ChainSegment("fused3", (i, i + 1, i + 2), p3))
                h, w, c = ho, wo, proj.features
                i += 3
                continue
        if allowed and "fusedmb" not in banned and _fusable_mb(stages, i):
            mb, proj = stages[i], stages[i + 1]
            ho, wo = mb.out_dims(h, w)
            with_res = res_active and i + 2 == n
            pmb = blocking.plan_fused_mb(
                ho, wo, c, mb.features, proj.features, stride=mb.stride,
                hf=mb.hf, wf=mb.wf, dtype=dtype, vmem_budget=budget,
                residual=with_res)
            if pmb is not None:
                segments.append(ChainSegment("fusedmb", (i, i + 1), pmb))
                h, w, c = ho, wo, proj.features
                i += 2
                continue
        if allowed and "fused2" not in banned and _fusable2(stages, i):
            d, proj = stages[i], stages[i + 1]
            ho, wo = d.out_dims(h, w)
            with_res = res_active and i + 2 == n
            p2 = blocking.plan_separable(
                ho, wo, c, proj.features, stride=d.stride, hf=d.hf,
                wf=d.wf, dtype=dtype, vmem_budget=budget,
                residual=with_res)
            if p2 is not None:
                segments.append(ChainSegment("fused2", (i, i + 1), p2))
                h, w, c = ho, wo, proj.features
                i += 2
                continue
        if allowed and "dw_se" not in banned and _fusable_dw_se(stages, i):
            d, se = stages[i], stages[i + 1]
            ho, wo = d.out_dims(h, w)
            hi_v = (ho - 1) * d.stride + d.hf
            wi_v = (wo - 1) * d.stride + d.wf
            pse = blocking.plan_dw_se(
                hi_v, wi_v, ho, wo, c, se.reduce, d.hf, d.wf,
                dtype=dtype, vmem_budget=budget)
            if pse is not None:
                segments.append(ChainSegment("dw_se", (i, i + 1), pse))
                h, w = ho, wo
                i += 2
                continue
        if isinstance(s, PW):
            pp = blocking.plan_pwconv(b * h * w, c, s.features, dtype=dtype,
                                      vmem_budget=budget)
            segments.append(ChainSegment("pw", (i,), pp))
            c = s.features
        elif isinstance(s, SE):
            segments.append(ChainSegment("se", (i,), blocking.plan_se(
                b, c, s.reduce, dtype=dtype, vmem_budget=budget)))
        elif isinstance(s, FusedMB):
            ho, wo = s.out_dims(h, w)
            segments.append(ChainSegment("mb", (i,), blocking.plan_mb(
                ho, wo, c, s.features, s.hf, s.wf, stride=s.stride,
                dtype=dtype, vmem_budget=budget)))
            h, w, c = ho, wo, s.features
        else:
            ho, wo = s.out_dims(h, w)
            hi_v = (ho - 1) * s.stride + s.hf
            wi_v = (wo - 1) * s.stride + s.wf
            dp = blocking.plan_dwconv2d(hi_v, wi_v, ho, wo, c, s.hf, s.wf,
                                        dtype=dtype, vmem_budget=budget)
            segments.append(ChainSegment("dw", (i,), dp))
            h, w = ho, wo
        i += 1

    residual_fused = bool(
        res_active and segments
        and segments[-1].kind in blocking.FUSED_KINDS)
    cp = ChainPlan(
        segments=tuple(segments),
        residual=res_active,
        residual_fused=residual_fused,
        dtype_bytes=nb,
        vmem_budget=budget,
    )
    return _maybe_verify(spec, cp, x_shape, policy)


def _maybe_verify(spec: SeparableSpec, cp: ChainPlan, x_shape,
                  policy: KernelPolicy) -> ChainPlan:
    """The ``policy.verify`` debug knob (DESIGN.md §8): run the static
    analyzer (planlint + mosaic rules — the cheap, trace-free passes) on
    the resolved plan and raise on any error diagnostic.  Lazy import:
    the analysis layer imports this module's consumers."""
    if policy.verify:
        from repro import analysis
        analysis.verify_or_raise(analysis.analyze_chain(
            spec, cp, x_shape, policy=policy, jaxpr=False))
    return cp


# ---------------------------------------------------------------------------
# lower / execute
# ---------------------------------------------------------------------------

#: Re-export: lowering lives at the kernel layer (kernels/lowering.py).
lower = lowering.lower


def resolve_plan(spec: SeparableSpec, params: Sequence[dict], x: jax.Array,
                 *, policy: KernelPolicy = DEFAULT_POLICY,
                 chain_plan: Optional[ChainPlan] = None) -> ChainPlan:
    """The plan :func:`execute` runs: the explicitly supplied plan
    (verified), the measured autotune winner (tune-on-first-execute on a
    miss), or the analytic :func:`plan` — exactly the resolution order of
    the raw execute path, factored out so the runtime executor
    (``repro.runtime.executor``) shares it verbatim."""
    if chain_plan is None:
        if policy.autotune:
            base = plan(spec, x.shape, dtype=x.dtype,
                        policy=dataclasses.replace(policy, autotune=False))
            return _maybe_verify(
                spec, autotune.autotune_chain(
                    spec, params, x, policy=policy, base_plan=base).plan,
                x.shape, policy)
        return plan(spec, x.shape, dtype=x.dtype, policy=policy)
    # an explicitly supplied plan bypasses plan() — verify it here so
    # the debug knob also gates hand-built / deserialized plans
    _maybe_verify(spec, chain_plan, x.shape, policy)
    return chain_plan


def execute(spec: SeparableSpec, params: Sequence[dict], x: jax.Array, *,
            policy: KernelPolicy = DEFAULT_POLICY,
            chain_plan: Optional[ChainPlan] = None) -> jax.Array:
    """Run the chain: plan (unless given), lower, execute.

    With ``policy.autotune`` the plan is the MEASURED winner from
    ``kernels/autotune.py``: the first call for a given problem signature
    times the candidate ladder and persists the winner; every later call
    (including in other processes) replays the cached plan with zero
    re-measurement.  Cache miss with tuning disabled — or tuning disabled
    outright — falls back to the analytic planner.

    Under the default ``policy.on_failure == "degrade"`` (or with
    ``policy.numeric_guard``) execution routes through the runtime
    degradation ladder (``repro.runtime.executor``, DESIGN.md §9): the
    steady-state path is identical — same plan resolution, same lowering,
    bitwise-identical outputs — plus a try/except; a classified backend
    failure quarantines the failing rung and retries one rung down.
    """
    if policy.on_failure == "degrade" or policy.numeric_guard:
        from repro.runtime import executor  # lazy: runtime sits above core
        return executor.execute_chain(spec, params, x, policy=policy,
                                      chain_plan=chain_plan)
    cp = resolve_plan(spec, params, x, policy=policy, chain_plan=chain_plan)
    return lower(spec, cp, policy)(params, x)


# ---------------------------------------------------------------------------
# ChainPlan traffic model (core/intensity.py per-segment terms)
# ---------------------------------------------------------------------------

def chain_traffic(spec: SeparableSpec, chain_plan: ChainPlan,
                  x_shape: Sequence[int], *,
                  dtype_bytes: Optional[int] = None) -> "it.Traffic":
    """Modeled HBM traffic + FLOPs of the planned chain: the sum of each
    segment's kernel-level model (``core/intensity.py``), plus the separate
    residual add when it is not folded into a fused pass, plus the
    standalone-DW bias/activation epilogue (``apply_epilogue`` in
    ``kernels/lowering.py`` is a separate elementwise op that reads and
    re-writes the whole ``(B,Ho,Wo,C)`` tensor — fused segments apply it
    inside the kernel for free).  This is the table the benchmark gate
    prints per block (3-stage fused vs 2-stage fused vs unfused)."""
    nb = dtype_bytes or chain_plan.dtype_bytes
    b, h, w, c = x_shape
    stages = spec.stages
    flops = 0.0
    bytes_ = 0.0
    for seg in chain_plan.segments:
        if seg.kind == "fused3":
            d, proj = stages[seg.stages[1]], stages[seg.stages[2]]
            ho, wo = d.out_dims(h, w)
            hi_v = (ho - 1) * d.stride + d.hf
            wi_v = (wo - 1) * d.stride + d.wf
            t = it.separable_traffic_fused3(
                b, hi_v, wi_v, c, stages[seg.stages[0]].features,
                proj.features, d.hf, d.wf, d.stride,
                block_co=seg.plan.block_co, slab_h=seg.plan.slab_h,
                dtype_bytes=nb)
            h, w, c = ho, wo, proj.features
        elif seg.kind == "fused2":
            d, proj = stages[seg.stages[0]], stages[seg.stages[1]]
            ho, wo = d.out_dims(h, w)
            hi_v = (ho - 1) * d.stride + d.hf
            wi_v = (wo - 1) * d.stride + d.wf
            t = it.separable_traffic_fused(
                b, hi_v, wi_v, c, proj.features, d.hf, d.wf, d.stride,
                block_co=seg.plan.block_co, slab_h=seg.plan.slab_h,
                dtype_bytes=nb)
            h, w, c = ho, wo, proj.features
        elif seg.kind == "fusedmb":
            mb, proj = stages[seg.stages[0]], stages[seg.stages[1]]
            ho, wo = mb.out_dims(h, w)
            hi_v = (ho - 1) * mb.stride + mb.hf
            wi_v = (wo - 1) * mb.stride + mb.wf
            t = it.fused_mb_traffic(
                b, hi_v, wi_v, c, mb.features, proj.features, mb.hf,
                mb.wf, mb.stride, block_co=seg.plan.block_co,
                slab_h=seg.plan.slab_h, dtype_bytes=nb)
            h, w, c = ho, wo, proj.features
        elif seg.kind == "dw_se":
            d, se = stages[seg.stages[0]], stages[seg.stages[1]]
            ho, wo = d.out_dims(h, w)
            hi_v = (ho - 1) * d.stride + d.hf
            wi_v = (wo - 1) * d.stride + d.wf
            t = it.dw_se_traffic(b, hi_v, wi_v, c, se.reduce, d.hf, d.wf,
                                 d.stride, dtype_bytes=nb)
            h, w = ho, wo
        elif seg.kind == "se":
            se = stages[seg.stages[0]]
            t = it.se_traffic(b, h, w, c, se.reduce, dtype_bytes=nb)
        elif seg.kind == "mb":
            mb = stages[seg.stages[0]]
            ho, wo = mb.out_dims(h, w)
            t = it.mb_traffic(b, h, w, c, mb.features, mb.hf, mb.wf,
                              mb.stride, dtype_bytes=nb)
            h, w, c = ho, wo, mb.features
        elif seg.kind == "pw":
            st = stages[seg.stages[0]]
            t = it.pwconv_traffic_rtrd(
                b * h * w, c, st.features, seg.plan.block_g,
                seg.plan.block_c, seg.plan.block_co, dtype_bytes=nb)
            c = st.features
        else:
            st = stages[seg.stages[0]]
            ho, wo = st.out_dims(h, w)
            hi_v = (ho - 1) * st.stride + st.hf
            wi_v = (wo - 1) * st.stride + st.wf
            t = it.dwconv2d_traffic(b, hi_v, wi_v, c, st.hf, st.wf,
                                    st.stride, dtype_bytes=nb)
            if st.bias or st.activation is not None:
                # standalone-DW epilogue: a separate elementwise op in the
                # lowering that re-reads and re-writes the whole output
                # tensor (+ the bias vector); XLA elides it when there is
                # neither bias nor activation, so only count it then
                epi = nb * (2 * b * ho * wo * c + (c if st.bias else 0))
                t = it.Traffic(t.flops + b * ho * wo * c,
                               t.bytes_hbm + epi)
            h, w = ho, wo
        flops += t.flops
        bytes_ += t.bytes_hbm
    if chain_plan.residual:
        if chain_plan.residual_fused:
            # the kernel streams the residual operand once; the accumulate
            # and store are already inside the fused pass
            bytes_ += nb * b * h * w * c
        else:
            # separate elementwise add: read both operands, write the sum
            bytes_ += nb * 3 * b * h * w * c
        flops += b * h * w * c
    return it.Traffic(flops, bytes_)
