"""DWConv — the paper's depthwise-convolution contribution as a framework op.

Two entry points, matching where depthwise convolution appears in practice:

* :func:`depthwise2d` — NHWC spatial DWConv (MobileNet/MnasNet workloads,
  conv frontends).
* :func:`depthwise1d_causal` — causal sequence DWConv (Mamba/Hymba heads,
  xLSTM conv preactivation) + :func:`depthwise1d_step` for decode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.pwconv import DEFAULT_POLICY, KernelPolicy
from repro.kernels import ops, ref


def depthwise2d(
    x: jax.Array,
    f: jax.Array,
    *,
    stride: int = 1,
    padding: str = "same",
    policy: KernelPolicy = DEFAULT_POLICY,
) -> jax.Array:
    """x (B, H, W, C) * f (Hf, Wf, C) -> (B, Ho, Wo, C)."""
    return ops.dwconv2d(
        x, f, stride=stride, padding=padding,
        impl=policy.impl, interpret=policy.interpret,
    )


def depthwise1d_causal(
    x: jax.Array,
    f: jax.Array,
    *,
    policy: KernelPolicy = DEFAULT_POLICY,
) -> jax.Array:
    """x (B, L, D) * f (K, D) -> (B, L, D), causal."""
    return ops.dwconv1d_causal(
        x, f, impl=policy.impl, interpret=policy.interpret
    )


def depthwise1d_step(
    state: jax.Array, x_t: jax.Array, f: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Single-token decode step; state (B, K-1, D) of past inputs."""
    return ref.dwconv1d_step_ref(state, x_t, f)


def init_conv_state(batch: int, k: int, d: int, dtype=jnp.float32) -> jax.Array:
    return jnp.zeros((batch, max(k - 1, 1), d), dtype)
