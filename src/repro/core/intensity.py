"""Analytical arithmetic-intensity (AI) / operational-intensity (OI) models.

Every equation in the paper, implemented exactly, plus the TPU translation.
These are the quantitative claims the reproduction validates
(tests/test_intensity.py) and the analytical layer the benchmark harness and
EXPERIMENTS.md report from.

Paper notation (fp32, 16-byte SIMD registers, FMA = 2 flops/lane · 4 lanes):

* ``T_tf_dw``    — TF-Lite DWConv AI  (paper: 1/8, or < 1/6 with the
                   benefit-of-the-doubt filter-in-register variant).
* ``T_ours_dw``  — paper Alg. 4 DWConv AI, eq. (1); ≥ 9/22 for 3×3 filters.
* ``T_rtra_pw``  — BLAS GEMM kernel (A-stationary) AI = 4/(3 + 8/Co).
* ``T_rtrd_pw``  — paper Alg. 6 (output-stationary) AI = 2/(1 + 8/Ci).

TPU translation: identical ratio structure with "bytes" = HBM↔VMEM traffic of
one pallas_call and tile sizes = BlockSpec tiles. Reported per-layer by
``benchmarks/``.
"""
from __future__ import annotations

import dataclasses
import math

FMA_FLOPS_PER_LANE = 2  # multiply + add
SIMD_LANES = 4          # 128-bit NEON / fp32
SIMD_BYTES = 16


# ---------------------------------------------------------------------------
# Paper equations (ARM level)
# ---------------------------------------------------------------------------

def t_tf_dw(w_ob: int | None = None) -> float:
    """TF-Lite DWConv AI. Plain: 1/8. With filter kept in registers across the
    kk loop (benefit of the doubt): 1/((3 + 1/W_ob) * 2) < 1/6."""
    if w_ob is None:
        return (FMA_FLOPS_PER_LANE * SIMD_LANES) / (4 * SIMD_BYTES)  # = 1/8
    return 1.0 / ((3.0 + 1.0 / w_ob) * 2.0)


def t_ours_dw(hf: int, wf: int, h_ob: int, w_ob: int, ho: int, wo: int) -> float:
    """Paper eq. (1): AI of Alg. 4.

    W = H_ob*W_ob*Hf*Wf FMA ops -> 8W flops. Traffic: amortized filter load +
    output load+store once + input stream (16 bytes per FMA).
    """
    w_work = h_ob * w_ob * hf * wf
    filt = (hf * wf) / ((ho / h_ob) * (wo / w_ob))
    out = h_ob * w_ob * 2
    return (8.0 * w_work) / (16.0 * (filt + out + w_work))


def t_ours_dw_asymptotic(hf: int, wf: int) -> float:
    """Paper's simplification: T = Hf*Wf / ((2 + Hf*Wf) * 2)   (>= 9/22 for 3x3)."""
    return (hf * wf) / ((2.0 + hf * wf) * 2.0)


def t_rtra_pw(g_b: int = 8, ci_b: int = 8, co_b: int = 4, co: int = 1024) -> float:
    """BLAS RTRA kernel AI (paper): D streamed twice per reduction block."""
    flops = 2.0 * g_b * ci_b * co_b
    bytes_ = (g_b * co_b * 2 + ci_b * co_b + (g_b * ci_b) / (co / co_b)) * 4.0
    return flops / bytes_


def t_rtrd_pw(g_b: int = 8, co_b: int = 8, ci_b: int = 4, ci: int = 1024) -> float:
    """Paper RTRD kernel AI: D resident across the whole Ci reduction."""
    flops = 2.0 * g_b * ci_b * co_b
    bytes_ = (g_b * ci_b + ci_b * co_b + (g_b * co_b * 2) / (ci / ci_b)) * 4.0
    return flops / bytes_


# ---------------------------------------------------------------------------
# TPU (VMEM-level) translation — same ratios, BlockSpec tiles, HBM traffic.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Traffic:
    """FLOPs and HBM<->VMEM bytes of one kernel invocation."""
    flops: float
    bytes_hbm: float

    @property
    def intensity(self) -> float:
        return self.flops / max(self.bytes_hbm, 1.0)

    def time_s(self, peak_flops: float, hbm_bw: float) -> tuple[float, float]:
        """(compute_s, memory_s) roofline terms for this kernel."""
        return self.flops / peak_flops, self.bytes_hbm / hbm_bw


def dwconv2d_traffic(
    b: int, hi: int, wi: int, c: int, hf: int, wf: int, stride: int,
    dtype_bytes: int = 4,
) -> Traffic:
    """Our dwconv2d kernel: input read once, filter once, output stored once —
    the information floor (paper's store-once / filter-stationary design)."""
    ho = (hi - hf) // stride + 1
    wo = (wi - wf) // stride + 1
    flops = 2.0 * b * ho * wo * c * hf * wf
    bytes_ = dtype_bytes * (b * hi * wi * c + hf * wf * c + b * ho * wo * c)
    return Traffic(flops, bytes_)


def dwconv2d_traffic_rowpar(
    b: int, hi: int, wi: int, c: int, hf: int, wf: int, stride: int,
    p: int, l1_bytes: int = 32 * 1024, dtype_bytes: int = 4,
) -> Traffic:
    """TF-Lite-style row-parallel partitioning at p cores: every core re-reads
    the WHOLE filter (Hf*Wf*C) and halo rows; models the paper's core-
    inscalability argument for the fig-7 scalability benchmark."""
    ho = (hi - hf) // stride + 1
    wo = (wi - wf) // stride + 1
    flops = 2.0 * b * ho * wo * c * hf * wf
    halo_rows = (hf - stride) if hf > stride else 0
    bytes_ = dtype_bytes * (
        b * hi * wi * c                      # input
        + b * p * halo_rows * wi * c         # halo re-reads at p chunk seams
        + p * hf * wf * c                    # filter replicated in every L1
        + b * ho * wo * c                    # output
    )
    # L1 thrash: when a core's filter + filter-support rows exceed its L1,
    # filter and input rows evict each other, so the filter is re-fetched per
    # output row and each input row is touched once per filter row instead of
    # once (the paper's "cache misses fly high" regime; worsens with p since
    # all cores hold the FULL filter).
    ws = (hf * wf * c + hf * wi * c) * dtype_bytes
    if ws > l1_bytes:
        bytes_ += dtype_bytes * b * (ho - 1) * hf * wf * c
        bytes_ += dtype_bytes * b * hi * wi * c * (hf - 1)
    return Traffic(flops, bytes_)


def pwconv_traffic_rtrd(
    g: int, ci: int, co: int, bg: int, bci: int, bco: int,
    dtype_bytes: int = 4,
) -> Traffic:
    """Our output-stationary GEMM: A re-read per Co panel, B re-read per G
    panel, D written once (never re-read)."""
    flops = 2.0 * g * ci * co
    n_jpanels = math.ceil(co / bco)
    n_gpanels = math.ceil(g / bg)
    bytes_ = dtype_bytes * (
        g * ci * n_jpanels      # A streamed once per output column panel
        + ci * co * n_gpanels   # B streamed once per output row panel
        + g * co                # D stored once  <- the RTRD win
    )
    return Traffic(flops, bytes_)


def separable_traffic_unfused(
    b: int, hi: int, wi: int, c: int, co: int, hf: int, wf: int, stride: int,
    bg: int = 256, bci: int = 256, bco: int = 256, dtype_bytes: int = 4,
) -> Traffic:
    """Depthwise-separable block as two standalone kernels: the DW output
    (B*Ho*Wo*C) is stored to HBM by dwconv2d and re-read by pwconv once per
    Co panel — the intermediate round-trip the fused kernel removes."""
    dw = dwconv2d_traffic(b, hi, wi, c, hf, wf, stride, dtype_bytes)
    ho = (hi - hf) // stride + 1
    wo = (wi - wf) // stride + 1
    pw = pwconv_traffic_rtrd(b * ho * wo, c, co, bg, bci, bco, dtype_bytes)
    return Traffic(dw.flops + pw.flops, dw.bytes_hbm + pw.bytes_hbm)


def separable_slab_halo_bytes(
    b: int, wi: int, c: int, hf: int, stride: int, n_slabs: int,
    n_co_panels: int = 1, dtype_bytes: int = 4,
) -> float:
    """The price of row-slab blocking: input rows re-fetched at slab seams.

    Adjacent slabs' input windows overlap by ``max(Hf - stride, 0)`` rows,
    so each of the ``n_slabs - 1`` interior seams re-reads that many rows of
    ``Wi x C`` input — per Co panel, since the input is streamed once per
    panel. Zero when unslabbed (n_slabs == 1) or when stride >= Hf (the
    windows are disjoint)."""
    halo = max(hf - stride, 0)
    return float(dtype_bytes * n_co_panels * b * (n_slabs - 1) * halo
                 * wi * c)


def separable_traffic_fused(
    b: int, hi: int, wi: int, c: int, co: int, hf: int, wf: int, stride: int,
    block_co: int | None = None, slab_h: int | None = None,
    dtype_bytes: int = 4,
) -> Traffic:
    """Fused DW+PW kernel (kernels/separable_fused.py): the DW output exists
    only in VMEM. Input streamed once per Co panel (recompute instead of
    round-trip), PW weight once per (batch, slab) row-panel, output stored
    once. With a single Co panel (the planner's preferred case) this is
    exactly the unfused traffic minus the intermediate store + re-read.

    ``slab_h`` models the row-slab grid dimension (BlockPlan.slab_h): each
    slab fetches its ``(slab_h-1)*stride + Hf``-row input window, so
    adjacent slabs re-read a halo counted explicitly by
    :func:`separable_slab_halo_bytes`; the filter tile is re-fetched per
    slab and the PW weight is re-streamed per slab (the accumulator now
    spans one slab, not the whole image). Slabbing moves NO extra flops —
    every output row is computed exactly once."""
    ho = (hi - hf) // stride + 1
    wo = (wi - wf) // stride + 1
    n_co = math.ceil(co / (block_co or co))
    n_slabs = math.ceil(ho / slab_h) if slab_h else 1
    flops = (n_co * 2.0 * b * ho * wo * c * hf * wf  # DW recomputed per panel
             + 2.0 * b * ho * wo * c * co)           # PW stage
    bytes_ = dtype_bytes * (
        n_co * b * hi * wi * c                # input slab, once per Co panel
        + n_co * n_slabs * b * hf * wf * c    # DW filter tile per grid cell
        + n_slabs * b * c * co                # PW weight per (batch, slab)
        + b * ho * wo * co                    # output stored once
        # intermediate term: 0 — never leaves VMEM (DESIGN.md §3)
    ) + separable_slab_halo_bytes(b, wi, c, hf, stride, n_slabs, n_co,
                                  dtype_bytes)
    return Traffic(flops, bytes_)


def separable_traffic_fused3(
    b: int, hi: int, wi: int, ci: int, c: int, co: int,
    hf: int, wf: int, stride: int,
    block_co: int | None = None, slab_h: int | None = None,
    dtype_bytes: int = 4,
) -> Traffic:
    """3-stage fused chain (PW-expand -> DW -> PW-project in ONE kernel
    pass, kernels/separable_fused.py with ``expand_w``): the expansion GEMM
    is computed on the fly per row slab, so neither the EXPANDED tensor
    (``B*Hi*Wi*C`` — 6x the input at MobileNetV2's expansion factor) nor
    the DW output ever exists in HBM.

    ``ci`` is the raw-input width, ``c`` the expanded (DW) width, ``co``
    the projected width.  Streams: RAW input once per Co panel (at ``ci``
    channels — cheaper than the 2-stage kernel's expanded-width stream),
    expand weight + DW filter per grid cell, project weight per
    (batch, slab), output once.  The expand GEMM and DW compute are
    replayed per Co panel (recompute instead of round-trip); the slab-seam
    halo re-read is counted at ``ci`` channels.  Expansion recompute of
    halo rows moves negligible extra flops and is excluded (the model
    counts each expanded pixel once per Co panel)."""
    ho = (hi - hf) // stride + 1
    wo = (wi - wf) // stride + 1
    n_co = math.ceil(co / (block_co or co))
    n_slabs = math.ceil(ho / slab_h) if slab_h else 1
    flops = (n_co * 2.0 * b * hi * wi * ci * c    # expand GEMM per Co panel
             + n_co * 2.0 * b * ho * wo * c * hf * wf  # DW per Co panel
             + 2.0 * b * ho * wo * c * co)             # PW-project stage
    bytes_ = dtype_bytes * (
        n_co * b * hi * wi * ci               # RAW input, once per Co panel
        + n_co * n_slabs * b * ci * c         # expand W tile per grid cell
        + n_co * n_slabs * b * hf * wf * c    # DW filter tile per grid cell
        + n_slabs * b * c * co                # project W per (batch, slab)
        + b * ho * wo * co                    # output stored once
        # expanded + DW intermediates: 0 — never leave VMEM (DESIGN.md §5)
    ) + separable_slab_halo_bytes(b, wi, ci, hf, stride, n_slabs, n_co,
                                  dtype_bytes)
    return Traffic(flops, bytes_)


def fused_mb_traffic(
    b: int, hi: int, wi: int, ci: int, c: int, co: int,
    hf: int, wf: int, stride: int,
    block_co: int | None = None, slab_h: int | None = None,
    dtype_bytes: int = 4,
) -> Traffic:
    """Fused-MBConv kernel (kernels/fused_mbconv.py): full ``hf x wf`` conv
    -> act -> PW-project in ONE pass.  ``ci`` is the raw-input width, ``c``
    the conv-output (expanded) width, ``co`` the projected width.  Streams:
    raw input once per Co panel, the dense conv filter per grid cell, the
    project weight per (batch, slab), output once — the expanded tensor
    (``B*Ho*Wo*C``) never exists in HBM.  The conv compute is replayed per
    Co panel (recompute instead of round-trip)."""
    ho = (hi - hf) // stride + 1
    wo = (wi - wf) // stride + 1
    n_co = math.ceil(co / (block_co or co))
    n_slabs = math.ceil(ho / slab_h) if slab_h else 1
    flops = (n_co * 2.0 * b * ho * wo * ci * c * hf * wf  # conv per Co panel
             + 2.0 * b * ho * wo * c * co)                # PW-project stage
    bytes_ = dtype_bytes * (
        n_co * b * hi * wi * ci               # RAW input, once per Co panel
        + n_co * n_slabs * b * hf * wf * ci * c  # conv filter per grid cell
        + n_slabs * b * c * co                # project W per (batch, slab)
        + b * ho * wo * co                    # output stored once
        # conv intermediate: 0 — never leaves VMEM (DESIGN.md §10)
    ) + separable_slab_halo_bytes(b, wi, ci, hf, stride, n_slabs, n_co,
                                  dtype_bytes)
    return Traffic(flops, bytes_)


def mb_traffic(
    b: int, h: int, w: int, ci: int, c: int, hf: int, wf: int, stride: int,
    dtype_bytes: int = 4,
) -> Traffic:
    """Standalone dense conv (the fused-MBConv degradation target,
    XLA-lowered): input read once, filter once, output stored once.
    ``h, w`` are the UNPADDED input dims (SAME geometry)."""
    ho, wo = -(-h // stride), -(-w // stride)
    flops = 2.0 * b * ho * wo * ci * c * hf * wf
    bytes_ = dtype_bytes * (b * h * w * ci + hf * wf * ci * c
                            + b * ho * wo * c)
    return Traffic(flops, bytes_)


def se_traffic(
    b: int, h: int, w: int, c: int, c_se: int,
    dtype_bytes: int = 4,
) -> Traffic:
    """Standalone squeeze-excite pass: the input tensor is read by the
    global pool, read AGAIN by the channelwise scale, and the scaled
    result stored — two reads + one write of ``B*H*W*C`` purely to apply
    two tiny FCs over the spatial mean (the round-trip the fused ``dw_se``
    segment removes).  Gate FLOPs: pool + two FCs + sigmoid + scale."""
    flops = (b * h * w * c                  # pool accumulation
             + 2.0 * b * c * c_se * 2      # the two FCs
             + 4.0 * b * c                  # sigmoid (approx)
             + b * h * w * c)               # the scale
    bytes_ = dtype_bytes * (
        3 * b * h * w * c                   # pool read + scale read + store
        + 2 * c * c_se + c_se + c           # gate weights + biases
    )
    return Traffic(flops, bytes_)


def dw_se_traffic(
    b: int, hi: int, wi: int, c: int, c_se: int, hf: int, wf: int,
    stride: int, dtype_bytes: int = 4,
) -> Traffic:
    """Fused DW + SE-epilogue kernel (kernels/se_epilogue.py): the DW
    output stays VMEM-resident through the pool, the gate FCs and the
    scale, and is stored exactly once, already scaled — vs the standalone
    composition's store + two re-reads (:func:`se_traffic`).  Input read
    once, DW filter + gate weights once; full-channel single-slab
    residency means no panel or halo re-reads at all."""
    ho = (hi - hf) // stride + 1
    wo = (wi - wf) // stride + 1
    flops = (2.0 * b * ho * wo * c * hf * wf    # DW
             + b * ho * wo * c                   # pool
             + 2.0 * b * c * c_se * 2           # the two FCs
             + 4.0 * b * c                       # sigmoid (approx)
             + b * ho * wo * c)                  # the scale
    bytes_ = dtype_bytes * (
        b * hi * wi * c                          # input read once
        + hf * wf * c                            # DW filter
        + 2 * c * c_se + c_se + c                # gate weights + biases
        + b * ho * wo * c                        # output stored once
        # DW intermediate + gate: 0 — never leave VMEM (DESIGN.md §10)
    )
    return Traffic(flops, bytes_)


def separable_traffic_2stage(
    b: int, h: int, w: int, ci: int, c: int, co: int,
    hf: int, wf: int, stride: int,
    block_co: int | None = None, slab_h: int | None = None,
    bg: int = 256, bci: int = 256, bco: int = 256,
    dtype_bytes: int = 4,
) -> Traffic:
    """The PR-2 lowering of an inverted residual: standalone expansion GEMM
    (RTRD) whose ``B*H*W*C`` output round-trips HBM, then the 2-stage fused
    DW -> PW kernel.  ``h, w`` are the UNPADDED input dims (the expansion
    runs pre-padding); the fused stage sees the SAME-padded geometry."""
    ho, wo = -(-h // stride), -(-w // stride)
    hi = (ho - 1) * stride + hf
    wi = (wo - 1) * stride + wf
    expand = pwconv_traffic_rtrd(b * h * w, ci, c, bg, bci, bco, dtype_bytes)
    tail = separable_traffic_fused(b, hi, wi, c, co, hf, wf, stride,
                                   block_co=block_co, slab_h=slab_h,
                                   dtype_bytes=dtype_bytes)
    return Traffic(expand.flops + tail.flops,
                   expand.bytes_hbm + tail.bytes_hbm)


def separable_traffic_unfused3(
    b: int, h: int, w: int, ci: int, c: int, co: int,
    hf: int, wf: int, stride: int,
    bg: int = 256, bci: int = 256, bco: int = 256,
    dtype_bytes: int = 4,
) -> Traffic:
    """Fully unfused inverted residual: expansion GEMM + standalone DW +
    standalone PW-project, every intermediate round-tripping HBM."""
    ho, wo = -(-h // stride), -(-w // stride)
    hi = (ho - 1) * stride + hf
    wi = (wo - 1) * stride + wf
    expand = pwconv_traffic_rtrd(b * h * w, ci, c, bg, bci, bco, dtype_bytes)
    tail = separable_traffic_unfused(b, hi, wi, c, co, hf, wf, stride,
                                     bg, bci, bco, dtype_bytes)
    return Traffic(expand.flops + tail.flops,
                   expand.bytes_hbm + tail.bytes_hbm)


def separable_intermediate_bytes(
    b: int, hi: int, wi: int, c: int, co: int, hf: int, wf: int, stride: int,
    bco: int = 256, dtype_bytes: int = 4,
) -> float:
    """The removed term: HBM bytes the unfused composition spends moving the
    DW intermediate (one store + one load per Co panel of pwconv)."""
    ho = (hi - hf) // stride + 1
    wo = (wi - wf) // stride + 1
    n_jpanels = math.ceil(co / bco)
    return dtype_bytes * b * ho * wo * c * (1 + n_jpanels)


def pwconv_traffic_rtra(
    g: int, ci: int, co: int, bg: int, bci: int, bco: int,
    dtype_bytes: int = 4,
) -> Traffic:
    """A-stationary GEMM (BLAS/RTRA): D round-trips once per Ci block."""
    flops = 2.0 * g * ci * co
    n_kpanels = math.ceil(ci / bci)
    n_gpanels = math.ceil(g / bg)
    bytes_ = dtype_bytes * (
        g * ci                      # A streamed once (stationary per panel)
        + ci * co * n_gpanels       # B streamed per row panel
        + g * co * 2 * n_kpanels    # D loaded+stored per reduction block
    )
    return Traffic(flops, bytes_)


def network_traffic(net, network_plan, *,
                    dtype_bytes: int | None = None) -> Traffic:
    """Modeled HBM traffic + FLOPs of a planned whole network: the sum of
    ``chain_traffic`` over every block at the shapes the NetworkPlan walked
    (DESIGN.md §7).

    Each block's bytes are counted at ITS plan's ``dtype_bytes`` — the
    stream width the planner budgeted at — so a bf16-streaming policy
    (``ChainPlan.dtype_bytes == 2``) halves every streamed term relative to
    the fp32 baseline, block by block, with no change to the FLOP count.
    ``dtype_bytes`` overrides that width uniformly (what-if re-costing).

    ``net`` / ``network_plan`` are ``core/network.py``'s NetworkSpec /
    NetworkPlan (duck-typed here; the lazy import below avoids the cycle
    core.chain -> core.intensity).
    """
    from repro.core import chain  # deferred: chain imports this module
    flops = 0.0
    bytes_ = 0.0
    for spec, cp, shape in zip(net.blocks, network_plan.plans,
                               network_plan.block_shapes):
        t = chain.chain_traffic(spec, cp, shape, dtype_bytes=dtype_bytes)
        flops += t.flops
        bytes_ += t.bytes_hbm
    return Traffic(flops, bytes_)
