"""Whole-network chain engine: NetworkSpec -> NetworkPlan -> execute_network.

PRs 1-4 made the separable BLOCK fast (fused single-pass inverted residuals,
dtype-aware VMEM planning, measured autotuning) — but a MobileNet was still
dispatched as a Python loop of independent per-block ``chain.execute`` calls,
re-deriving every plan on every call and streaming everything at one global
dtype.  This module is the network-level step (DESIGN.md §7):

* :class:`NetworkSpec` — an ordered tuple of :class:`~repro.core.chain.
  SeparableSpec` blocks plus the stem width; frozen/hashable, so it is a
  cache key.  :func:`mobilenet_v1_spec` / :func:`mobilenet_v2_spec` build
  the full paper backbones from their config tables (width multiplier
  included).
* :func:`plan_network` -> :class:`NetworkPlan` — every block's ``ChainPlan``
  resolved ONCE by walking the activation shapes/dtypes through the
  network, with the autotune cache consulted under a key derived from the
  WHOLE-network signature (per-block problem signatures concatenated).
* :func:`execute_network` — the entire backbone as ONE jitted call.  The
  (plan, jitted runner) pair is memoized per ``(spec, shape, dtype,
  policy)``, so steady-state calls do zero planning and zero tracing.
* per-segment mixed precision — the policy's :class:`~repro.kernels.policy.
  DtypePolicy` applies to every block, or ``block_dtype_policies`` pins a
  different policy per block (e.g. keep the first block fp32, stream the
  rest bf16).  ``core/intensity.network_traffic`` sums the per-block traffic
  models under whatever the plan was budgeted at, proving the bf16 HBM
  reduction analytically.

    net = mobilenet_v2_spec()
    params = init_network(key, net)
    y = execute_network(net, params, x,
                        policy=KernelPolicy(dtype_policy=BF16_STREAM))
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib
import json
import warnings
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import chain
from repro.kernels import autotune, lowering
from repro.kernels.blocking import ChainPlan
from repro.kernels.policy import DEFAULT_POLICY, DtypePolicy, KernelPolicy


# ---------------------------------------------------------------------------
# NetworkSpec: the declarative description of a whole backbone
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class NetworkSpec:
    """An ordered chain of separable blocks.  ``c_in`` is the channel width
    the first block consumes (the stem output — the stem conv itself is a
    dense 3x3 outside the paper's scope, as in ``examples/``)."""
    name: str
    c_in: int
    blocks: Tuple[chain.SeparableSpec, ...]

    def __post_init__(self):
        assert self.blocks, "empty network"
        assert all(isinstance(b, chain.SeparableSpec) for b in self.blocks)

    @property
    def n_blocks(self) -> int:
        return len(self.blocks)

    def out_channels(self) -> int:
        c = self.c_in
        for b in self.blocks:
            c = b.out_channels(c)
        return c

    def stride_product(self) -> int:
        p = 1
        for b in self.blocks:
            p *= b.stride_product()
        return p


def make_divisible(v: float, divisor: int = 8) -> int:
    """Channel rounding used by the MobileNet reference configs: round to
    the nearest multiple of ``divisor``, never dropping below 90% of ``v``."""
    new = max(divisor, int(v + divisor / 2) // divisor * divisor)
    if new < 0.9 * v:
        new += divisor
    return new


#: MobileNetV1 body after the 32-channel stem: (c_out, stride) per block
#: (Howard et al. 2017, Table 1 — the 13 depthwise-separable blocks).
MOBILENET_V1_BODY: Tuple[Tuple[int, int], ...] = (
    (64, 1), (128, 2), (128, 1), (256, 2), (256, 1), (512, 2),
    (512, 1), (512, 1), (512, 1), (512, 1), (512, 1), (1024, 2), (1024, 1),
)

#: MobileNetV2 body after the 32-channel stem: (t, c, n, s) rows
#: (Sandler et al. 2018, Table 2 — expansion, channels, repeats, stride).
MOBILENET_V2_BODY: Tuple[Tuple[int, int, int, int], ...] = (
    (1, 16, 1, 1), (6, 24, 2, 2), (6, 32, 3, 2), (6, 64, 4, 2),
    (6, 96, 3, 1), (6, 160, 3, 2), (6, 320, 1, 1),
)

#: MnasNet-A1 body after the 32-channel stem: (t, c, n, s, k, se) rows
#: (Tan et al. 2019, Fig. 7 — expansion, channels, repeats, stride,
#: DW kernel, squeeze-excite).  The t=1 first row is the SepConv block.
MNASNET_A1_BODY: Tuple[Tuple[int, int, int, int, int, bool], ...] = (
    (1, 16, 1, 1, 3, False), (6, 24, 2, 2, 3, False),
    (3, 40, 3, 2, 5, True), (6, 80, 4, 2, 3, False),
    (6, 112, 2, 1, 3, True), (6, 160, 3, 2, 5, True),
    (6, 320, 1, 1, 3, False),
)

#: EfficientNet-Lite0 body after the 32-channel stem: (t, c, n, s, k,
#: fused) rows — the B0 table (Tan & Le 2019) with the Lite deployment
#: edits (no SE, relu6) and the early stages declared as fused-MBConv
#: (full 3x3 conv to the expanded width, the EfficientNet-Lite /
#: EdgeTPU-style mobile idiom this PR's ``FusedMB`` stage models).
EFFICIENTNET_LITE0_BODY: Tuple[Tuple[int, int, int, int, int, bool], ...] = (
    (1, 16, 1, 1, 3, False), (6, 24, 2, 2, 3, True),
    (6, 40, 2, 2, 3, True), (6, 80, 3, 2, 3, False),
    (6, 112, 3, 1, 5, False), (6, 192, 4, 2, 5, False),
    (6, 320, 1, 1, 3, False),
)


def mobilenet_v1_spec(width_mult: float = 1.0) -> NetworkSpec:
    """The 13-block MobileNetV1 body: DW(+bias) -> PW(+bias) per block."""
    blocks = tuple(
        chain.separable_block_spec(make_divisible(c * width_mult), stride=s)
        for c, s in MOBILENET_V1_BODY)
    return NetworkSpec(name=f"mobilenet_v1_{width_mult:g}",
                       c_in=make_divisible(32 * width_mult), blocks=blocks)


def mobilenet_v2_spec(width_mult: float = 1.0) -> NetworkSpec:
    """The 17-block MobileNetV2 body.  The t=1 first row has no expansion
    GEMM, so it declares a (DW, PW) chain — the planner fuses it as a
    single 2-stage pass; every t=6 row is a full inverted residual that
    plans to ONE 3-stage fused pass."""
    c = make_divisible(32 * width_mult)
    c_in = c
    blocks = []
    for t, co, n, s in MOBILENET_V2_BODY:
        co = make_divisible(co * width_mult)
        for i in range(n):
            stride = s if i == 0 else 1
            if t == 1:
                blocks.append(chain.SeparableSpec(stages=(
                    chain.DW(stride=stride, activation="relu6"),
                    chain.PW(co),
                ), residual="auto"))
            else:
                blocks.append(chain.inverted_residual_spec(
                    c, co, expand=t, stride=stride))
            c = co
    return NetworkSpec(name=f"mobilenet_v2_{width_mult:g}",
                       c_in=c_in, blocks=tuple(blocks))


def mnasnet_a1_spec(width_mult: float = 1.0) -> NetworkSpec:
    """The MnasNet-A1 body: SepConv + MBConv blocks, three stages carrying
    squeeze-excite (SE reduced width = 1/4 of the BLOCK INPUT, the MnasNet
    convention).  The SE rows declare 4-stage (PW, DW, SE, PW) chains —
    the planner's ``dw_se`` window fuses the gate onto the DW pass when
    the full-channel working set fits VMEM (DESIGN.md §10)."""
    c = make_divisible(32 * width_mult)
    c_in = c
    blocks = []
    for t, co, n, s, k, se in MNASNET_A1_BODY:
        co = make_divisible(co * width_mult)
        for i in range(n):
            stride = s if i == 0 else 1
            if t == 1:
                blocks.append(chain.SeparableSpec(stages=(
                    chain.DW(stride=stride, activation="relu"),
                    chain.PW(co),
                ), residual="auto"))
            elif se:
                blocks.append(chain.mbconv_se_spec(
                    c, co, expand=t, stride=stride, hf=k))
            else:
                blocks.append(chain.inverted_residual_spec(
                    c, co, expand=t, stride=stride, hf=k))
            c = co
    return NetworkSpec(name=f"mnasnet_a1_{width_mult:g}",
                       c_in=c_in, blocks=tuple(blocks))


def efficientnet_lite0_spec(width_mult: float = 1.0) -> NetworkSpec:
    """The EfficientNet-Lite0 body: the B0 stage table with the Lite
    deployment edits (SE removed, relu6) and the early stages declared as
    fused-MBConv — a full 3x3 conv to the expanded width in place of
    PW-expand + DW.  Those rows plan to the single-pass ``fusedmb``
    segment (conv + PW-project in one kernel) when VMEM allows."""
    c = make_divisible(32 * width_mult)
    c_in = c
    blocks = []
    for t, co, n, s, k, fused in EFFICIENTNET_LITE0_BODY:
        co = make_divisible(co * width_mult)
        for i in range(n):
            stride = s if i == 0 else 1
            if t == 1:
                blocks.append(chain.SeparableSpec(stages=(
                    chain.DW(stride=stride, activation="relu6"),
                    chain.PW(co),
                ), residual="auto"))
            elif fused:
                blocks.append(chain.fused_mbconv_spec(
                    c, co, expand=t, stride=stride, hf=k))
            else:
                blocks.append(chain.inverted_residual_spec(
                    c, co, expand=t, stride=stride, hf=k))
            c = co
    return NetworkSpec(name=f"efficientnet_lite0_{width_mult:g}",
                       c_in=c_in, blocks=tuple(blocks))


def init_network(key, net: NetworkSpec, dtype=jnp.float32) -> list:
    """Per-block ``init_chain`` params, aligned with ``net.blocks``."""
    params = []
    c = net.c_in
    for k, spec in zip(jax.random.split(key, net.n_blocks), net.blocks):
        params.append(chain.init_chain(k, spec, c, dtype))
        c = spec.out_channels(c)
    return params


def cast_network_params(params, dtype) -> list:
    """Cast every parameter leaf once, up front — deployment-style weight
    storage at the stream width, making the lowering's per-call casts
    no-ops (DESIGN.md §7)."""
    return jax.tree_util.tree_map(lambda a: a.astype(dtype), params)


# ---------------------------------------------------------------------------
# NetworkPlan: every block's ChainPlan, resolved once
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class NetworkPlan:
    """Per-block ``ChainPlan``s + the shape/dtype walk they were planned at.
    Frozen/hashable — a complete, reproducible execution recipe for the
    whole backbone (and the unit the network-level autotune cache stores)."""
    plans: Tuple[ChainPlan, ...]
    block_shapes: Tuple[Tuple[int, int, int, int], ...]
    block_dtypes: Tuple[str, ...]
    out_shape: Tuple[int, int, int, int]
    key: str

    @property
    def n_blocks(self) -> int:
        return len(self.plans)

    @property
    def n_kernel_passes(self) -> int:
        return sum(p.n_kernel_passes for p in self.plans)

    @property
    def fully_fused(self) -> bool:
        """Every block runs as ONE kernel pass."""
        return all(p.fully_fused for p in self.plans)

    def segment_histogram(self) -> dict:
        """{'fused3': n, 'fused2': m, ...} across all blocks."""
        counter = collections.Counter(
            seg.kind for p in self.plans for seg in p.segments)
        return dict(counter)


def resolve_block_policies(
    net: NetworkSpec, policy: KernelPolicy,
    block_dtype_policies: Optional[Sequence[DtypePolicy]] = None,
) -> Tuple[KernelPolicy, ...]:
    """The effective per-block KernelPolicy.

    Broadcasting one policy over the network: intermediate blocks hand off
    at the STREAM width (their ``out`` is cleared — only the final block
    honors the policy's ``out`` pin, otherwise a bf16-streamed network with
    ``out="float32"`` would widen at every block boundary).  With explicit
    ``block_dtype_policies`` each block's policy is taken verbatim — the
    caller states exactly what each block emits.
    """
    n = net.n_blocks
    if block_dtype_policies is None:
        dp = policy.dtype_policy
        inner = dataclasses.replace(dp, out=None)
        return tuple(
            dataclasses.replace(policy,
                                dtype_policy=dp if i == n - 1 else inner)
            for i in range(n))
    assert len(block_dtype_policies) == n, (len(block_dtype_policies), n)
    return tuple(dataclasses.replace(policy, dtype_policy=d)
                 for d in block_dtype_policies)


def _block_problems(net: NetworkSpec, x_shape, dtype,
                    policies: Sequence[KernelPolicy]):
    """Walk (shape, dtype) through the network: the per-block problem
    each ChainPlan answers.  Block i+1's input dtype is block i's OUT
    dtype (= its stream width for broadcast policies), exactly matching
    what the lowering emits at run time."""
    b, h, w, c = (int(v) for v in x_shape)
    assert c == net.c_in, (c, net.c_in)
    problems = []
    d = jnp.dtype(dtype)
    for spec, pol in zip(net.blocks, policies):
        problems.append(((b, h, w, c), d.name))
        for s in spec.stages:
            if isinstance(s, (chain.DW, chain.FusedMB)):
                h, w = s.out_dims(h, w)
        c = spec.out_channels(c)
        d = pol.dtype_policy.out_dtype(d)
    return problems, (b, h, w, c)


def network_signature(net: NetworkSpec, x_shape, dtype,
                      policy: KernelPolicy,
                      block_dtype_policies=None) -> dict:
    """The whole-network identity a tuned NetworkPlan is valid for: the
    concatenated per-block problem signatures (DESIGN.md §6 schema, §7)."""
    policies = resolve_block_policies(net, policy, block_dtype_policies)
    problems, _ = _block_problems(net, x_shape, dtype, policies)
    return {
        "name": net.name,
        "blocks": [
            autotune.problem_signature(spec, shape, dt, pol)
            for spec, (shape, dt), pol in zip(net.blocks, problems, policies)
        ],
    }


def network_key(net: NetworkSpec, x_shape, dtype, policy: KernelPolicy,
                block_dtype_policies=None) -> str:
    blob = json.dumps(
        network_signature(net, x_shape, dtype, policy, block_dtype_policies),
        sort_keys=True, separators=(",", ":"))
    return "net:" + hashlib.sha256(blob.encode("utf-8")).hexdigest()[:20]


def plan_network(net: NetworkSpec, x_shape, *, dtype=jnp.float32,
                 policy: KernelPolicy = DEFAULT_POLICY,
                 block_dtype_policies: Optional[Sequence[DtypePolicy]] = None,
                 ) -> NetworkPlan:
    """Resolve every block's ChainPlan ONCE by walking shapes/dtypes through
    the network.

    With ``policy.autotune`` the network-level tune-cache entry (keyed on
    :func:`network_key`) wins when present; otherwise each block's
    ``chain.plan`` answers (itself consulting the per-block cache), so a
    partially tuned cache still helps.  Measurement never happens here —
    :func:`tune_network` owns that.
    """
    policies = resolve_block_policies(net, policy, block_dtype_policies)
    problems, out_shape = _block_problems(net, x_shape, dtype, policies)
    key = network_key(net, x_shape, dtype, policy, block_dtype_policies)
    if policy.autotune:
        cached = _lookup_network_entry(key, policy)
        if cached is not None and _validate_network_entry(
                net, cached, policy,
                block_dtype_policies=block_dtype_policies):
            return _maybe_verify_network(net, cached, policy,
                                         block_dtype_policies)
    nplan = NetworkPlan(
        plans=tuple(
            chain.plan(spec, shape, dtype=jnp.dtype(dt), policy=pol)
            for spec, (shape, dt), pol in zip(net.blocks, problems,
                                              policies)),
        block_shapes=tuple(shape for shape, _ in problems),
        block_dtypes=tuple(dt for _, dt in problems),
        out_shape=out_shape,
        key=key,
    )
    return _maybe_verify_network(net, nplan, policy, block_dtype_policies)


def _validate_network_entry(net: NetworkSpec, nplan: NetworkPlan,
                            policy: KernelPolicy,
                            block_dtype_policies=None) -> bool:
    """Replayed whole-network cache entries must pass planlint block-wise
    before executing verbatim (DESIGN.md §8) and must not use any
    quarantined rung (DESIGN.md §9); a stale/banned entry is dropped with
    a warning (and the caller re-plans), never executed or crashed on.
    Lazy import: analysis/runtime sit above this module."""
    from repro.analysis import lint_cached_plan
    path = policy.tune_cache or autotune.default_cache_path()
    for i, (spec, cp, shape) in enumerate(zip(net.blocks, nplan.plans,
                                              nplan.block_shapes)):
        rules = lint_cached_plan(spec, cp, shape,
                                 label=f"net-cache/block{i}")
        if rules is not None:
            warnings.warn(
                f"dropping network tune-cache entry {nplan.key} from "
                f"{path}: block {i} failed planlint ({rules}); "
                "re-planning analytically", stacklevel=3)
            return False
    if policy.on_failure == "degrade":
        from repro.runtime import quarantine
        policies = resolve_block_policies(net, policy, block_dtype_policies)
        for i, (spec, cp, shape, dt, pol) in enumerate(zip(
                net.blocks, nplan.plans, nplan.block_shapes,
                nplan.block_dtypes, policies)):
            banned = quarantine.banned_kinds(spec, shape, jnp.dtype(dt), pol)
            if banned and ("unfused" in banned
                           or any(s.kind in banned for s in cp.segments)):
                warnings.warn(
                    f"dropping network tune-cache entry {nplan.key} from "
                    f"{path}: block {i} uses quarantined rungs "
                    f"({sorted(banned)} banned); re-planning analytically",
                    stacklevel=3)
                return False
    return True


def _maybe_verify_network(net: NetworkSpec, nplan: NetworkPlan,
                          policy: KernelPolicy,
                          block_dtype_policies=None) -> NetworkPlan:
    """The ``policy.verify`` knob at network scope: static analyzer over
    every block's resolved plan, raising on error diagnostics."""
    if policy.verify:
        from repro import analysis
        analysis.verify_or_raise(analysis.analyze_network(
            net, nplan, policy=dataclasses.replace(policy, verify=False),
            block_dtype_policies=block_dtype_policies, jaxpr=False))
    return nplan


def _serialize_network_plan(nplan: NetworkPlan) -> dict:
    return {
        "plans": [autotune.serialize_chain_plan(p) for p in nplan.plans],
        "block_shapes": [list(s) for s in nplan.block_shapes],
        "block_dtypes": list(nplan.block_dtypes),
        "out_shape": list(nplan.out_shape),
    }


def _deserialize_network_plan(key: str, d: dict) -> NetworkPlan:
    return NetworkPlan(
        plans=tuple(autotune.deserialize_chain_plan(p) for p in d["plans"]),
        block_shapes=tuple(tuple(int(v) for v in s)
                           for s in d["block_shapes"]),
        block_dtypes=tuple(str(v) for v in d["block_dtypes"]),
        out_shape=tuple(int(v) for v in d["out_shape"]),
        key=key,
    )


def _lookup_network_entry(key: str,
                          policy: KernelPolicy) -> Optional[NetworkPlan]:
    path = policy.tune_cache or autotune.default_cache_path()
    entry = autotune.TuneCache.load(path).get(key)
    if entry is None:
        return None
    try:
        return _deserialize_network_plan(key, entry["network_plan"])
    except (KeyError, TypeError, ValueError):
        return None


# ---------------------------------------------------------------------------
# tune_network: measured per-block plans, persisted under the network key
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class NetworkTuneResult:
    plan: NetworkPlan
    cache_hit: bool
    n_measured: int
    key: str
    cache_path: str


def tune_network(net: NetworkSpec, params, x, *,
                 policy: KernelPolicy,
                 block_dtype_policies: Optional[Sequence[DtypePolicy]] = None,
                 warmup: int = 1, repeats: int = 5) -> NetworkTuneResult:
    """Measured whole-network plan: autotune each block on its REAL
    intermediate activation (produced by executing the preceding tuned
    blocks), then persist the assembled NetworkPlan under the network key.

    A network-entry cache hit replays with ZERO measurements; per-block
    cache hits (e.g. from tuning a different network that shares layers)
    also skip measurement block-wise."""
    path = policy.tune_cache or autotune.default_cache_path()
    key = network_key(net, x.shape, x.dtype, policy, block_dtype_policies)
    cached = _lookup_network_entry(key, policy)
    if cached is not None:
        return NetworkTuneResult(plan=cached, cache_hit=True, n_measured=0,
                                 key=key, cache_path=path)
    policies = resolve_block_policies(net, policy, block_dtype_policies)
    problems, out_shape = _block_problems(net, x.shape, x.dtype, policies)
    plans = []
    n_measured = 0
    y = x
    for spec, p, pol in zip(net.blocks, params, policies):
        base = chain.plan(spec, y.shape, dtype=y.dtype,
                          policy=dataclasses.replace(pol, autotune=False))
        r = autotune.autotune_chain(spec, p, y, policy=pol, base_plan=base,
                                    warmup=warmup, repeats=repeats)
        plans.append(r.plan)
        n_measured += r.n_measured
        y = lowering.lower(spec, r.plan, pol)(p, y)
    nplan = NetworkPlan(
        plans=tuple(plans),
        block_shapes=tuple(shape for shape, _ in problems),
        block_dtypes=tuple(dt for _, dt in problems),
        out_shape=out_shape,
        key=key,
    )
    cache = autotune.TuneCache.load(path)
    cache.put(key, {
        "signature": network_signature(net, x.shape, x.dtype, policy,
                                       block_dtype_policies),
        "network_plan": _serialize_network_plan(nplan),
        "n_measured": n_measured,
    })
    cache.save()
    return NetworkTuneResult(plan=nplan, cache_hit=False,
                             n_measured=n_measured, key=key, cache_path=path)


# ---------------------------------------------------------------------------
# execute_network: the whole backbone as ONE jitted call
# ---------------------------------------------------------------------------

def build_network_fn(net: NetworkSpec, nplan: NetworkPlan,
                     policy: KernelPolicy = DEFAULT_POLICY,
                     block_dtype_policies=None):
    """Compose the per-block lowered runners into one ``run(params, x)``.
    Pure composition — every block executes its planned blocks verbatim
    (the lowering never re-plans), so jitting ``run`` compiles the whole
    backbone as one program.

    Quarantine honoring (DESIGN.md §9): the planner already degrades
    banned FUSION rungs at plan time, but an ``"unfused"`` ban (the Pallas
    kernels themselves failed for a block's problem) cannot be expressed
    in a ChainPlan — it is honored here by lowering that block on the XLA
    reference backend, keeping the rest of the network on its fast path
    inside the same jitted program."""
    policies = resolve_block_policies(net, policy, block_dtype_policies)
    if policy.on_failure == "degrade":
        from repro.runtime import quarantine  # lazy: runtime sits above
        policies = tuple(
            dataclasses.replace(pol, impl="xla")
            if "unfused" in quarantine.banned_kinds(spec, shape,
                                                    jnp.dtype(dt), pol)
            else pol
            for spec, pol, shape, dt in zip(net.blocks, policies,
                                            nplan.block_shapes,
                                            nplan.block_dtypes))
    runners = [lowering.lower(spec, cp, pol)
               for spec, cp, pol in zip(net.blocks, nplan.plans, policies)]

    def run(params, x):
        assert len(params) == len(runners), (len(params), len(runners))
        for r, p in zip(runners, params):
            x = r(p, x)
        return x

    return run


#: (net, shape, dtype, policy, block policies, explicit plan) ->
#: (NetworkPlan, jitted runner).  Every component of the key is frozen /
#: hashable, so steady-state execute_network calls do ZERO planning and
#: ZERO tracing.
_NETWORK_CACHE: dict = {}


def clear_network_cache() -> None:
    _NETWORK_CACHE.clear()


def execute_network(net: NetworkSpec, params, x, *,
                    policy: KernelPolicy = DEFAULT_POLICY,
                    network_plan: Optional[NetworkPlan] = None,
                    block_dtype_policies: Optional[Tuple[DtypePolicy, ...]]
                    = None):
    """Run the whole backbone in ONE jitted call.

    First call for a given (net, input shape/dtype, policy): resolve the
    NetworkPlan once — via :func:`tune_network` when ``policy.autotune``
    (cache-replayed when already tuned), else :func:`plan_network` — build
    the composed runner, jit it, and memoize the pair.  Every later call
    is a dictionary hit straight into the compiled program.

    Under the default ``policy.on_failure == "degrade"`` (or with
    ``policy.numeric_guard``) the call routes through the runtime guard
    (``repro.runtime.executor.run_network``, DESIGN.md §9): the
    steady-state path is the same ONE jitted call; a classified failure of
    the composed program recovers per-block, quarantining the failing
    blocks so the next call re-plans and re-jits around them.
    """
    if policy.on_failure == "degrade" or policy.numeric_guard:
        from repro.runtime import executor  # lazy: runtime sits above core
        return executor.run_network(
            net, params, x, policy=policy, network_plan=network_plan,
            block_dtype_policies=block_dtype_policies)
    return _execute_network_raw(
        net, params, x, policy=policy, network_plan=network_plan,
        block_dtype_policies=block_dtype_policies)


def _execute_network_raw(net: NetworkSpec, params, x, *,
                         policy: KernelPolicy = DEFAULT_POLICY,
                         network_plan: Optional[NetworkPlan] = None,
                         block_dtype_policies=None):
    """The unguarded engine behind :func:`execute_network`: plan, jit,
    memoize, run.  The (plan, runner) pair is memoized only AFTER its
    first call succeeds — a plan whose trace/compile fails must not poison
    the memo, or the re-plan after a quarantine write could never happen."""
    cache_key = (net, x.shape, jnp.dtype(x.dtype).name, policy,
                 block_dtype_policies, network_plan)
    hit = _NETWORK_CACHE.get(cache_key)
    if hit is not None:
        return hit[1](params, x)
    nplan = network_plan
    if nplan is None:
        if policy.autotune:
            nplan = tune_network(
                net, params, x, policy=policy,
                block_dtype_policies=block_dtype_policies).plan
        else:
            nplan = plan_network(
                net, x.shape, dtype=x.dtype, policy=policy,
                block_dtype_policies=block_dtype_policies)
    fn = jax.jit(build_network_fn(net, nplan, policy,
                                  block_dtype_policies))
    y = fn(params, x)
    _NETWORK_CACHE[cache_key] = (nplan, fn)
    return y
