"""PWConv — the paper's pointwise-convolution contribution as a framework op.

Every dense projection in the framework (attention QKV/O, MLP, MoE experts,
router, unembed) routes through :func:`pointwise`, so the paper's
output-stationary GEMM is a first-class, globally selectable feature
(``KernelPolicy``), not a benchmark-only artifact.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.kernels import ops

# KernelPolicy lives at the kernel layer now (kernels/policy.py — the single
# owner of backend resolution and the VMEM budget); re-exported here because
# this module was its historical home.  Fusion is no longer a policy field
# but a planner decision (core/chain.plan, DESIGN.md §5).
from repro.kernels.policy import (  # noqa: F401  (re-export)
    DEFAULT_POLICY,
    KernelPolicy,
    resolve_impl,
)


def pointwise(
    x: jax.Array,
    w: jax.Array,
    bias: Optional[jax.Array] = None,
    *,
    activation: Optional[str] = None,
    policy: KernelPolicy = DEFAULT_POLICY,
) -> jax.Array:
    """Pointwise conv (1x1) / GEMM over the trailing axis, fp32 accumulate."""
    return ops.pwconv(
        x, w, bias,
        activation=activation,
        impl=policy.impl,
        interpret=policy.interpret,
        block_g=policy.block_g,
        block_co=policy.block_co,
        block_ci=policy.block_ci,
    )
