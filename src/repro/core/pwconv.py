"""PWConv — the paper's pointwise-convolution contribution as a framework op.

Every dense projection in the framework (attention QKV/O, MLP, MoE experts,
router, unembed) routes through :func:`pointwise`, so the paper's
output-stationary GEMM is a first-class, globally selectable feature
(``KernelPolicy``), not a benchmark-only artifact.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax

from repro.kernels import ops


@dataclasses.dataclass(frozen=True)
class KernelPolicy:
    """Global execution policy for the paper's ops.

    impl: "auto" | "xla" | "pallas". interpret=True only for CPU validation.
    fused: run depthwise-separable blocks through the single-pass fused
    DW+PW kernel (DESIGN.md §3) instead of composing the standalone ops —
    the DW intermediate then never round-trips HBM.
    block_g/co/ci: explicit GEMM grid overrides; None (default) defers to
    the dtype-aware planner (kernels/blocking.plan_pwconv, DESIGN.md §4).
    """
    impl: str = "auto"
    interpret: bool = False
    fused: bool = False
    block_g: Optional[int] = None
    block_co: Optional[int] = None
    block_ci: Optional[int] = None

    def resolved(self) -> str:
        return (
            "pallas" if self.impl == "auto" and jax.default_backend() == "tpu"
            else ("xla" if self.impl == "auto" else self.impl)
        )


DEFAULT_POLICY = KernelPolicy()


def pointwise(
    x: jax.Array,
    w: jax.Array,
    bias: Optional[jax.Array] = None,
    *,
    activation: Optional[str] = None,
    policy: KernelPolicy = DEFAULT_POLICY,
) -> jax.Array:
    """Pointwise conv (1x1) / GEMM over the trailing axis, fp32 accumulate."""
    return ops.pwconv(
        x, w, bias,
        activation=activation,
        impl=policy.impl,
        interpret=policy.interpret,
        block_g=policy.block_g,
        block_co=policy.block_co,
        block_ci=policy.block_ci,
    )
