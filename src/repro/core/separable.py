"""Depthwise-separable convolution blocks — the paper's own workload.

MobileNetV1 blocks (DW 3x3 + folded-BN + ReLU6, then PW + ReLU6) and the
MobileNetV2 inverted residual (PW-expand + DW + PW-project), built entirely
from the paper's two ops.  BatchNorm is folded into the filters/bias
(inference form), as in the paper's measured binaries.

These entry points are thin shims over the declarative chain API
(``core/chain.py``, DESIGN.md §5): each builds a `SeparableSpec`, adapts
the legacy param dict to per-stage params, and calls ``chain.execute`` —
the planner decides what fuses (3-stage -> 2-stage -> unfused by VMEM
feasibility), not a user boolean.  A MobileNetV2 inverted residual now
lowers to ONE fused kernel pass (expand-on-the-fly) at MobileNet shapes.

Used by examples/mobilenet_inference.py and benchmarks/ (figs. 4-6).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import chain
from repro.core.pwconv import DEFAULT_POLICY, KernelPolicy


def init_separable(key, c_in: int, c_out: int, hf: int = 3, wf: int = 3):
    k1, k2 = jax.random.split(key, 2)
    scale_dw = 1.0 / jnp.sqrt(hf * wf)
    scale_pw = 1.0 / jnp.sqrt(c_in)
    return {
        "dw_filter": jax.random.normal(k1, (hf, wf, c_in)) * scale_dw,
        "dw_bias": jnp.zeros((c_in,)),
        "pw_weight": jax.random.normal(k2, (c_in, c_out)) * scale_pw,
        "pw_bias": jnp.zeros((c_out,)),
    }


def separable_block(
    params,
    x: jax.Array,
    *,
    stride: int = 1,
    activation: str = "relu6",
    policy: KernelPolicy = DEFAULT_POLICY,
) -> jax.Array:
    """MobileNetV1 depthwise-separable block (inference, BN folded).

    Shim over the chain API: DW(+bias, act) -> PW(+bias, act).  The planner
    fuses the pair into one kernel pass whenever its working set fits the
    policy's VMEM budget (``KernelPolicy(fused=False)`` forces the old
    unfused composition).
    """
    hf, wf = params["dw_filter"].shape[:2]
    spec = chain.SeparableSpec(stages=(
        chain.DW(stride=stride, activation=activation, hf=hf, wf=wf,
                 bias=True),
        chain.PW(params["pw_weight"].shape[-1], activation=activation,
                 bias=True),
    ))
    stage_params = (
        {"f": params["dw_filter"], "b": params["dw_bias"]},
        {"w": params["pw_weight"], "b": params["pw_bias"]},
    )
    return chain.execute(spec, stage_params, x, policy=policy)


def init_inverted_residual(key, c_in: int, c_out: int, expand: int = 6,
                           hf: int = 3):
    k1, k2, k3 = jax.random.split(key, 3)
    c_mid = c_in * expand
    return {
        "expand_w": jax.random.normal(k1, (c_in, c_mid)) / jnp.sqrt(c_in),
        "dw_filter": jax.random.normal(k2, (hf, hf, c_mid)) / hf,
        "project_w": jax.random.normal(k3, (c_mid, c_out)) / jnp.sqrt(c_mid),
    }


def inverted_residual(
    params,
    x: jax.Array,
    *,
    stride: int = 1,
    policy: KernelPolicy = DEFAULT_POLICY,
) -> jax.Array:
    """MobileNetV2 inverted-residual block (PW-expand -> DW -> PW-project).

    Shim over the chain API.  The planner lowers the whole block to a
    SINGLE fused kernel pass (expansion computed on the fly per row slab,
    residual folded into the store) whenever the 3-stage working set fits
    VMEM, degrading to expand + fused DW->project, then fully unfused.
    """
    hf, wf = params["dw_filter"].shape[:2]
    c_mid = params["expand_w"].shape[-1]
    c_out = params["project_w"].shape[-1]
    spec = chain.SeparableSpec(stages=(
        chain.PW(c_mid, activation="relu6"),
        chain.DW(stride=stride, activation="relu6", hf=hf, wf=wf),
        chain.PW(c_out),
    ), residual="auto")
    stage_params = (
        {"w": params["expand_w"]},
        {"f": params["dw_filter"]},
        {"w": params["project_w"]},
    )
    return chain.execute(spec, stage_params, x, policy=policy)
