"""Depthwise-separable convolution blocks — the paper's own workload.

MobileNetV1 blocks (DW 3x3 + folded-BN + ReLU6, then PW + ReLU6) and the
MobileNetV2 inverted residual (PW-expand + DW + PW-project), built entirely
from the paper's two ops. BatchNorm is folded into the filters/bias
(inference form), as in the paper's measured binaries.

Used by examples/mobilenet_inference.py and benchmarks/ (figs. 4-6).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.dwconv import depthwise2d
from repro.core.pwconv import DEFAULT_POLICY, KernelPolicy, pointwise
from repro.kernels import ops


def init_separable(key, c_in: int, c_out: int, hf: int = 3, wf: int = 3):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    scale_dw = 1.0 / jnp.sqrt(hf * wf)
    scale_pw = 1.0 / jnp.sqrt(c_in)
    return {
        "dw_filter": jax.random.normal(k1, (hf, wf, c_in)) * scale_dw,
        "dw_bias": jnp.zeros((c_in,)),
        "pw_weight": jax.random.normal(k2, (c_in, c_out)) * scale_pw,
        "pw_bias": jnp.zeros((c_out,)),
    }


def separable_block(
    params,
    x: jax.Array,
    *,
    stride: int = 1,
    activation: str = "relu6",
    policy: KernelPolicy = DEFAULT_POLICY,
) -> jax.Array:
    """MobileNetV1 depthwise-separable block (inference, BN folded).

    With ``policy.fused`` the whole block runs as one kernel pass and the DW
    output never touches HBM (kernels/separable_fused.py, DESIGN.md §3).
    """
    if policy.fused:
        return ops.separable_fused(
            x, params["dw_filter"], params["pw_weight"],
            params["dw_bias"], params["pw_bias"],
            stride=stride, padding="same",
            dw_activation=activation, activation=activation,
            impl=policy.impl, interpret=policy.interpret,
        )
    y = depthwise2d(x, params["dw_filter"], stride=stride, policy=policy)
    y = y + params["dw_bias"]
    y = jnp.clip(y, 0.0, 6.0) if activation == "relu6" else jax.nn.relu(y)
    return pointwise(
        y, params["pw_weight"], params["pw_bias"],
        activation=activation, policy=policy,
    )


def init_inverted_residual(key, c_in: int, c_out: int, expand: int = 6,
                           hf: int = 3):
    k1, k2, k3 = jax.random.split(key, 3)
    c_mid = c_in * expand
    return {
        "expand_w": jax.random.normal(k1, (c_in, c_mid)) / jnp.sqrt(c_in),
        "dw_filter": jax.random.normal(k2, (hf, hf, c_mid)) / hf,
        "project_w": jax.random.normal(k3, (c_mid, c_out)) / jnp.sqrt(c_mid),
    }


def inverted_residual(
    params,
    x: jax.Array,
    *,
    stride: int = 1,
    policy: KernelPolicy = DEFAULT_POLICY,
) -> jax.Array:
    """MobileNetV2 inverted-residual block (PW-expand -> DW -> PW-project).

    With ``policy.fused`` the DW -> PW-project tail (and the residual add)
    runs as one kernel pass; only the expansion remains a standalone GEMM.
    """
    y = pointwise(x, params["expand_w"], activation="relu6", policy=policy)
    c_out = params["project_w"].shape[-1]
    res = x if stride == 1 and x.shape[-1] == c_out else None
    if policy.fused:
        return ops.separable_fused(
            y, params["dw_filter"], params["project_w"], None, None, res,
            stride=stride, padding="same",
            dw_activation="relu6", activation=None,
            impl=policy.impl, interpret=policy.interpret,
        )
    y = depthwise2d(y, params["dw_filter"], stride=stride, policy=policy)
    y = jnp.clip(y, 0.0, 6.0)
    y = pointwise(y, params["project_w"], policy=policy)
    if res is not None:
        y = y + res
    return y
