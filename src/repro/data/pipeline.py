"""Deterministic, resumable, shard-disjoint synthetic LM data pipeline.

Production posture without shipping a corpus: a seeded counter-based stream
(threefry on (seed, step, shard)) generates token batches with a Zipfian
marginal + a deterministic n-gram structure so models actually have signal
to fit (loss decreases — used by integration tests and examples).

* determinism: batch(step) is a pure function of (seed, step) — replaying a
  step after restore is bit-exact (checkpoint stores only `step`).
* sharding: each data-parallel rank draws a disjoint slice of the global
  batch (host-sharded loading at scale).
* prefetch: a background thread keeps `prefetch` batches ready.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    structure: int = 8     # n-gram period giving learnable structure


def _batch_np(cfg: DataConfig, step: int, shard: int = 0,
              n_shards: int = 1) -> dict:
    """Pure function of (cfg.seed, step, shard)."""
    assert cfg.global_batch % n_shards == 0
    b_local = cfg.global_batch // n_shards
    rng = np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, shard])
    )
    # Zipf marginal clipped to vocab
    raw = rng.zipf(cfg.zipf_a, size=(b_local, cfg.seq_len + 1))
    toks = (raw - 1) % cfg.vocab_size
    # learnable structure: every `structure`-th token repeats (shifted) the
    # anchor token, so context predicts it
    anchor = toks[:, 0::cfg.structure]
    for j in range(1, cfg.structure // 2 + 1):
        idx = np.arange(j, cfg.seq_len + 1, cfg.structure)
        toks[:, idx] = (anchor[:, : len(idx)] + j) % cfg.vocab_size
    tokens = toks[:, :-1].astype(np.int32)
    labels = toks[:, 1:].astype(np.int32)
    return {"tokens": tokens, "labels": labels}


class DataIterator:
    """Stateful iterator with save/restore; optional background prefetch."""

    def __init__(self, cfg: DataConfig, *, shard: int = 0, n_shards: int = 1,
                 start_step: int = 0, prefetch: int = 2):
        self.cfg = cfg
        self.shard = shard
        self.n_shards = n_shards
        self.step = start_step
        self._prefetch_n = prefetch
        self._q: Optional[queue.Queue] = None
        self._thread: Optional[threading.Thread] = None
        if prefetch > 0:
            self._start_prefetch()

    # -- checkpointable state ------------------------------------------------
    def state(self) -> dict:
        return {"step": self.step, "shard": self.shard,
                "n_shards": self.n_shards}

    @classmethod
    def restore(cls, cfg: DataConfig, state: dict, prefetch: int = 2):
        return cls(cfg, shard=state["shard"], n_shards=state["n_shards"],
                   start_step=state["step"], prefetch=prefetch)

    # -- iteration -------------------------------------------------------------
    def _start_prefetch(self):
        self._q = queue.Queue(maxsize=self._prefetch_n)
        self._stop = threading.Event()
        fetch_from = self.step

        def worker():
            s = fetch_from
            while not self._stop.is_set():
                batch = _batch_np(self.cfg, s, self.shard, self.n_shards)
                try:
                    self._q.put((s, batch), timeout=0.5)
                    s += 1
                except queue.Full:
                    continue

        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()

    def __next__(self) -> dict:
        if self._q is not None:
            s, batch = self._q.get()
            # on restore mid-stream the queue may hold stale steps; skip
            while s < self.step:
                s, batch = self._q.get()
            self.step = s + 1
            return batch
        batch = _batch_np(self.cfg, self.step, self.shard, self.n_shards)
        self.step += 1
        return batch

    def __iter__(self) -> Iterator[dict]:
        return self

    def close(self):
        if self._thread is not None:
            self._stop.set()
            self._thread.join(timeout=2)
