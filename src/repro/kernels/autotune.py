"""Measured ChainPlan autotuner with a persistent on-disk cache (DESIGN §6).

The analytic planner (``core/chain.plan`` -> ``kernels/blocking.py``) picks
block shapes by VMEM arithmetic alone.  That is the right *feasibility*
filter, but on real hardware the fastest feasible blocking is not always
the first one the preference ladder hits — TVM (the paper's baseline) and
the ARMv8 DWConv follow-up both close that gap with a measurement loop over
a pruned candidate set.  This module is that loop for declared separable
chains:

* **candidate ladder** — per chain segment, enumerate a handful of feasible
  ``BlockPlan``s from the SAME ladders the analytic planner walks
  (``co_candidates`` x ``slab_candidates`` probed via
  ``plan_separable_at``/``plan_separable3_at``, the ``PW_G_CANDIDATES``
  GEMM panel ladder, ``snap_channels`` channel blocks), capped at
  :data:`MAX_SEGMENT_CANDIDATES` per segment;
* **timing harness** — each candidate ``ChainPlan`` is lowered
  (``kernels/lowering.lower`` — which executes plans verbatim, never
  re-plans) and timed jitted with ``block_until_ready``: warmup runs to
  absorb compilation, then median-of-k repeats.  Works on the Pallas
  interpret path in a CPU container and on compiled Pallas on real TPU;
* **persistent cache** — winners are stored in a JSON file keyed on the
  serialized problem signature (spec stages + input shape/dtype + VMEM
  budget + backend fingerprint), so repeated runs — and repeated identical
  layers within a run — replay cache hits with zero re-measurement.  A
  corrupted cache file is treated as empty (recoverable), never a crash.

A candidate only dethrones the incumbent when it wins by more than
:data:`REL_IMPROVEMENT` — on backends where block shapes cannot change the
wall time (the XLA reference path) the analytic plan therefore stays the
winner, and measured noise cannot flip plans between runs.

Entry points: ``core/chain.execute(policy=KernelPolicy(autotune=True))``
measures on the first call and replays the cache afterwards;
``core/chain.plan`` consults :func:`lookup_cached_plan`;
``benchmarks/run.py --autotune`` prints the analytic-vs-measured table.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import statistics
import time
import warnings
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.kernels import blocking, lowering
from repro.kernels.blocking import BlockPlan, ChainPlan, ChainSegment
from repro.kernels.policy import KernelPolicy

#: Cache-file schema version; bump on incompatible layout changes (old
#: files then read as empty and re-tune, they are never mis-parsed).
#: v2: problem signatures gained the per-segment dtype policy (DESIGN §7) —
#: v1 keys hashed only the input dtype, so a bf16-streamed winner could
#: replay onto a native fp32 run of the same problem.
CACHE_VERSION = 2

#: Feasible candidates measured per chain segment (incl. the analytic plan).
MAX_SEGMENT_CANDIDATES = 8

#: A candidate must beat the incumbent by this relative margin to win —
#: keeps plan churn at measurement-noise level (and keeps the analytic plan
#: the winner on backends where blocks cannot change the wall time).
REL_IMPROVEMENT = 0.02


def default_cache_path() -> str:
    """$REPRO_TUNE_CACHE, else ~/.cache/repro/autotune.json."""
    env = os.environ.get("REPRO_TUNE_CACHE")
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache", "repro",
                        "autotune.json")


# ---------------------------------------------------------------------------
# Problem signature: the cache key schema (DESIGN.md §6)
# ---------------------------------------------------------------------------

def _stage_signature(s) -> dict:
    """Duck-typed stage descriptor (PW has ``features``; DW has ``stride``),
    mirroring kernels/lowering.py's duck-typing so this module needs no
    import of core/chain."""
    if hasattr(s, "features"):
        return {"kind": "pw", "features": int(s.features),
                "activation": s.activation, "bias": bool(s.bias)}
    return {"kind": "dw", "stride": int(s.stride), "hf": int(s.hf),
            "wf": int(s.wf), "padding": s.padding.lower(),
            "activation": s.activation, "bias": bool(s.bias)}


def backend_fingerprint(policy: KernelPolicy) -> dict:
    """What makes a measurement transferable: same resolved impl, interpret
    mode, jax backend and device kind (a v5e winner must not replay on a
    v4, nor an interpret-mode winner on compiled Pallas)."""
    dev = jax.devices()[0]
    return {
        "impl": policy.resolved(),
        "interpret": bool(policy.interpret),
        "backend": jax.default_backend(),
        "device_kind": getattr(dev, "device_kind", "unknown"),
        "jax": jax.__version__,
    }


def problem_signature(spec, x_shape: Sequence[int], dtype,
                      policy: KernelPolicy) -> dict:
    """The full serialized problem identity a measurement is valid for."""
    residual = spec.residual
    return {
        "stages": [_stage_signature(s) for s in spec.stages],
        "residual": residual if isinstance(residual, bool) else str(residual),
        "x_shape": [int(v) for v in x_shape],
        "dtype": jnp.dtype(dtype).name,
        # ``dtype`` alone is NOT the precision identity: the dtype policy
        # changes both what was measured (streamed bytes) and what the plan
        # was budgeted at (stream-width VMEM), so a bf16-streamed winner
        # must never replay onto a native run of the same input dtype.
        "dtype_policy": policy.dtype_policy.signature(),
        "vmem_budget": int(policy.vmem_budget),
        "backend": backend_fingerprint(policy),
    }


def problem_key(spec, x_shape: Sequence[int], dtype,
                policy: KernelPolicy) -> str:
    """Stable digest of :func:`problem_signature` — the cache key."""
    blob = json.dumps(problem_signature(spec, x_shape, dtype, policy),
                      sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode("utf-8")).hexdigest()[:20]


# ---------------------------------------------------------------------------
# ChainPlan (de)serialization
# ---------------------------------------------------------------------------

def serialize_chain_plan(cp: ChainPlan) -> dict:
    return {
        "segments": [
            {"kind": s.kind, "stages": list(s.stages),
             "plan": dataclasses.asdict(s.plan)}
            for s in cp.segments],
        "residual": bool(cp.residual),
        "residual_fused": bool(cp.residual_fused),
        "dtype_bytes": int(cp.dtype_bytes),
        "vmem_budget": int(cp.vmem_budget),
    }


def deserialize_chain_plan(d: dict) -> ChainPlan:
    segments = tuple(
        ChainSegment(kind=s["kind"], stages=tuple(int(i) for i in s["stages"]),
                     plan=BlockPlan(**{k: int(v)
                                       for k, v in s["plan"].items()}))
        for s in d["segments"])
    return ChainPlan(
        segments=segments,
        residual=bool(d["residual"]),
        residual_fused=bool(d["residual_fused"]),
        dtype_bytes=int(d["dtype_bytes"]),
        vmem_budget=int(d["vmem_budget"]),
    )


# ---------------------------------------------------------------------------
# Persistent cache
# ---------------------------------------------------------------------------

class TuneCache:
    """JSON-file-backed map ``key -> {signature, plan, measured_us, ...}``.

    Load tolerates a missing, unreadable or corrupted file (the cache is a
    performance artifact, never a correctness dependency): any parse
    failure yields an EMPTY cache whose next ``save`` rewrites the file.
    ``save`` is atomic (tmp file + ``os.replace``) so a crashed writer
    cannot corrupt a reader."""

    def __init__(self, path: str):
        self.path = path
        self.entries: dict = {}

    @classmethod
    def load(cls, path: str) -> "TuneCache":
        cache = cls(path)
        try:
            with open(path) as f:
                raw = json.load(f)
            if (isinstance(raw, dict) and raw.get("version") == CACHE_VERSION
                    and isinstance(raw.get("entries"), dict)):
                cache.entries = raw["entries"]
        except FileNotFoundError:
            pass
        except (OSError, ValueError):
            pass  # corrupted / unreadable -> recover as empty
        return cache

    def get(self, key: str) -> Optional[dict]:
        entry = self.entries.get(key)
        return entry if isinstance(entry, dict) else None

    def put(self, key: str, entry: dict) -> None:
        self.entries[key] = entry

    def save(self) -> None:
        d = os.path.dirname(self.path)
        if d:
            os.makedirs(d, exist_ok=True)
        tmp = f"{self.path}.tmp.{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump({"version": CACHE_VERSION, "entries": self.entries},
                      f, indent=1, sort_keys=True)
        os.replace(tmp, self.path)


def validate_cached_plan(spec, cp: ChainPlan, x_shape: Sequence[int],
                         key: str, path: str) -> Optional[ChainPlan]:
    """Replayed cache entries must pass planlint before executing verbatim
    (DESIGN.md §8): an entry that became infeasible after a planner/kernel
    change — or was hand-edited — is dropped with a warning naming the
    cache path and the rule ids, and the caller falls back to the analytic
    planner / re-tunes.  A stale cache is a performance artifact, never a
    crash.  Lazy import: analysis sits above this module."""
    from repro.analysis import lint_cached_plan
    rules = lint_cached_plan(spec, cp, x_shape, label=f"tune-cache[{key}]")
    if rules is None:
        return cp
    warnings.warn(
        f"dropping tune-cache entry {key} from {path}: failed planlint "
        f"({rules}); falling back to the analytic plan (the entry is "
        "stale — delete the cache or re-tune)",
        stacklevel=3)
    return None


def lookup_cached_plan(spec, x_shape: Sequence[int], dtype,
                       policy: KernelPolicy) -> Optional[ChainPlan]:
    """Pure cache consult (no measurement): the tuned ChainPlan for this
    problem signature, or None on a miss / undecodable / planlint-rejected
    entry."""
    path = policy.tune_cache or default_cache_path()
    key = problem_key(spec, x_shape, dtype, policy)
    entry = TuneCache.load(path).get(key)
    if entry is None:
        return None
    try:
        cp = deserialize_chain_plan(entry["plan"])
    except (KeyError, TypeError, ValueError):
        return None
    return validate_cached_plan(spec, cp, x_shape, key, path)


# ---------------------------------------------------------------------------
# Candidate enumeration (the pruned ladder the tuner measures)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class _SegGeom:
    """Shapes a segment's kernel sees — what candidate feasibility needs."""
    kind: str
    ho: int
    wo: int
    ci: int        # segment input channels (raw input for fused3)
    c: int         # DW / expanded width (fused segments)
    co: int        # output channels
    stride: int
    hf: int
    wf: int
    g: int         # GEMM rows (pw only)
    residual: bool  # the folded residual rides this segment's kernel


def _segment_geoms(stages, cp: ChainPlan,
                   x_shape: Sequence[int]) -> list[_SegGeom]:
    """Walk the chain shapes segment by segment (same walk as
    ``core/chain.chain_traffic``, duck-typed on the stage objects)."""
    b, h, w, c = (int(v) for v in x_shape)
    geoms = []
    for si, seg in enumerate(cp.segments):
        with_res = bool(cp.residual_fused and si == len(cp.segments) - 1)
        if seg.kind == "fused3":
            ex, d, proj = (stages[i] for i in seg.stages)
            ho, wo = d.out_dims(h, w)
            geoms.append(_SegGeom("fused3", ho, wo, c, ex.features,
                                  proj.features, d.stride, d.hf, d.wf, 0,
                                  with_res))
            h, w, c = ho, wo, proj.features
        elif seg.kind == "fused2":
            d, proj = (stages[i] for i in seg.stages)
            ho, wo = d.out_dims(h, w)
            geoms.append(_SegGeom("fused2", ho, wo, c, c, proj.features,
                                  d.stride, d.hf, d.wf, 0, with_res))
            h, w, c = ho, wo, proj.features
        elif seg.kind == "pw":
            st = stages[seg.stages[0]]
            geoms.append(_SegGeom("pw", h, w, c, 0, st.features, 1, 0, 0,
                                  b * h * w, False))
            c = st.features
        else:  # "dw"
            st = stages[seg.stages[0]]
            ho, wo = st.out_dims(h, w)
            geoms.append(_SegGeom("dw", ho, wo, c, c, c, st.stride, st.hf,
                                  st.wf, 0, False))
            h, w = ho, wo
    return geoms


def segment_candidates(geom: _SegGeom, base: BlockPlan, dtype,
                       vmem_budget: int,
                       max_candidates: int = MAX_SEGMENT_CANDIDATES,
                       ) -> list[BlockPlan]:
    """Up to ``max_candidates`` feasible BlockPlans for one segment, the
    analytic plan first.  Fused segments sweep the (Co panel x row slab)
    grid the analytic ladder prefers the corner of; pw sweeps the GEMM
    G-panel ladder; dw sweeps snapped channel blocks."""
    nb = blocking.dtype_bytes(dtype)
    cands = [base]
    if geom.kind in ("fused2", "fused3"):
        probe = (blocking.plan_separable3_at if geom.kind == "fused3"
                 else blocking.plan_separable_at)
        for cob in blocking.co_candidates(geom.co):
            if len(cands) >= max_candidates:
                break
            for slab_h in blocking.slab_candidates(geom.ho):
                if len(cands) >= max_candidates:
                    break
                if geom.kind == "fused3":
                    p = probe(geom.ho, geom.wo, geom.ci, geom.c, geom.co,
                              block_co=cob, slab_h=slab_h,
                              stride=geom.stride, hf=geom.hf, wf=geom.wf,
                              dtype=dtype, vmem_budget=vmem_budget,
                              residual=geom.residual)
                else:
                    p = probe(geom.ho, geom.wo, geom.c, geom.co,
                              block_co=cob, slab_h=slab_h,
                              stride=geom.stride, hf=geom.hf, wf=geom.wf,
                              dtype=dtype, vmem_budget=vmem_budget,
                              residual=geom.residual)
                if p is not None and p not in cands:
                    cands.append(p)
    elif geom.kind == "pw":
        for bg in blocking.PW_G_CANDIDATES:
            if len(cands) >= max_candidates:
                break
            vb = blocking.pwconv_vmem_bytes(bg, base.block_c, base.block_co,
                                            nb)
            if vb > vmem_budget:
                continue
            p = dataclasses.replace(base, block_g=bg, vmem_bytes=vb)
            if p not in cands:
                cands.append(p)
    else:  # "dw"
        hi = (geom.ho - 1) * geom.stride + geom.hf
        wi = (geom.wo - 1) * geom.stride + geom.wf
        for target in (geom.c, 1024, 512, 256, 128, 64, 32, 16, 8):
            if len(cands) >= max_candidates:
                break
            cb = blocking.snap_channels(min(target, geom.c), geom.c)
            vb = blocking.dwconv2d_vmem_bytes(hi, wi, geom.ho, geom.wo, cb,
                                              geom.hf, geom.wf, nb)
            if vb > vmem_budget:
                continue
            p = BlockPlan(block_c=cb, block_co=0, slab_h=geom.ho, n_slabs=1,
                          halo_rows=0, vmem_bytes=vb, dtype_bytes=nb)
            if p not in cands:
                cands.append(p)
    return cands[:max_candidates]


def _with_segment_plan(cp: ChainPlan, si: int, plan: BlockPlan) -> ChainPlan:
    segments = tuple(
        dataclasses.replace(seg, plan=plan) if i == si else seg
        for i, seg in enumerate(cp.segments))
    return dataclasses.replace(cp, segments=segments)


# ---------------------------------------------------------------------------
# Timing harness
# ---------------------------------------------------------------------------

def measure_run(run, params, x, *, warmup: int = 1,
                repeats: int = 5) -> float:
    """Median wall seconds of ``run(params, x)`` jitted: ``warmup`` calls
    absorb compilation (and interpret-mode tracing), then median-of-k timed
    calls, each synchronized with ``block_until_ready``."""
    fn = jax.jit(run)
    for _ in range(max(warmup, 1)):
        jax.block_until_ready(fn(params, x))
    ts = []
    for _ in range(max(repeats, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(params, x))
        ts.append(time.perf_counter() - t0)
    return float(statistics.median(ts))


# ---------------------------------------------------------------------------
# The tuner
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AutotuneResult:
    """What one autotune consult answered: the plan to execute, whether it
    replayed the cache (``n_measured == 0`` then), and the timings behind
    the decision (microseconds; on a hit, as recorded at tune time)."""
    plan: ChainPlan
    cache_hit: bool
    measured_us: float
    analytic_us: float
    n_measured: int
    key: str
    cache_path: str


def autotune_chain(spec, params, x, *, policy: KernelPolicy,
                   base_plan: ChainPlan,
                   warmup: int = 1, repeats: int = 5,
                   max_candidates: int = MAX_SEGMENT_CANDIDATES,
                   cache: Optional[TuneCache] = None) -> AutotuneResult:
    """Measured plan selection for one declared chain at one input.

    Cache hit: decode and return the stored winner — ZERO measurements.
    Miss: time the analytic ``base_plan``, then coordinate-descend over the
    per-segment candidate ladder (vary one segment, keep the others at the
    incumbent) timing the WHOLE chain per candidate, persist the winner.
    The analytic plan is always among the candidates, so the tuner can
    never do worse than the planner it replaces (up to measurement noise,
    bounded by :data:`REL_IMPROVEMENT`).
    """
    path = policy.tune_cache or default_cache_path()
    if cache is None:
        cache = TuneCache.load(path)
    key = problem_key(spec, x.shape, x.dtype, policy)
    entry = cache.get(key)
    if entry is not None:
        try:
            plan = deserialize_chain_plan(entry["plan"])
        except (KeyError, TypeError, ValueError):
            plan = None  # undecodable entry -> re-tune and overwrite
        if plan is not None:
            plan = validate_cached_plan(spec, plan, x.shape, key, path)
        if plan is not None:
            return AutotuneResult(
                plan=plan, cache_hit=True,
                measured_us=float(entry.get("measured_us", 0.0)),
                analytic_us=float(entry.get("analytic_us", 0.0)),
                n_measured=0, key=key, cache_path=path)

    def timed(cp: ChainPlan) -> float:
        run = lowering.lower(spec, cp, policy)
        return measure_run(run, params, x, warmup=warmup, repeats=repeats)

    t_base = timed(base_plan)
    best, t_best = base_plan, t_base
    n_measured = 1
    geoms = _segment_geoms(spec.stages, base_plan, x.shape)
    for si, geom in enumerate(geoms):
        for cand in segment_candidates(geom, best.segments[si].plan,
                                       x.dtype, policy.vmem_budget,
                                       max_candidates):
            if cand == best.segments[si].plan:
                continue
            cp = _with_segment_plan(best, si, cand)
            t = timed(cp)
            n_measured += 1
            if t < t_best * (1.0 - REL_IMPROVEMENT):
                best, t_best = cp, t
    cache.put(key, {
        "signature": problem_signature(spec, x.shape, x.dtype, policy),
        "plan": serialize_chain_plan(best),
        "measured_us": t_best * 1e6,
        "analytic_us": t_base * 1e6,
        "n_measured": n_measured,
    })
    cache.save()
    return AutotuneResult(plan=best, cache_hit=False,
                          measured_us=t_best * 1e6,
                          analytic_us=t_base * 1e6,
                          n_measured=n_measured, key=key, cache_path=path)
