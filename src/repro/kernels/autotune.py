"""Measured ChainPlan autotuner with a persistent on-disk cache (DESIGN §6).

The analytic planner (``core/chain.plan`` -> ``kernels/blocking.py``) picks
block shapes by VMEM arithmetic alone.  That is the right *feasibility*
filter, but on real hardware the fastest feasible blocking is not always
the first one the preference ladder hits — TVM (the paper's baseline) and
the ARMv8 DWConv follow-up both close that gap with a measurement loop over
a pruned candidate set.  This module is that loop for declared separable
chains:

* **candidate ladder** — per chain segment, enumerate a handful of feasible
  ``BlockPlan``s from the SAME ladders the analytic planner walks
  (``co_candidates`` x ``slab_candidates`` probed via
  ``plan_separable_at``/``plan_separable3_at``, the ``PW_G_CANDIDATES``
  GEMM panel ladder, ``snap_channels`` channel blocks), capped at
  :data:`MAX_SEGMENT_CANDIDATES` per segment;
* **timing harness** — each candidate ``ChainPlan`` is lowered
  (``kernels/lowering.lower`` — which executes plans verbatim, never
  re-plans) and timed jitted with ``block_until_ready``: warmup runs to
  absorb compilation, then median-of-k repeats.  Works on the Pallas
  interpret path in a CPU container and on compiled Pallas on real TPU;
* **persistent cache** — winners are stored in a JSON file keyed on the
  serialized problem signature (spec stages + input shape/dtype + VMEM
  budget + backend fingerprint), so repeated runs — and repeated identical
  layers within a run — replay cache hits with zero re-measurement.  A
  corrupted cache file is treated as empty (recoverable), never a crash.

A candidate only dethrones the incumbent when it wins by more than
:data:`REL_IMPROVEMENT` — on backends where block shapes cannot change the
wall time (the XLA reference path) the analytic plan therefore stays the
winner, and measured noise cannot flip plans between runs.

Entry points: ``core/chain.execute(policy=KernelPolicy(autotune=True))``
measures on the first call and replays the cache afterwards;
``core/chain.plan`` consults :func:`lookup_cached_plan`;
``benchmarks/run.py --autotune`` prints the analytic-vs-measured table.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import statistics
import time
import warnings
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.kernels import blocking, lowering
from repro.kernels.blocking import BlockPlan, ChainPlan, ChainSegment
from repro.kernels.diskstore import VersionedJsonStore
from repro.kernels.policy import KernelPolicy

#: Cache-file schema version; bump on incompatible layout changes (old
#: files then read as empty and re-tune, they are never mis-parsed).
#: v2: problem signatures gained the per-segment dtype policy (DESIGN §7) —
#: v1 keys hashed only the input dtype, so a bf16-streamed winner could
#: replay onto a native fp32 run of the same problem.
#: v3: the stage algebra grew SE and FusedMB stages (DESIGN §10); v2 stage
#: signatures could collide a FusedMB with a PW of the same features.
CACHE_VERSION = 3

#: Feasible candidates measured per chain segment (incl. the analytic plan).
MAX_SEGMENT_CANDIDATES = 8

#: A candidate must beat the incumbent by this relative margin to win —
#: keeps plan churn at measurement-noise level (and keeps the analytic plan
#: the winner on backends where blocks cannot change the wall time).
REL_IMPROVEMENT = 0.02


def default_cache_path() -> str:
    """$REPRO_TUNE_CACHE, else ~/.cache/repro/autotune.json."""
    env = os.environ.get("REPRO_TUNE_CACHE")
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache", "repro",
                        "autotune.json")


# ---------------------------------------------------------------------------
# Problem signature: the cache key schema (DESIGN.md §6)
# ---------------------------------------------------------------------------

def _stage_signature(s) -> dict:
    """Duck-typed stage descriptor, mirroring kernels/lowering.py's
    duck-typing so this module needs no import of core/chain.  Order
    matters: SE is the only stage with ``reduce``; FusedMB has BOTH
    ``features`` and ``stride`` (a PW has only ``features``)."""
    if hasattr(s, "reduce"):
        return {"kind": "se", "reduce": int(s.reduce),
                "activation": s.activation}
    if hasattr(s, "features") and hasattr(s, "stride"):
        return {"kind": "mb", "features": int(s.features),
                "stride": int(s.stride), "hf": int(s.hf), "wf": int(s.wf),
                "padding": s.padding.lower(), "activation": s.activation,
                "bias": bool(s.bias)}
    if hasattr(s, "features"):
        return {"kind": "pw", "features": int(s.features),
                "activation": s.activation, "bias": bool(s.bias)}
    return {"kind": "dw", "stride": int(s.stride), "hf": int(s.hf),
            "wf": int(s.wf), "padding": s.padding.lower(),
            "activation": s.activation, "bias": bool(s.bias)}


def backend_fingerprint(policy: KernelPolicy) -> dict:
    """What makes a measurement transferable: same resolved impl, interpret
    mode, jax backend and device kind (a v5e winner must not replay on a
    v4, nor an interpret-mode winner on compiled Pallas)."""
    dev = jax.devices()[0]
    return {
        "impl": policy.resolved(),
        "interpret": bool(policy.interpret),
        "backend": jax.default_backend(),
        "device_kind": getattr(dev, "device_kind", "unknown"),
        "jax": jax.__version__,
    }


def problem_signature(spec, x_shape: Sequence[int], dtype,
                      policy: KernelPolicy) -> dict:
    """The full serialized problem identity a measurement is valid for."""
    residual = spec.residual
    return {
        "stages": [_stage_signature(s) for s in spec.stages],
        "residual": residual if isinstance(residual, bool) else str(residual),
        "x_shape": [int(v) for v in x_shape],
        "dtype": jnp.dtype(dtype).name,
        # ``dtype`` alone is NOT the precision identity: the dtype policy
        # changes both what was measured (streamed bytes) and what the plan
        # was budgeted at (stream-width VMEM), so a bf16-streamed winner
        # must never replay onto a native run of the same input dtype.
        "dtype_policy": policy.dtype_policy.signature(),
        "vmem_budget": int(policy.vmem_budget),
        "backend": backend_fingerprint(policy),
    }


def problem_key(spec, x_shape: Sequence[int], dtype,
                policy: KernelPolicy) -> str:
    """Stable digest of :func:`problem_signature` — the cache key."""
    blob = json.dumps(problem_signature(spec, x_shape, dtype, policy),
                      sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode("utf-8")).hexdigest()[:20]


# ---------------------------------------------------------------------------
# ChainPlan (de)serialization
# ---------------------------------------------------------------------------

def serialize_chain_plan(cp: ChainPlan) -> dict:
    return {
        "segments": [
            {"kind": s.kind, "stages": list(s.stages),
             "plan": dataclasses.asdict(s.plan)}
            for s in cp.segments],
        "residual": bool(cp.residual),
        "residual_fused": bool(cp.residual_fused),
        "dtype_bytes": int(cp.dtype_bytes),
        "vmem_budget": int(cp.vmem_budget),
    }


def deserialize_chain_plan(d: dict) -> ChainPlan:
    segments = tuple(
        ChainSegment(kind=s["kind"], stages=tuple(int(i) for i in s["stages"]),
                     plan=BlockPlan(**{k: int(v)
                                       for k, v in s["plan"].items()}))
        for s in d["segments"])
    return ChainPlan(
        segments=segments,
        residual=bool(d["residual"]),
        residual_fused=bool(d["residual_fused"]),
        dtype_bytes=int(d["dtype_bytes"]),
        vmem_budget=int(d["vmem_budget"]),
    )


# ---------------------------------------------------------------------------
# Persistent cache
# ---------------------------------------------------------------------------

class TuneCache(VersionedJsonStore):
    """JSON-file-backed map ``key -> {signature, plan, measured_us, ...}``.

    All the durability mechanics live in the shared
    :class:`~repro.kernels.diskstore.VersionedJsonStore` (also the base of
    the runtime plan quarantine): load tolerates a missing file silently and
    WARNS on a corrupted/unreadable one before recovering as empty (the
    cache is a performance artifact, never a correctness dependency), and
    save is merge-on-write + atomic ``os.replace`` — two processes tuning
    disjoint problems into one file both keep their entries."""

    version = CACHE_VERSION


def validate_cached_plan(spec, cp: ChainPlan, x_shape: Sequence[int],
                         key: str, path: str) -> Optional[ChainPlan]:
    """Replayed cache entries must pass planlint before executing verbatim
    (DESIGN.md §8): an entry that became infeasible after a planner/kernel
    change — or was hand-edited — is dropped with a warning naming the
    cache path and the rule ids, and the caller falls back to the analytic
    planner / re-tunes.  A stale cache is a performance artifact, never a
    crash.  Lazy import: analysis sits above this module."""
    from repro.analysis import lint_cached_plan
    rules = lint_cached_plan(spec, cp, x_shape, label=f"tune-cache[{key}]")
    if rules is None:
        return cp
    warnings.warn(
        f"dropping tune-cache entry {key} from {path}: failed planlint "
        f"({rules}); falling back to the analytic plan (the entry is "
        "stale — delete the cache or re-tune)",
        stacklevel=3)
    return None


def lookup_cached_plan(spec, x_shape: Sequence[int], dtype,
                       policy: KernelPolicy) -> Optional[ChainPlan]:
    """Pure cache consult (no measurement): the tuned ChainPlan for this
    problem signature, or None on a miss / undecodable / planlint-rejected
    entry."""
    path = policy.tune_cache or default_cache_path()
    key = problem_key(spec, x_shape, dtype, policy)
    entry = TuneCache.load(path).get(key)
    if entry is None:
        return None
    try:
        cp = deserialize_chain_plan(entry["plan"])
    except (KeyError, TypeError, ValueError):
        return None
    cp = validate_cached_plan(spec, cp, x_shape, key, path)
    if cp is None:
        return None
    if getattr(policy, "on_failure", "raise") == "degrade":
        # a tuned winner that uses a quarantined rung must not replay
        # (DESIGN.md §9) — drop it and let the planner degrade
        from repro.runtime import quarantine  # lazy: runtime sits above
        banned = quarantine.load(quarantine.quarantine_path(policy)) \
            .banned(key)
        if banned and ("unfused" in banned
                       or any(s.kind in banned for s in cp.segments)):
            warnings.warn(
                f"dropping tune-cache entry {key} from {path}: its plan "
                f"uses quarantined rungs ({sorted(banned)} banned); the "
                "analytic planner will degrade around them", stacklevel=3)
            return None
    return cp


# ---------------------------------------------------------------------------
# Candidate enumeration (the pruned ladder the tuner measures)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class _SegGeom:
    """Shapes a segment's kernel sees — what candidate feasibility needs."""
    kind: str
    ho: int
    wo: int
    ci: int        # segment input channels (raw input for fused3/fusedmb)
    c: int         # DW / expanded width (fused segments)
    co: int        # output channels
    stride: int
    hf: int
    wf: int
    g: int         # GEMM rows (pw); SE reduced width (dw_se / se)
    residual: bool  # the folded residual rides this segment's kernel


def _segment_geoms(stages, cp: ChainPlan,
                   x_shape: Sequence[int]) -> list[_SegGeom]:
    """Walk the chain shapes segment by segment (same walk as
    ``core/chain.chain_traffic``, duck-typed on the stage objects)."""
    b, h, w, c = (int(v) for v in x_shape)
    geoms = []
    for si, seg in enumerate(cp.segments):
        with_res = bool(cp.residual_fused and si == len(cp.segments) - 1)
        if seg.kind == "fused3":
            ex, d, proj = (stages[i] for i in seg.stages)
            ho, wo = d.out_dims(h, w)
            geoms.append(_SegGeom("fused3", ho, wo, c, ex.features,
                                  proj.features, d.stride, d.hf, d.wf, 0,
                                  with_res))
            h, w, c = ho, wo, proj.features
        elif seg.kind == "fused2":
            d, proj = (stages[i] for i in seg.stages)
            ho, wo = d.out_dims(h, w)
            geoms.append(_SegGeom("fused2", ho, wo, c, c, proj.features,
                                  d.stride, d.hf, d.wf, 0, with_res))
            h, w, c = ho, wo, proj.features
        elif seg.kind == "fusedmb":
            mb, proj = (stages[i] for i in seg.stages)
            ho, wo = mb.out_dims(h, w)
            geoms.append(_SegGeom("fusedmb", ho, wo, c, mb.features,
                                  proj.features, mb.stride, mb.hf, mb.wf,
                                  0, with_res))
            h, w, c = ho, wo, proj.features
        elif seg.kind == "dw_se":
            d, se = (stages[i] for i in seg.stages)
            ho, wo = d.out_dims(h, w)
            geoms.append(_SegGeom("dw_se", ho, wo, c, c, c, d.stride, d.hf,
                                  d.wf, se.reduce, False))
            h, w = ho, wo
        elif seg.kind == "se":
            se = stages[seg.stages[0]]
            geoms.append(_SegGeom("se", h, w, c, c, c, 1, 0, 0, se.reduce,
                                  False))
        elif seg.kind == "mb":
            mb = stages[seg.stages[0]]
            ho, wo = mb.out_dims(h, w)
            geoms.append(_SegGeom("mb", ho, wo, c, mb.features, mb.features,
                                  mb.stride, mb.hf, mb.wf, 0, False))
            h, w, c = ho, wo, mb.features
        elif seg.kind == "pw":
            st = stages[seg.stages[0]]
            geoms.append(_SegGeom("pw", h, w, c, 0, st.features, 1, 0, 0,
                                  b * h * w, False))
            c = st.features
        else:  # "dw"
            st = stages[seg.stages[0]]
            ho, wo = st.out_dims(h, w)
            geoms.append(_SegGeom("dw", ho, wo, c, c, c, st.stride, st.hf,
                                  st.wf, 0, False))
            h, w = ho, wo
    return geoms


def segment_candidates(geom: _SegGeom, base: BlockPlan, dtype,
                       vmem_budget: int,
                       max_candidates: int = MAX_SEGMENT_CANDIDATES,
                       ) -> list[BlockPlan]:
    """Up to ``max_candidates`` feasible BlockPlans for one segment, the
    analytic plan first.  Fused segments sweep the (Co panel x row slab)
    grid the analytic ladder prefers the corner of; pw sweeps the GEMM
    G-panel ladder; dw sweeps snapped channel blocks."""
    nb = blocking.dtype_bytes(dtype)
    cands = [base]
    if geom.kind in ("fused2", "fused3"):
        probe = (blocking.plan_separable3_at if geom.kind == "fused3"
                 else blocking.plan_separable_at)
        for cob in blocking.co_candidates(geom.co):
            if len(cands) >= max_candidates:
                break
            for slab_h in blocking.slab_candidates(geom.ho):
                if len(cands) >= max_candidates:
                    break
                if geom.kind == "fused3":
                    p = probe(geom.ho, geom.wo, geom.ci, geom.c, geom.co,
                              block_co=cob, slab_h=slab_h,
                              stride=geom.stride, hf=geom.hf, wf=geom.wf,
                              dtype=dtype, vmem_budget=vmem_budget,
                              residual=geom.residual)
                else:
                    p = probe(geom.ho, geom.wo, geom.c, geom.co,
                              block_co=cob, slab_h=slab_h,
                              stride=geom.stride, hf=geom.hf, wf=geom.wf,
                              dtype=dtype, vmem_budget=vmem_budget,
                              residual=geom.residual)
                if p is not None and p not in cands:
                    cands.append(p)
    elif geom.kind == "fusedmb":
        for cob in blocking.co_candidates(geom.co):
            if len(cands) >= max_candidates:
                break
            for slab_h in blocking.slab_candidates(geom.ho):
                if len(cands) >= max_candidates:
                    break
                p = blocking.plan_fused_mb_at(
                    geom.ho, geom.wo, geom.ci, geom.c, geom.co,
                    block_co=cob, slab_h=slab_h, stride=geom.stride,
                    hf=geom.hf, wf=geom.wf, dtype=dtype,
                    vmem_budget=vmem_budget, residual=geom.residual)
                if p is not None and p not in cands:
                    cands.append(p)
    elif geom.kind in ("dw_se", "se", "mb"):
        # no block ladder: dw_se is feasible only at full-channel
        # single-slab residency (anything else is WRONG, not slower), the
        # standalone SE GEMMs are tiny, and the standalone conv is
        # XLA-lowered — the analytic plan is the only candidate
        pass
    elif geom.kind == "pw":
        for bg in blocking.PW_G_CANDIDATES:
            if len(cands) >= max_candidates:
                break
            vb = blocking.pwconv_vmem_bytes(bg, base.block_c, base.block_co,
                                            nb)
            if vb > vmem_budget:
                continue
            p = dataclasses.replace(base, block_g=bg, vmem_bytes=vb)
            if p not in cands:
                cands.append(p)
    else:  # "dw"
        hi = (geom.ho - 1) * geom.stride + geom.hf
        wi = (geom.wo - 1) * geom.stride + geom.wf
        for target in (geom.c, 1024, 512, 256, 128, 64, 32, 16, 8):
            if len(cands) >= max_candidates:
                break
            cb = blocking.snap_channels(min(target, geom.c), geom.c)
            vb = blocking.dwconv2d_vmem_bytes(hi, wi, geom.ho, geom.wo, cb,
                                              geom.hf, geom.wf, nb)
            if vb > vmem_budget:
                continue
            p = BlockPlan(block_c=cb, block_co=0, slab_h=geom.ho, n_slabs=1,
                          halo_rows=0, vmem_bytes=vb, dtype_bytes=nb)
            if p not in cands:
                cands.append(p)
    return cands[:max_candidates]


def _with_segment_plan(cp: ChainPlan, si: int, plan: BlockPlan) -> ChainPlan:
    segments = tuple(
        dataclasses.replace(seg, plan=plan) if i == si else seg
        for i, seg in enumerate(cp.segments))
    return dataclasses.replace(cp, segments=segments)


# ---------------------------------------------------------------------------
# Timing harness
# ---------------------------------------------------------------------------

#: Transient-failure retries per measurement (RESOURCE_EXHAUSTED while a
#: sibling benchmark holds the device, a flaky interpret-mode trace):
#: retried this many times before the failure propagates to the tuner.
MEASURE_RETRIES = 2


def measure_run(run, params, x, *, warmup: int = 1, repeats: int = 5,
                retries: int = MEASURE_RETRIES) -> float:
    """Median wall seconds of ``run(params, x)`` jitted: ``warmup`` calls
    absorb compilation (and interpret-mode tracing), then median-of-k timed
    calls, each synchronized with ``block_until_ready``.

    Robustness (DESIGN.md §9): a classified backend failure
    (``runtime.failures.classify``) during warmup/timing is retried up to
    ``retries`` times — transient device contention must not abort a whole
    tune — then propagates to the caller (``autotune_chain`` folds it into
    the candidate's record).  Unrecognized exceptions propagate immediately.
    A first timed sample more than 10x the median of the rest is discarded
    as a straggler (late compilation, page-in): warmup should absorb it, but
    a deadline-scheduled first call occasionally slips through.
    """
    from repro.runtime import failures as _failures  # runtime sits above

    fn = jax.jit(run)
    for attempt in range(max(retries, 0) + 1):
        try:
            for _ in range(max(warmup, 1)):
                jax.block_until_ready(fn(params, x))
            ts = []
            for _ in range(max(repeats, 1)):
                t0 = time.perf_counter()
                jax.block_until_ready(fn(params, x))
                ts.append(time.perf_counter() - t0)
            break
        except Exception as e:
            if _failures.classify(e) is None or attempt >= max(retries, 0):
                raise
            warnings.warn(
                f"measure_run: transient {type(e).__name__} during "
                f"measurement (attempt {attempt + 1}/{max(retries, 0) + 1}):"
                f" {e}; retrying", stacklevel=2)
    if len(ts) > 2 and ts[0] > 10.0 * statistics.median(ts[1:]):
        ts = ts[1:]  # discard the straggler first sample
    return float(statistics.median(ts))


# ---------------------------------------------------------------------------
# The tuner
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AutotuneResult:
    """What one autotune consult answered: the plan to execute, whether it
    replayed the cache (``n_measured == 0`` then), and the timings behind
    the decision (microseconds; on a hit, as recorded at tune time)."""
    plan: ChainPlan
    cache_hit: bool
    measured_us: float
    analytic_us: float
    n_measured: int
    key: str
    cache_path: str


def autotune_chain(spec, params, x, *, policy: KernelPolicy,
                   base_plan: ChainPlan,
                   warmup: int = 1, repeats: int = 5,
                   max_candidates: int = MAX_SEGMENT_CANDIDATES,
                   cache: Optional[TuneCache] = None) -> AutotuneResult:
    """Measured plan selection for one declared chain at one input.

    Cache hit: decode and return the stored winner — ZERO measurements.
    Miss: time the analytic ``base_plan``, then coordinate-descend over the
    per-segment candidate ladder (vary one segment, keep the others at the
    incumbent) timing the WHOLE chain per candidate, persist the winner.
    The analytic plan is always among the candidates, so the tuner can
    never do worse than the planner it replaces (up to measurement noise,
    bounded by :data:`REL_IMPROVEMENT`).
    """
    path = policy.tune_cache or default_cache_path()
    if cache is None:
        cache = TuneCache.load(path)
    key = problem_key(spec, x.shape, x.dtype, policy)
    entry = cache.get(key)
    if entry is not None:
        try:
            plan = deserialize_chain_plan(entry["plan"])
        except (KeyError, TypeError, ValueError):
            plan = None  # undecodable entry -> re-tune and overwrite
        if plan is not None:
            plan = validate_cached_plan(spec, plan, x.shape, key, path)
        if plan is not None:
            return AutotuneResult(
                plan=plan, cache_hit=True,
                measured_us=float(entry.get("measured_us", 0.0)),
                analytic_us=float(entry.get("analytic_us", 0.0)),
                n_measured=0, key=key, cache_path=path)

    from repro.runtime import failures as _failures  # runtime sits above

    failed: list = []

    def timed(cp: ChainPlan, label: str) -> float:
        run = lowering.lower(spec, cp, policy)
        try:
            return measure_run(run, params, x, warmup=warmup,
                               repeats=repeats)
        except Exception as e:
            # a candidate that cannot even run must lose, not abort the
            # tune — fold the classified failure into the entry's record
            # (unrecognized exceptions still propagate: those are bugs)
            if _failures.classify(e) is None:
                raise
            failed.append({"candidate": label,
                           "error": f"{type(e).__name__}: {e}"[:200]})
            return float("inf")

    t_base = timed(base_plan, "analytic")
    best, t_best = base_plan, t_base
    n_measured = 1
    geoms = _segment_geoms(spec.stages, base_plan, x.shape)
    for si, geom in enumerate(geoms):
        for cand in segment_candidates(geom, best.segments[si].plan,
                                       x.dtype, policy.vmem_budget,
                                       max_candidates):
            if cand == best.segments[si].plan:
                continue
            cp = _with_segment_plan(best, si, cand)
            t = timed(cp, f"seg{si}:{cand.block_c}/{cand.block_co}"
                          f"/{cand.slab_h}")
            n_measured += 1
            if t < t_best * (1.0 - REL_IMPROVEMENT):
                best, t_best = cp, t
    if t_best == float("inf"):
        # every candidate (incl. the analytic plan) failed to measure:
        # nothing to persist — return the analytic plan unpersisted and let
        # execution-time handling (the runtime ladder) deal with it
        warnings.warn(
            f"autotune: every candidate failed to measure for {key} "
            f"({len(failed)} failures, first: "
            f"{failed[0]['error'] if failed else '?'}); returning the "
            "analytic plan unpersisted", stacklevel=2)
        return AutotuneResult(plan=base_plan, cache_hit=False,
                              measured_us=float("inf"),
                              analytic_us=float("inf"),
                              n_measured=n_measured, key=key,
                              cache_path=path)
    entry = {
        "signature": problem_signature(spec, x.shape, x.dtype, policy),
        "plan": serialize_chain_plan(best),
        "measured_us": t_best * 1e6,
        "analytic_us": t_base * 1e6,
        "n_measured": n_measured,
    }
    if failed:
        entry["failed_candidates"] = failed
    cache.put(key, entry)
    cache.save()
    return AutotuneResult(plan=best, cache_hit=False,
                          measured_us=t_best * 1e6,
                          analytic_us=t_base * 1e6,
                          n_measured=n_measured, key=key, cache_path=path)
