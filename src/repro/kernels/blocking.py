"""Unified dtype-aware block planner for the Pallas kernels (DESIGN.md §4).

The paper's core argument — pick blockings that pin the working set at the
fastest memory level and write each output exactly once — used to be
re-derived separately by ``dwconv2d._block_c``, ``separable_fused._snap`` /
``_co_candidates`` / ``_block_sizes`` and ``pwconv``'s fixed grid defaults,
each budgeting at fp32 widths.  This module is the single owner of that
logic:

* **dtype-aware VMEM budgeting** — streamed operands (input slabs, filter
  and weight tiles, output tiles) are costed at ``dtype.itemsize`` bytes;
  only the accumulators are pinned at fp32 (``ACC_BYTES``), matching what
  the kernels actually allocate.  bf16 working sets therefore claim ~2x
  less than the old fp32-only math and the planner can afford larger
  blocks.
* **channel / Co-panel enumeration** — ``snap_channels`` and
  ``co_candidates`` (strictly descending, deduplicated) shared by every
  consumer.
* **spatial row-slab blocking with halo** — ``plan_separable`` adds an
  output-row slab dimension: when the full ``(Ho·Wo, Cob)`` accumulator
  panel cannot fit VMEM, the image is cut into ``n_slabs`` slabs of
  ``slab_h`` output rows whose *input* fetches overlap by
  ``halo_rows = Hf - stride`` rows at each interior seam.  This lifts the
  old ~1.5M-pixel fused-kernel ceiling: any resolution now yields a real
  :class:`BlockPlan` instead of the unfused fallback.

* **whole-chain budgeting** — ``plan_separable3`` budgets the full
  MobileNetV2 inverted residual (PW-expand -> DW -> PW-project) as ONE
  kernel: the expansion GEMM is computed on the fly per row slab inside the
  fused kernel, so the budget adds the raw-input window (at ``Ci``
  channels), the expand-weight tile and the fp32 expanded value to the
  2-stage working set.  ``ChainPlan`` / ``ChainSegment`` are the planner's
  answer for a whole declared stage chain (``core/chain.plan``): which
  contiguous stages fuse, at which blocks — a frozen, hashable, comparable
  unit (the cache key for measured autotuning later).

Consumers: ``kernels/dwconv2d.py`` (``plan_dwconv2d``),
``kernels/separable_fused.py`` + ``kernels/ops.py`` (``plan_separable``,
``plan_separable3``), ``kernels/ops.py::pwconv`` (``plan_pwconv``),
``core/chain.py`` + ``kernels/lowering.py`` (``ChainPlan``), and the
analysis layer (``benchmarks/kernel_vmem.py``,
``benchmarks/roofline_table.py``, ``core/intensity.py`` consumers report
the planner's choices).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp

#: Default HBM->VMEM working-set budget a single kernel may claim. 12 MiB of
#: the ~16 MiB/core leaves headroom for Mosaic's own spills and semaphores.
DEFAULT_VMEM_BUDGET = 12 * 1024 * 1024

#: Accumulators are always fp32 scratch regardless of the activation dtype.
ACC_BYTES = 4

#: TPU lane count — the minor-dim vector width every block snaps to.
LANES = 128


def dtype_bytes(dtype) -> int:
    """Element width the planner budgets streamed operands at."""
    return jnp.dtype(dtype).itemsize


@dataclasses.dataclass(frozen=True)
class BlockPlan:
    """One kernel invocation's block choices + the VMEM claim behind them.

    Which fields a kernel consumes (DESIGN.md §4):

    * ``dwconv2d``          — ``block_c`` only (``slab_h`` == Ho, one slab).
    * ``separable_fused``   — ``block_c``, ``block_co``, ``slab_h`` /
      ``n_slabs`` / ``halo_rows`` (the row-slab grid dimension).
    * ``pwconv``            — ``block_g``, ``block_c`` (= Ci block),
      ``block_co``.

    ``vmem_bytes`` is the claimed working set at these blocks and
    ``dtype_bytes`` the streamed-element width it was budgeted at; both are
    reported by ``benchmarks/kernel_vmem.py``.
    """
    block_c: int            # channel slab (DW lanes / GEMM reduction block)
    block_co: int           # output-channel panel (0: op has no Co dim)
    slab_h: int             # output rows per spatial slab
    n_slabs: int            # ceil(Ho / slab_h)
    halo_rows: int          # input rows re-fetched per interior slab seam
    vmem_bytes: int         # claimed working set at these blocks
    dtype_bytes: int        # streamed-element width budgeted
    block_g: int = 0        # GEMM row-panel (pwconv only)

    def co_panels(self, co: int) -> int:
        """Number of output-channel panels this plan splits ``co`` into."""
        return -(-co // self.block_co) if self.block_co else 1


def snap_channels(cb: int, c: int) -> int:
    """Snap a raw channel-count budget to a usable block: all of ``c``, a
    multiple of 128 lanes, or the tiny-VMEM power-of-two fallback (correct
    everywhere; only lane utilization suffers — DESIGN.md §2)."""
    if c <= cb:
        return c
    if cb >= LANES:
        return (cb // LANES) * LANES
    p = 1
    while p * 2 <= cb:
        p *= 2
    return p


def co_candidates(co: int) -> list[int]:
    """Strictly descending, deduplicated Co-panel candidates: all of Co
    first (single panel — the traffic-optimal case), then multiples of 128,
    then powers of two.  Replaces ``separable_fused._co_candidates``, which
    could emit interleaved/duplicate entries."""
    cands = {co}
    k = ((co - 1) // LANES) * LANES
    while k >= LANES:
        cands.add(k)
        k -= LANES
    p = 64
    while p >= 1:
        if p < co:
            cands.add(p)
        p //= 2
    return sorted(cands, reverse=True)


def slab_candidates(ho: int) -> list[int]:
    """Descending output-row slab heights: the whole image first (no
    slabbing, no halo), then powers of two.  Strictly descending and
    deduplicated like :func:`co_candidates`."""
    cands = {ho}
    p = 1
    while p * 2 < ho:
        p *= 2
    while p >= 1:
        cands.add(p)
        p //= 2
    return sorted(cands, reverse=True)


# ---------------------------------------------------------------------------
# dwconv2d
# ---------------------------------------------------------------------------

def dwconv2d_vmem_bytes(hi: int, wi: int, ho: int, wo: int, cb: int,
                        hf: int = 3, wf: int = 3,
                        itemsize: int = 4) -> int:
    """Working set of ``dwconv2d`` at channel block ``cb``: 2x double-
    buffered input slab + filter tile (streamed at ``itemsize``), fp32
    output accumulator."""
    return cb * (2 * hi * wi * itemsize + hf * wf * itemsize
                 + ho * wo * ACC_BYTES)


def plan_dwconv2d(hi: int, wi: int, ho: int, wo: int, c: int,
                  hf: int = 3, wf: int = 3, *,
                  dtype=jnp.float32,
                  vmem_budget: int = DEFAULT_VMEM_BUDGET) -> BlockPlan:
    """Channel-block plan for the depthwise kernel (replaces
    ``dwconv2d._block_c``, now budgeting at ``dtype.itemsize``)."""
    nb = dtype_bytes(dtype)
    per_c = dwconv2d_vmem_bytes(hi, wi, ho, wo, 1, hf, wf, nb)
    cb = snap_channels(max(1, vmem_budget // max(per_c, 1)), c)
    return BlockPlan(
        block_c=cb, block_co=0, slab_h=ho, n_slabs=1, halo_rows=0,
        vmem_bytes=dwconv2d_vmem_bytes(hi, wi, ho, wo, cb, hf, wf, nb),
        dtype_bytes=nb,
    )


# ---------------------------------------------------------------------------
# fused separable block (DW -> act -> PW)
# ---------------------------------------------------------------------------

def fused_vmem_bytes(wo: int, slab_h: int, cb: int, cob: int,
                     hf: int = 3, wf: int = 3, stride: int = 1,
                     itemsize: int = 4, residual: bool = False) -> int:
    """Working-set bytes of the fused kernel at blocks
    ``(cb, cob, slab_h)``: fp32 accumulator + output tile (+ 2x residual
    tile), and per channel slab the 2x double-buffered input slab, the DW
    intermediate (fp32 value), the filter tile and 2x the PW weight tile.
    The single source of truth for :func:`plan_separable` and
    ``benchmarks/kernel_vmem.py``."""
    slab_hi = (slab_h - 1) * stride + hf
    wiu = (wo - 1) * stride + wf
    out_side = slab_h * wo * cob * (ACC_BYTES + itemsize)
    if residual:
        out_side += 2 * slab_h * wo * cob * itemsize
    per_c = (2 * slab_hi * wiu * itemsize       # input slab, double-buffered
             + hf * wf * itemsize               # DW filter tile
             + slab_h * wo * ACC_BYTES          # DW intermediate (fp32 value)
             + 2 * cob * itemsize)              # PW weight tile, dbl-buffered
    return out_side + cb * per_c


def _fused_plan_at(ho: int, wo: int, c: int, slab_h: int, cob: int,
                   hf: int, wf: int, stride: int, itemsize: int,
                   residual: bool, vmem_budget: int,
                   min_cb: int) -> Optional[int]:
    """Largest snapped channel block >= min_cb fitting the budget, or None."""
    base = fused_vmem_bytes(wo, slab_h, 0, cob, hf, wf, stride, itemsize,
                            residual)
    per_c = fused_vmem_bytes(wo, slab_h, 1, cob, hf, wf, stride, itemsize,
                             residual) - base
    rem = vmem_budget - base
    if rem < per_c:
        return None
    cb = snap_channels(int(rem // per_c), c)
    return cb if cb >= min_cb else None


def plan_separable_at(ho: int, wo: int, c: int, co: int, *,
                      block_co: int, slab_h: int,
                      stride: int = 1, hf: int = 3, wf: int = 3,
                      dtype=jnp.float32,
                      vmem_budget: int = DEFAULT_VMEM_BUDGET,
                      residual: bool = False) -> Optional[BlockPlan]:
    """Feasibility probe at an EXPLICIT ``(block_co, slab_h)`` point: the
    largest channel block that fits the budget there, or None.  This is the
    autotuner's candidate constructor (``kernels/autotune.py``) — the
    analytic :func:`plan_separable` walks the same ladder but stops at the
    first hit; the tuner instead measures several feasible points."""
    nb = dtype_bytes(dtype)
    cb = _fused_plan_at(ho, wo, c, slab_h, block_co, hf, wf, stride, nb,
                        residual, vmem_budget, 1)
    if cb is None:
        return None
    n_slabs = -(-ho // slab_h)
    return BlockPlan(
        block_c=cb, block_co=block_co, slab_h=slab_h, n_slabs=n_slabs,
        halo_rows=max(hf - stride, 0) if n_slabs > 1 else 0,
        vmem_bytes=fused_vmem_bytes(wo, slab_h, cb, block_co, hf, wf,
                                    stride, nb, residual),
        dtype_bytes=nb,
    )


def plan_separable(ho: int, wo: int, c: int, co: int, *,
                   stride: int = 1, hf: int = 3, wf: int = 3,
                   dtype=jnp.float32,
                   vmem_budget: int = DEFAULT_VMEM_BUDGET,
                   residual: bool = False) -> Optional[BlockPlan]:
    """Block plan for the fused separable kernel, or None when nothing fits.

    Preference order (traffic-motivated, DESIGN.md §3):

    1. a **single Co panel** — splitting Co replays the input stream and the
       DW compute per panel, the costliest re-read;
    2. the **largest row slab** — slabbing only re-fetches
       ``halo_rows = Hf - stride`` input rows per interior seam, the
       cheapest re-read, so it is the dimension of last resort *within* a
       Co choice but always preferred over splitting Co;
    3. the **largest channel slab** that still fits, full-lane (>= 128 or
       all of C) if possible, power-of-two fallback otherwise.

    Returns None only when even ``(cb=1, cob=1, slab_h=1)`` exceeds the
    budget — with row slabs there is no resolution-driven ceiling anymore.
    """
    nb = dtype_bytes(dtype)
    halo = max(hf - stride, 0)
    # Co outermost so a single panel always wins over splitting Co; within a
    # panel choice, prefer a full-lane channel block (min_cb pass 1) over a
    # larger slab with degenerate lanes, then take anything that fits.
    for cob in co_candidates(co):
        for min_cb in (min(c, LANES), 1):
            for slab_h in slab_candidates(ho):
                cb = _fused_plan_at(ho, wo, c, slab_h, cob, hf, wf, stride,
                                    nb, residual, vmem_budget, min_cb)
                if cb is None:
                    continue
                n_slabs = -(-ho // slab_h)
                return BlockPlan(
                    block_c=cb, block_co=cob, slab_h=slab_h,
                    n_slabs=n_slabs,
                    halo_rows=halo if n_slabs > 1 else 0,
                    vmem_bytes=fused_vmem_bytes(
                        wo, slab_h, cb, cob, hf, wf, stride, nb, residual),
                    dtype_bytes=nb,
                )
    return None


# ---------------------------------------------------------------------------
# 3-stage fused chain (PW-expand -> DW -> PW-project): expand-on-the-fly
# ---------------------------------------------------------------------------

def fused3_vmem_bytes(wo: int, slab_h: int, ci: int, cb: int, cob: int,
                      hf: int = 3, wf: int = 3, stride: int = 1,
                      itemsize: int = 4, residual: bool = False) -> int:
    """Working-set bytes of the 3-stage fused kernel (expand-on-the-fly) at
    blocks ``(cb, cob, slab_h)`` with raw-input channels ``ci``.

    Relative to :func:`fused_vmem_bytes` the input slab is the RAW input at
    ``ci`` channels (fetched whole per grid cell — it is the expand GEMM's
    A-operand), and each expanded-channel slab adds the expand-weight tile
    ``(ci, cb)`` plus the fp32 expanded value ``(slab_hi, wiu, cb)`` that
    replaces the streamed input as the DW stage's operand.  Single source of
    truth for :func:`plan_separable3` and ``benchmarks/kernel_vmem.py``.
    """
    slab_hi = (slab_h - 1) * stride + hf
    wiu = (wo - 1) * stride + wf
    out_side = slab_h * wo * cob * (ACC_BYTES + itemsize)
    if residual:
        out_side += 2 * slab_h * wo * cob * itemsize
    out_side += 2 * slab_hi * wiu * ci * itemsize  # raw input, dbl-buffered
    per_c = (2 * ci * itemsize                 # expand W tile, dbl-buffered
             + slab_hi * wiu * ACC_BYTES       # expanded value (fp32, VMEM)
             + hf * wf * itemsize              # DW filter tile
             + slab_h * wo * ACC_BYTES         # DW intermediate (fp32 value)
             + 2 * cob * itemsize)             # PW weight tile, dbl-buffered
    return out_side + cb * per_c


def _fused3_plan_at(c: int, ci: int, slab_h: int, cob: int, wo: int,
                    hf: int, wf: int, stride: int, itemsize: int,
                    residual: bool, vmem_budget: int,
                    min_cb: int) -> Optional[int]:
    """Largest snapped expanded-channel block >= min_cb that fits, or None."""
    base = fused3_vmem_bytes(wo, slab_h, ci, 0, cob, hf, wf, stride,
                             itemsize, residual)
    per_c = fused3_vmem_bytes(wo, slab_h, ci, 1, cob, hf, wf, stride,
                              itemsize, residual) - base
    rem = vmem_budget - base
    if rem < per_c:
        return None
    cb = snap_channels(int(rem // per_c), c)
    return cb if cb >= min_cb else None


def plan_separable3_at(ho: int, wo: int, ci: int, c: int, co: int, *,
                       block_co: int, slab_h: int,
                       stride: int = 1, hf: int = 3, wf: int = 3,
                       dtype=jnp.float32,
                       vmem_budget: int = DEFAULT_VMEM_BUDGET,
                       residual: bool = False) -> Optional[BlockPlan]:
    """3-stage analogue of :func:`plan_separable_at`: feasibility probe for
    the expand-on-the-fly kernel at an explicit ``(block_co, slab_h)``."""
    nb = dtype_bytes(dtype)
    cb = _fused3_plan_at(c, ci, slab_h, block_co, wo, hf, wf, stride, nb,
                         residual, vmem_budget, 1)
    if cb is None:
        return None
    n_slabs = -(-ho // slab_h)
    return BlockPlan(
        block_c=cb, block_co=block_co, slab_h=slab_h, n_slabs=n_slabs,
        halo_rows=max(hf - stride, 0) if n_slabs > 1 else 0,
        vmem_bytes=fused3_vmem_bytes(wo, slab_h, ci, cb, block_co, hf, wf,
                                     stride, nb, residual),
        dtype_bytes=nb,
    )


def plan_separable3(ho: int, wo: int, ci: int, c: int, co: int, *,
                    stride: int = 1, hf: int = 3, wf: int = 3,
                    dtype=jnp.float32,
                    vmem_budget: int = DEFAULT_VMEM_BUDGET,
                    residual: bool = False) -> Optional[BlockPlan]:
    """Block plan for the 3-stage fused chain (expand -> DW -> project), or
    None when nothing fits (callers degrade to the 2-stage plan:
    standalone expand GEMM + :func:`plan_separable`, then to unfused).

    ``ci`` is the raw-input channel count, ``c`` the expanded (DW) width and
    ``co`` the projected output width.  Same preference order as
    :func:`plan_separable`: single Co panel > largest row slab > largest
    expanded-channel slab, full-lane if possible.  The expanded intermediate
    dominates the budget (fp32 ``(slab_hi, wiu, cb)`` per reduction step),
    so high resolutions slab earlier than the 2-stage kernel does.
    """
    nb = dtype_bytes(dtype)
    halo = max(hf - stride, 0)
    for cob in co_candidates(co):
        for min_cb in (min(c, LANES), 1):
            for slab_h in slab_candidates(ho):
                cb = _fused3_plan_at(c, ci, slab_h, cob, wo, hf, wf, stride,
                                     nb, residual, vmem_budget, min_cb)
                if cb is None:
                    continue
                n_slabs = -(-ho // slab_h)
                return BlockPlan(
                    block_c=cb, block_co=cob, slab_h=slab_h,
                    n_slabs=n_slabs,
                    halo_rows=halo if n_slabs > 1 else 0,
                    vmem_bytes=fused3_vmem_bytes(
                        wo, slab_h, ci, cb, cob, hf, wf, stride, nb,
                        residual),
                    dtype_bytes=nb,
                )
    return None


# ---------------------------------------------------------------------------
# fused MBConv (full conv -> act -> PW-project): conv-on-the-fly
# ---------------------------------------------------------------------------

def fused_mb_vmem_bytes(wo: int, slab_h: int, ci: int, cb: int, cob: int,
                        hf: int = 3, wf: int = 3, stride: int = 1,
                        itemsize: int = 4, residual: bool = False) -> int:
    """Working-set bytes of the fused-MBConv kernel (full ``hf x wf`` conv
    -> act -> PW-project in one pass) at blocks ``(cb, cob, slab_h)`` with
    raw-input channels ``ci``.

    Like :func:`fused3_vmem_bytes` the raw input window is fetched whole
    (all ``ci`` channels — it is every conv tap's A-operand), but there is
    no expanded-value slab: each reduction step computes the conv
    intermediate directly at ``(slab_h, wo, cb)`` and feeds it to the
    projection GEMM.  The conv filter tile is ``(hf, wf, ci, cb)`` — the
    dense filter replaces the depthwise one + expand weight.  Single source
    of truth for :func:`plan_fused_mb` and the static analyzer.
    """
    slab_hi = (slab_h - 1) * stride + hf
    wiu = (wo - 1) * stride + wf
    out_side = slab_h * wo * cob * (ACC_BYTES + itemsize)
    if residual:
        out_side += 2 * slab_h * wo * cob * itemsize
    out_side += 2 * slab_hi * wiu * ci * itemsize  # raw input, dbl-buffered
    per_c = (2 * hf * wf * ci * itemsize       # conv filter tile, dbl-buffered
             + slab_h * wo * ACC_BYTES         # conv intermediate (fp32 value)
             + 2 * cob * itemsize)             # PW weight tile, dbl-buffered
    return out_side + cb * per_c


def _fused_mb_plan_at(c: int, ci: int, slab_h: int, cob: int, wo: int,
                      hf: int, wf: int, stride: int, itemsize: int,
                      residual: bool, vmem_budget: int,
                      min_cb: int) -> Optional[int]:
    """Largest snapped conv-output channel block >= min_cb that fits."""
    base = fused_mb_vmem_bytes(wo, slab_h, ci, 0, cob, hf, wf, stride,
                               itemsize, residual)
    per_c = fused_mb_vmem_bytes(wo, slab_h, ci, 1, cob, hf, wf, stride,
                                itemsize, residual) - base
    rem = vmem_budget - base
    if rem < per_c:
        return None
    cb = snap_channels(int(rem // per_c), c)
    return cb if cb >= min_cb else None


def plan_fused_mb_at(ho: int, wo: int, ci: int, c: int, co: int, *,
                     block_co: int, slab_h: int,
                     stride: int = 1, hf: int = 3, wf: int = 3,
                     dtype=jnp.float32,
                     vmem_budget: int = DEFAULT_VMEM_BUDGET,
                     residual: bool = False) -> Optional[BlockPlan]:
    """Feasibility probe for the fused-MBConv kernel at an explicit
    ``(block_co, slab_h)`` — the autotuner's candidate constructor."""
    nb = dtype_bytes(dtype)
    cb = _fused_mb_plan_at(c, ci, slab_h, block_co, wo, hf, wf, stride, nb,
                           residual, vmem_budget, 1)
    if cb is None:
        return None
    n_slabs = -(-ho // slab_h)
    return BlockPlan(
        block_c=cb, block_co=block_co, slab_h=slab_h, n_slabs=n_slabs,
        halo_rows=max(hf - stride, 0) if n_slabs > 1 else 0,
        vmem_bytes=fused_mb_vmem_bytes(wo, slab_h, ci, cb, block_co, hf, wf,
                                       stride, nb, residual),
        dtype_bytes=nb,
    )


def plan_fused_mb(ho: int, wo: int, ci: int, c: int, co: int, *,
                  stride: int = 1, hf: int = 3, wf: int = 3,
                  dtype=jnp.float32,
                  vmem_budget: int = DEFAULT_VMEM_BUDGET,
                  residual: bool = False) -> Optional[BlockPlan]:
    """Block plan for the fused-MBConv pass (full conv -> act -> PW-project
    in ONE kernel), or None when nothing fits (callers degrade to a
    standalone XLA conv + standalone PW).  ``ci`` is the raw-input width,
    ``c`` the conv-output (expanded) width, ``co`` the projected width.
    Same preference order as :func:`plan_separable3`."""
    nb = dtype_bytes(dtype)
    halo = max(hf - stride, 0)
    for cob in co_candidates(co):
        for min_cb in (min(c, LANES), 1):
            for slab_h in slab_candidates(ho):
                cb = _fused_mb_plan_at(c, ci, slab_h, cob, wo, hf, wf,
                                       stride, nb, residual, vmem_budget,
                                       min_cb)
                if cb is None:
                    continue
                n_slabs = -(-ho // slab_h)
                return BlockPlan(
                    block_c=cb, block_co=cob, slab_h=slab_h,
                    n_slabs=n_slabs,
                    halo_rows=halo if n_slabs > 1 else 0,
                    vmem_bytes=fused_mb_vmem_bytes(
                        wo, slab_h, ci, cb, cob, hf, wf, stride, nb,
                        residual),
                    dtype_bytes=nb,
                )
    return None


def plan_mb(ho: int, wo: int, ci: int, c: int, hf: int = 3, wf: int = 3, *,
            stride: int = 1, dtype=jnp.float32,
            vmem_budget: int = DEFAULT_VMEM_BUDGET) -> BlockPlan:
    """Standalone dense-conv segment (the fused-MBConv degradation target).
    It lowers to the XLA convolution — the dense conv is MXU-shaped as-is;
    the Pallas win is fusing the projection — so the plan records geometry
    for traffic/telemetry and claims zero Pallas VMEM."""
    return BlockPlan(
        block_c=c, block_co=0, slab_h=ho, n_slabs=1, halo_rows=0,
        vmem_bytes=0, dtype_bytes=dtype_bytes(dtype),
    )


# ---------------------------------------------------------------------------
# squeeze-excite: DW + SE-epilogue fused pass, and the standalone two-GEMM
# ---------------------------------------------------------------------------

def dw_se_vmem_bytes(hiu: int, wiu: int, ho: int, wo: int, c: int,
                     c_se: int, hf: int = 3, wf: int = 3,
                     itemsize: int = 4) -> int:
    """Working set of the DW + SE-epilogue kernel.  The SE gate mixes ALL
    channels of the pooled DW output, so the pass requires full-channel,
    full-spatial residency: 2x input window + filter at all ``c`` channels,
    the fp32 DW accumulator + output tile, and the (tiny) gate weights."""
    return (c * (2 * hiu * wiu * itemsize + hf * wf * itemsize
                 + ho * wo * (ACC_BYTES + itemsize))
            + 4 * c * c_se * itemsize          # w1 + w2 tiles, dbl-buffered
            + 2 * (c_se + c) * itemsize)       # b1 + b2 vectors


def plan_dw_se(hiu: int, wiu: int, ho: int, wo: int, c: int, c_se: int,
               hf: int = 3, wf: int = 3, *,
               dtype=jnp.float32,
               vmem_budget: int = DEFAULT_VMEM_BUDGET
               ) -> Optional[BlockPlan]:
    """Plan for the fused DW + SE-epilogue pass, or None when the
    full-channel working set exceeds the budget (callers degrade to a
    standalone DW + a standalone SE two-GEMM pass).  Unlike the other fused
    planners there is no block ladder to walk: the squeeze FC needs the
    whole pooled channel vector, so partial-channel residency is not a
    degraded plan — it is a wrong one.  ``block_g`` carries ``c_se``."""
    nb = dtype_bytes(dtype)
    need = dw_se_vmem_bytes(hiu, wiu, ho, wo, c, c_se, hf, wf, nb)
    if need > vmem_budget:
        return None
    return BlockPlan(
        block_c=c, block_co=0, slab_h=ho, n_slabs=1, halo_rows=0,
        vmem_bytes=need, dtype_bytes=nb, block_g=c_se,
    )


def plan_se(b: int, c: int, c_se: int, *, dtype=jnp.float32,
            vmem_budget: int = DEFAULT_VMEM_BUDGET) -> BlockPlan:
    """Standalone squeeze-excite segment: global pool + two tiny GEMMs
    (reduce, expand) + sigmoid scale.  The GEMMs run through the pwconv
    kernel at its own planned blocks; the claim here is the larger of the
    two GEMM working sets.  ``block_g`` carries ``c_se``."""
    nb = dtype_bytes(dtype)
    p1 = plan_pwconv(b, c, c_se, dtype=dtype, vmem_budget=vmem_budget)
    p2 = plan_pwconv(b, c_se, c, dtype=dtype, vmem_budget=vmem_budget)
    return BlockPlan(
        block_c=c, block_co=0, slab_h=1, n_slabs=1, halo_rows=0,
        vmem_bytes=max(p1.vmem_bytes, p2.vmem_bytes),
        dtype_bytes=nb, block_g=c_se,
    )


# ---------------------------------------------------------------------------
# whole-chain plan schema (core/chain.plan -> kernels/lowering.lower)
# ---------------------------------------------------------------------------

#: Segment kinds a chain lowers to.  ``fused3`` = one kernel pass for
#: PW-expand -> DW -> PW-project (expand-on-the-fly); ``fused2`` = one pass
#: for DW -> PW (the PR-2 kernel); ``fusedmb`` = one pass for a full
#: ``hf x wf`` conv -> act -> PW-project (the fused-MBConv block);
#: ``dw_se`` = one pass for DW with the squeeze-excite gate applied as an
#: in-kernel epilogue; ``pw`` / ``dw`` = standalone kernels; ``se`` = the
#: standalone squeeze-excite two-GEMM pass; ``mb`` = a standalone dense
#: conv (XLA-lowered — the fused-MBConv degradation target).
SEGMENT_KINDS = ("fused3", "fused2", "fusedmb", "dw_se", "pw", "dw", "se",
                 "mb")

#: Segment kinds whose kernels take a residual operand (the chain residual
#: can fold into their final store).
FUSED_KINDS = ("fused3", "fused2", "fusedmb")


@dataclasses.dataclass(frozen=True)
class ChainSegment:
    """One lowering unit of a stage chain: which contiguous spec stages run
    as one kernel pass, and at which block shapes."""
    kind: str                      # one of SEGMENT_KINDS
    stages: tuple[int, ...]        # indices into the spec's stage tuple
    plan: BlockPlan                # block choices for this segment's kernel

    def __post_init__(self):
        assert self.kind in SEGMENT_KINDS, self.kind


@dataclasses.dataclass(frozen=True)
class ChainPlan:
    """The planner's answer for a whole declared stage chain (DESIGN.md §5).

    Produced by ``core/chain.plan`` and consumed by
    ``kernels/lowering.lower``; frozen + hashable so it is a cacheable,
    comparable unit (the key for measured autotuning later).

    ``residual``: the spec's residual connection is active at these shapes
    (stride product 1, c_out == c_in).  ``residual_fused``: it is folded
    into the final fused segment's kernel pass (otherwise the lowering adds
    it as a separate elementwise op).
    """
    segments: tuple[ChainSegment, ...]
    residual: bool
    residual_fused: bool
    dtype_bytes: int
    vmem_budget: int

    @property
    def n_kernel_passes(self) -> int:
        # a standalone SE segment runs two GEMM passes (reduce + expand);
        # a standalone "mb" conv lowers to XLA but still counts as one pass
        # of HBM round-trip; every other segment is one kernel pass.
        n = sum(2 if s.kind == "se" else 1 for s in self.segments)
        return n + (1 if self.residual and not self.residual_fused else 0)

    @property
    def fully_fused(self) -> bool:
        """The whole chain (incl. any residual) runs as ONE kernel pass."""
        return len(self.segments) == 1 and self.segments[0].kind in (
            FUSED_KINDS) and (self.residual_fused or not self.residual)


# ---------------------------------------------------------------------------
# pwconv (output-stationary GEMM)
# ---------------------------------------------------------------------------

def pwconv_vmem_bytes(bg: int, bci: int, bco: int, itemsize: int = 4) -> int:
    """Working set of the RTRD GEMM: fp32 accumulator + 2x double-buffered
    streamed A/B tiles at the activation width."""
    return bg * bco * ACC_BYTES + 2 * (bg * bci + bci * bco) * itemsize


#: G-panel ladder the GEMM planner walks (and the autotuner measures over).
PW_G_CANDIDATES = (1024, 512, 256, 128, 64, 32, 16, 8)


def plan_pwconv(g: int, ci: int, co: int, *,
                dtype=jnp.float32,
                vmem_budget: int = DEFAULT_VMEM_BUDGET) -> BlockPlan:
    """Grid plan for the pointwise GEMM (owns what used to be ``pwconv``'s
    hard-coded 256^3 defaults).  Co/Ci blocks stay MXU-aligned multiples of
    128; the G panel grows when the dtype is narrow (bf16 tiles cost half,
    so the same budget affords a 2x taller output panel)."""
    nb = dtype_bytes(dtype)
    bco = bci = 2 * LANES
    for bg in PW_G_CANDIDATES:
        if pwconv_vmem_bytes(bg, bci, bco, nb) <= vmem_budget:
            break
    return BlockPlan(
        block_c=bci, block_co=bco, slab_h=0, n_slabs=1, halo_rows=0,
        vmem_bytes=pwconv_vmem_bytes(bg, bci, bco, nb),
        dtype_bytes=nb, block_g=bg,
    )
