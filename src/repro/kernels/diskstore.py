"""Shared versioned JSON disk store (tune cache + runtime plan quarantine).

``kernels/autotune.TuneCache`` and ``runtime/quarantine.Quarantine`` persist
the same shape of artifact — a ``{key: entry}`` map keyed on a problem
signature digest with a backend fingerprint baked in — and need the same
durability discipline, so they share this one implementation:

* **load** tolerates a missing file silently, but a corrupted or unreadable
  one emits a warning naming the path and the parse error (a mystery full
  re-tune is worse than a warning) and recovers as EMPTY — the store is a
  performance/robustness artifact, never a correctness dependency;
* **save** is merge-on-write: re-read whatever another process persisted
  since our load, union the entry maps (our entries win conflicts), then
  atomic ``tmp + os.replace`` — two concurrent writers cannot clobber each
  other's entries and a crashed writer cannot corrupt a reader;
* a ``version`` class attribute gates the schema: a file written at a
  different version reads as empty (and is ignored by the merge), so layout
  changes re-tune instead of mis-parsing.
"""
from __future__ import annotations

import json
import os
import warnings


class VersionedJsonStore:
    """JSON-file-backed ``{key: entry}`` map with versioned, merge-on-write
    atomic persistence.  Subclasses pin ``version`` and add typed accessors."""

    version: int = 1

    def __init__(self, path: str):
        self.path = path
        self.entries: dict = {}

    @classmethod
    def load(cls, path: str) -> "VersionedJsonStore":
        store = cls(path)
        store.entries = cls._read(path, warn=True)
        return store

    @classmethod
    def _read(cls, path: str, *, warn: bool) -> dict:
        try:
            with open(path) as f:
                raw = json.load(f)
        except FileNotFoundError:
            return {}
        except (OSError, ValueError) as e:
            if warn:
                warnings.warn(
                    f"{cls.__name__}: could not read {path} "
                    f"({type(e).__name__}: {e}); recovering as empty — "
                    "entries persisted there are lost until re-recorded",
                    stacklevel=3)
            return {}
        if (isinstance(raw, dict) and raw.get("version") == cls.version
                and isinstance(raw.get("entries"), dict)):
            return raw["entries"]
        return {}

    def get(self, key: str):
        entry = self.entries.get(key)
        return entry if isinstance(entry, dict) else None

    def put(self, key: str, entry: dict) -> None:
        self.entries[key] = entry

    def save(self) -> None:
        d = os.path.dirname(self.path)
        if d:
            os.makedirs(d, exist_ok=True)
        # merge-on-write: a concurrent writer's entries survive; ours win
        # conflicts (we hold the newest measurement/failure for our keys)
        disk = self._read(self.path, warn=False)
        self.entries = {**disk, **self.entries}
        tmp = f"{self.path}.tmp.{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump({"version": self.version, "entries": self.entries},
                      f, indent=1, sort_keys=True)
        os.replace(tmp, self.path)
