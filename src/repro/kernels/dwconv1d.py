"""Causal depthwise 1-D convolution Pallas kernel (SSM/Mamba conv preact).

This is the paper's DWConv design re-specialized to the sequence axis, which
is where depthwise convolution actually appears in the assigned LM
architectures (hymba's Mamba heads, xLSTM conv preactivation; K = 3..5).

Design (same levers as dwconv2d.py):
* grid ``(B, D/Db, L/Lb)`` — channel blocks parallel (paper's channel-outer
  loop), sequence blocks innermost & sequential.
* filter tile (K, Db) resident in VMEM for the whole sequence sweep.
* causal halo: instead of overlapping input blocks (not expressible with
  blocked BlockSpecs), a ``(K-1, Db)`` VMEM scratch carries the last K-1
  input rows across sequence steps — zero-initialized at l==0 (causal
  zero-pad). Grid iteration on a TensorCore is sequential over the
  ``arbitrary`` axis, so the carry is well-defined.
* output block written exactly once (store-once, Alg. 4 lines 29-34).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _dw1d_kernel(x_ref, f_ref, out_ref, carry_ref, *, k: int, out_dtype):
    """Blocks: x (1, Lb, Db); f (K, Db); out (1, Lb, Db); carry (K-1, Db)."""
    l_idx = pl.program_id(2)

    @pl.when(l_idx == 0)
    def _reset():  # causal zero left-pad at sequence start
        carry_ref[...] = jnp.zeros_like(carry_ref)

    x = x_ref[0].astype(jnp.float32)                    # (Lb, Db)
    f = f_ref[...].astype(jnp.float32)                  # (K, Db) resident
    lb = x.shape[0]
    xp = jnp.concatenate([carry_ref[...], x], axis=0)   # (Lb + K - 1, Db)
    acc = jnp.zeros_like(x)
    for i in range(k):                                  # unrolled taps
        acc = acc + xp[i : i + lb, :] * f[i][None, :]
    out_ref[0] = acc.astype(out_dtype)                  # single store
    if k > 1:
        carry_ref[...] = x[lb - (k - 1) :, :]           # halo for next block


@functools.partial(
    jax.jit, static_argnames=("block_l", "block_d", "interpret")
)
def dwconv1d_causal_pallas(
    x: jax.Array,
    f: jax.Array,
    *,
    block_l: int = 1024,
    block_d: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """x: (B, L, D); f: (K, D) -> (B, L, D), causal (zero left-pad)."""
    b, l, d = x.shape
    k, df = f.shape
    assert d == df, (x.shape, f.shape)

    bl = min(block_l, l)
    bd = min(block_d, d)
    pad_l = (-l) % bl
    pad_d = (-d) % bd
    if pad_l or pad_d:
        x = jnp.pad(x, ((0, 0), (0, pad_l), (0, pad_d)))
        f = jnp.pad(f, ((0, 0), (0, pad_d)))
    lp, dp = l + pad_l, d + pad_d

    kernel = functools.partial(_dw1d_kernel, k=k, out_dtype=x.dtype)
    try:
        compiler_params = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        )
    except AttributeError:
        compiler_params = pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        )

    out = pl.pallas_call(
        kernel,
        grid=(b, dp // bd, lp // bl),
        in_specs=[
            pl.BlockSpec((1, bl, bd), lambda i, j, s: (i, s, j)),
            pl.BlockSpec((k, bd), lambda i, j, s: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, bl, bd), lambda i, j, s: (i, s, j)),
        out_shape=jax.ShapeDtypeStruct((b, lp, dp), x.dtype),
        scratch_shapes=[pltpu.VMEM((max(k - 1, 1), bd), jnp.float32)],
        compiler_params=compiler_params,
        interpret=interpret,
    )(x, f)
    return out[:, :l, :d]
