"""Depthwise 2-D convolution Pallas kernel (paper Alg. 4, TPU adaptation).

Paper mechanism → TPU mapping (DESIGN.md §2):

* channel-outermost parallel loop (``i'``)  → grid over channel blocks, with
  ``dimension_semantics="parallel"`` — each TensorCore owns a channel slab, so
  its filter working set is ``Hf·Wf·Cblk`` (the 1/p scalability argument).
* filter register tile pinned across all output blocks → the ``(Hf, Wf, Cblk)``
  filter tile is fetched to VMEM once per grid cell and reused for the whole
  spatial extent.
* output block loaded/stored once (Alg. 4 lines 14-19 / 29-34) → the output
  tile is accumulated in a VMEM fp32 buffer and written to HBM exactly once.
* the 4-channel NEON SIMD dimension → the 128-lane minor dimension (NHWC).

DWConv has no matmul structure, so this is a pure-VPU kernel: an unrolled
``Hf×Wf`` shift-and-FMA over the spatial extent, vectorized across lanes
(channels) and sublanes (rows). HBM traffic is the information floor: input
read once, filter once, output written once — AI = Hf·Wf/(1+1/…) FLOPs/byte,
the paper's T^DW bound with the block terms at their VMEM-scale limits.

Stride > 1 is handled with static strided slices on the H/W (non-minor) dims.
Padding is applied by the wrapper (ops.py) so the kernel sees VALID geometry.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import blocking
from repro.kernels.gridspec import (BlockRef, KernelModel,
                                    in_specs_from_model,
                                    out_spec_from_model)


def dw_kernel_model(*, b: int, hiu: int, wiu: int, ho: int, wo: int, c: int,
                    block_c: int, hf: int, wf: int, itemsize: int,
                    out_itemsize: int) -> KernelModel:
    """The exact grid/BlockSpec geometry ``dwconv2d_pallas`` lowers to —
    consumed by both the kernel and the static analyzer (DESIGN.md §8).
    ``hiu``/``wiu`` are the input rows/cols actually consumed; shapes are
    the channel-padded shapes handed to ``pl.pallas_call``."""
    cb = block_c
    cp = c + (-c) % cb
    return KernelModel(
        name="dwconv2d",
        grid=(b, cp // cb),
        dimension_semantics=("parallel", "parallel"),
        inputs=(
            BlockRef("x", (b, hiu, wiu, cp), (1, hiu, wiu, cb),
                     lambda i, j: (i, 0, 0, j), itemsize),
            BlockRef("f", (hf, wf, cp), (hf, wf, cb),
                     lambda i, j: (0, 0, j), itemsize),
        ),
        output=BlockRef("out", (b, ho, wo, cp), (1, ho, wo, cb),
                        lambda i, j: (i, 0, 0, j), out_itemsize),
        value_bytes=ho * wo * cb * 4,              # fp32 jnp accumulator
    )


def _dw2d_kernel(x_ref, f_ref, out_ref, *, hf: int, wf: int, stride: int,
                 out_dtype):
    """Blocks: x (1, Hi, Wi, Cb); f (Hf, Wf, Cb); out (1, Ho, Wo, Cb)."""
    _, ho, wo, _ = out_ref.shape
    x = x_ref[0].astype(jnp.float32)           # (Hi, Wi, Cb) — read once
    f = f_ref[...].astype(jnp.float32)         # filter tile: VMEM-resident
    acc = jnp.zeros(out_ref.shape[1:], jnp.float32)
    s = stride
    for n in range(hf):                        # unrolled taps (Hf·Wf ≤ 25)
        for m in range(wf):
            # strided window of the input block for tap (n, m):
            win = jax.lax.slice(
                x,
                (n, m, 0),
                (n + (ho - 1) * s + 1, m + (wo - 1) * s + 1, x.shape[2]),
                (s, s, 1),
            )
            acc = acc + win * f[n, m][None, None, :]
    out_ref[0] = acc.astype(out_dtype)         # single store (lines 29-34)


@functools.partial(jax.jit, static_argnames=("stride", "interpret", "block_c",
                                             "vmem_budget", "out_dtype"))
def dwconv2d_pallas(
    x: jax.Array,
    f: jax.Array,
    *,
    stride: int = 1,
    block_c: int | None = None,
    vmem_budget: int = blocking.DEFAULT_VMEM_BUDGET,
    interpret: bool = False,
    out_dtype: str | None = None,
) -> jax.Array:
    """x: (B, Hi, Wi, C); f: (Hf, Wf, C) -> (B, Ho, Wo, C). VALID geometry.

    An explicit ``block_c`` (e.g. a ``ChainSegment.plan``'s or a measured
    autotuner winner's) is executed verbatim; ``None`` re-plans at
    ``vmem_budget``.  ``out_dtype`` (dtype NAME, static) selects the store
    width of the single output write (DESIGN.md §7); ``None`` stores at
    ``x.dtype``; accumulation is fp32 either way."""
    odt = jnp.dtype(out_dtype) if out_dtype is not None else x.dtype
    b, hi, wi, c = x.shape
    hf, wf, cf = f.shape
    assert c == cf, (x.shape, f.shape)
    ho = (hi - hf) // stride + 1
    wo = (wi - wf) // stride + 1
    assert ho >= 1 and wo >= 1, "input smaller than filter"

    if block_c is None:
        # dtype-aware channel-block plan (kernels/blocking.py owns the math)
        block_c = blocking.plan_dwconv2d(
            hi, wi, ho, wo, c, hf, wf, dtype=x.dtype,
            vmem_budget=vmem_budget).block_c
    cb = block_c
    pad = (-c) % cb
    if pad:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, 0), (0, pad)))
        f = jnp.pad(f, ((0, 0), (0, 0), (0, pad)))
    cp = c + pad

    # Input rows/cols actually consumed (drop the VALID remainder so block
    # shapes match exactly).
    hiu = (ho - 1) * stride + hf
    wiu = (wo - 1) * stride + wf
    x = x[:, :hiu, :wiu, :]

    # Grid and BlockSpecs come from the kernel model — the same object the
    # static analyzer (repro.analysis) checks (DESIGN.md §8).
    model = dw_kernel_model(
        b=b, hiu=hiu, wiu=wiu, ho=ho, wo=wo, c=c, block_c=cb, hf=hf, wf=wf,
        itemsize=x.dtype.itemsize, out_itemsize=odt.itemsize,
    )
    for arr, br in zip((x, f), model.inputs):
        assert arr.shape == br.array_shape, (br.name, arr.shape,
                                             br.array_shape)

    kernel = functools.partial(
        _dw2d_kernel, hf=hf, wf=wf, stride=stride, out_dtype=odt
    )
    try:
        compiler_params = pltpu.CompilerParams(
            dimension_semantics=model.dimension_semantics
        )
    except AttributeError:
        compiler_params = pltpu.TPUCompilerParams(
            dimension_semantics=model.dimension_semantics
        )

    out = pl.pallas_call(
        kernel,
        grid=model.grid,
        in_specs=in_specs_from_model(model),
        out_specs=out_spec_from_model(model),
        out_shape=jax.ShapeDtypeStruct(model.output.array_shape, odt),
        compiler_params=compiler_params,
        interpret=interpret,
    )(x, f)
    return out[..., :c]
