"""Shared bias + activation epilogue for every kernel and oracle.

One implementation of the op-tail semantics (add bias, apply activation)
used by the pure-jnp oracles (``ref.py``), the Pallas kernel bodies
(``pwconv.py``, ``separable_fused.py`` — the same jnp ops trace inside a
kernel), and the chain lowering's unfused fallback (``lowering.py``).  It
was previously a private ``ref._epilogue`` that ``ops.separable_fused``'s
fallback path reached into, duplicated once more inside ``pwconv``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

#: Activations every op in this package accepts (all map 0 -> 0, which the
#: fused expand-on-the-fly path relies on: zero SAME-padding pixels stay
#: zero through a bias-free expansion — see kernels/separable_fused.py).
ACTIVATIONS = ("relu", "relu6", "gelu", "silu")


def apply_epilogue(y, bias=None, activation: Optional[str] = None):
    """``y + bias`` then ``activation(y)``; bias broadcast in ``y.dtype``."""
    if bias is not None:
        y = y + bias.astype(y.dtype)
    if activation is None:
        return y
    if activation == "relu":
        return jnp.maximum(y, 0.0)
    if activation == "relu6":
        return jnp.clip(y, 0.0, 6.0)
    if activation == "gelu":
        return jax.nn.gelu(y)
    if activation == "silu":
        return jax.nn.silu(y)
    raise ValueError(f"unknown activation {activation!r}")
