"""Fused-MBConv Pallas kernel: full HfxWf conv -> act -> PW-project GEMM in
ONE pass (the EfficientNet-Lite edge block, DESIGN.md §10).

The fused-MBConv block replaces PW-expand + DW with a single dense
convolution straight to the expanded width, then projects back down with a
1x1 conv.  Composed through HBM the expanded tensor — ``expand`` times the
input — takes a full round-trip purely as an artifact of op granularity,
exactly the paper's argument for the separable pair.  This kernel computes

    conv(HfxWf, stride, Ci -> C) (+ bias) -> activation -> PW GEMM
    (+ PW bias, activation, optional residual add)

in one grid pass: each reduction step materializes one conv-output channel
slab as a VMEM fp32 value and immediately feeds it to the output-stationary
projection GEMM; the expanded tensor never exists in HBM.

Grid and residency (mirrors ``separable_fused_pallas``'s expand-on-the-fly
structure):

* grid ``(B, n_slabs, Co/Cob, C/Cb)`` with the conv-output channel
  reduction **innermost** and the output BlockSpec ignoring it — the fp32
  accumulator ``(slab_h*Wo, Cob)`` stays VMEM-resident across the whole
  reduction and is stored exactly once.
* the input window carries ALL ``Ci`` raw channels (it is every conv tap's
  A-operand), fetched with ``pl.unblocked`` element-offset indexing per row
  slab — adjacent slabs re-read the ``Hf - stride`` row halo.
* per reduction step, the conv runs as ``Hf*Wf`` tap GEMMs:
  ``window(slab_h, Wo, Ci) . f[n, m] (Ci, Cb)`` accumulated in fp32 (MXU
  work — unlike the depthwise taps these contract over ``Ci``), then
  bias + activation, then the ``(slab_h*Wo, Cb) @ (Cb, Cob)`` projection.

Unlike the 3-stage separable fusion, a conv **bias is allowed**: SAME
padding is consumed by the conv taps BEFORE the bias is added to the conv
output, so padded input pixels never meet the bias (the bias-free
restriction on fused PW-expansions does not apply here).

All block choices come from ``kernels.blocking.plan_fused_mb``; when even
the minimal plan exceeds the budget the planner returns None and
``core/chain.plan`` degrades to a standalone XLA conv (segment kind
``mb``) + standalone PW.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import blocking
from repro.kernels.epilogue import apply_epilogue as _epilogue
from repro.kernels.gridspec import (BlockRef, KernelModel,
                                    in_specs_from_model,
                                    out_spec_from_model)


def fused_mb_kernel_model(*, b: int, ho: int, wo: int, c_in: int, c: int,
                          co: int, hf: int, wf: int, stride: int,
                          block_c: int, block_co: int, slab_h: int,
                          itemsize: int, out_itemsize: int,
                          has_mb_bias: bool, has_pw_bias: bool,
                          has_residual: bool) -> KernelModel:
    """The exact grid/BlockSpec geometry ``fused_mbconv_pallas`` lowers to
    at these blocks — consumed by BOTH the kernel and ``repro.analysis``
    (DESIGN.md §8).  ``c_in`` is the raw input width, ``c`` the conv-output
    (expanded) width, ``co`` the projected width.  Shapes are the PADDED
    shapes handed to ``pl.pallas_call``."""
    cb, cob = block_c, block_co
    sh = min(slab_h, ho)
    n_slabs = -(-ho // sh)
    ho_p = n_slabs * sh
    slab_hi = (sh - 1) * stride + hf
    wiu = (wo - 1) * stride + wf
    pad_c = (-c) % cb
    pad_co = (-co) % cob
    cp, cop = c + pad_c, co + pad_co
    nk = cp // cb
    rows_in = (ho_p - 1) * stride + hf

    inputs = [BlockRef(
        "x", (b, rows_in, wiu, c_in), (1, slab_hi, wiu, c_in),
        lambda i, s, j, k, sh=sh, st=stride: (i, s * sh * st, 0, 0),
        itemsize, unblocked=True)]
    inputs.append(BlockRef("mb_f", (hf, wf, c_in, cp), (hf, wf, c_in, cb),
                           lambda i, s, j, k: (0, 0, 0, k), itemsize))
    if has_mb_bias:
        inputs.append(BlockRef("mb_bias", (1, cp), (1, cb),
                               lambda i, s, j, k: (0, k), itemsize))
    inputs.append(BlockRef("pw_w", (cp, cop), (cb, cob),
                           lambda i, s, j, k: (k, j), itemsize))
    if has_pw_bias:
        inputs.append(BlockRef("pw_bias", (1, cop), (1, cob),
                               lambda i, s, j, k: (0, j), itemsize))
    if has_residual:
        inputs.append(BlockRef("residual", (b, ho_p, wo, cop),
                               (1, sh, wo, cob),
                               lambda i, s, j, k: (i, s, 0, j), itemsize))
    out_ref = BlockRef("out", (b, ho_p, wo, cop), (1, sh, wo, cob),
                       lambda i, s, j, k: (i, s, 0, j), out_itemsize)
    return KernelModel(
        name="fused_mbconv",
        grid=(b, n_slabs, cop // cob, nk),
        dimension_semantics=("parallel", "parallel", "parallel",
                             "arbitrary"),
        inputs=tuple(inputs),
        output=out_ref,
        scratch_bytes=sh * wo * cob * 4,           # fp32 accumulator
        value_bytes=sh * wo * cb * 4,              # conv intermediate (fp32)
        reshapes=(((sh, wo, c_in), (sh * wo, c_in)),
                  ((sh, wo, cb), (sh * wo, cb))),
    )


def _fused_mb_kernel(*refs, hf: int, wf: int, stride: int, nk: int,
                     mb_activation, activation, has_mbb: bool,
                     has_pwb: bool, has_res: bool, out_dtype):
    """refs = (x, mb_f, [mb_bias,] pw_w, [pw_bias,] [residual,] out, acc).

    Blocks: x (1, slab_hi, Wiu, Ci) — the overlapping raw-input window of
    this row slab, identical for every reduction step; mb_f
    (Hf, Wf, Ci, Cb); mb_bias (1, Cb); pw_w (Cb, Cob); pw_bias (1, Cob);
    residual / out (1, slab_h, Wo, Cob); acc VMEM scratch (slab_h*Wo, Cob)
    fp32.
    """
    it = iter(refs)
    x_ref = next(it)
    f_ref = next(it)
    mbb_ref = next(it) if has_mbb else None
    w_ref = next(it)
    pwb_ref = next(it) if has_pwb else None
    res_ref = next(it) if has_res else None
    out_ref = next(it)
    acc_ref = next(it)

    _, slab_h, wo, cob = out_ref.shape
    cb = f_ref.shape[3]
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0].astype(jnp.float32)
    ci = x.shape[2]
    f = f_ref[...].astype(jnp.float32)
    s = stride

    # --- conv stage: Hf*Wf tap GEMMs contracting over the raw channels ---
    conv = jnp.zeros((slab_h * wo, cb), jnp.float32)
    for n in range(hf):
        for m in range(wf):
            win = jax.lax.slice(
                x,
                (n, m, 0),
                (n + (slab_h - 1) * s + 1, m + (wo - 1) * s + 1, ci),
                (s, s, 1),
            )
            conv = conv + jnp.dot(
                win.reshape(slab_h * wo, ci), f[n, m],
                preferred_element_type=jnp.float32,
            )
    conv = _epilogue(
        conv, mbb_ref[0][None, :] if mbb_ref is not None else None,
        mb_activation,
    )

    # --- projection: conv tile (VMEM value, never stored) is the A-operand
    acc_ref[...] += jnp.dot(
        conv, w_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == nk - 1)
    def _store():  # single store of the slab's output block
        acc = _epilogue(
            acc_ref[...],
            pwb_ref[...] if pwb_ref is not None else None,
            activation,
        )
        y = acc.reshape(slab_h, wo, cob)
        if res_ref is not None:
            y = y + res_ref[0].astype(jnp.float32)
        out_ref[0] = y.astype(out_dtype)


@functools.partial(
    jax.jit,
    static_argnames=("stride", "mb_activation", "activation", "block_c",
                     "block_co", "slab_h", "interpret", "out_dtype"),
)
def fused_mbconv_pallas(
    x: jax.Array,
    mb_f: jax.Array,
    pw_w: jax.Array,
    mb_bias: Optional[jax.Array] = None,
    pw_bias: Optional[jax.Array] = None,
    residual: Optional[jax.Array] = None,
    *,
    stride: int = 1,
    mb_activation: Optional[str] = "relu6",
    activation: Optional[str] = None,
    block_c: int | None = None,
    block_co: int | None = None,
    slab_h: int | None = None,
    interpret: bool = False,
    out_dtype: Optional[str] = None,
) -> jax.Array:
    """Fused-MBConv block.  x (B,Hi,Wi,Ci); mb_f (Hf,Wf,Ci,C); pw_w (C,Co)
    [+ mb_bias (C,), pw_bias (Co,), residual (B,Ho,Wo,Co)] -> (B,Ho,Wo,Co).

    VALID geometry — SAME padding is applied by the wrapper (lowering.py).
    ``out_dtype`` (a dtype NAME, static) selects the store width of the
    single output write; the accumulator is fp32 VMEM scratch regardless.
    Block shapes not given explicitly come from
    :func:`repro.kernels.blocking.plan_fused_mb`; raises ValueError when
    even the minimal plan exceeds the VMEM budget (callers should have
    consulted the planner and degraded to the standalone conv instead).
    """
    b, hi, wi, c_in = x.shape
    odt = jnp.dtype(out_dtype) if out_dtype is not None else x.dtype
    hf, wf, ci_f, c = mb_f.shape
    cw, co = pw_w.shape
    assert ci_f == c_in and c == cw, (x.shape, mb_f.shape, pw_w.shape)
    ho = (hi - hf) // stride + 1
    wo = (wi - wf) // stride + 1
    assert ho >= 1 and wo >= 1, "input smaller than filter"
    hiu = (ho - 1) * stride + hf
    wiu = (wo - 1) * stride + wf

    if block_c is None or block_co is None or slab_h is None:
        plan = blocking.plan_fused_mb(
            ho, wo, c_in, c, co, stride=stride, hf=hf, wf=wf,
            dtype=x.dtype, residual=residual is not None)
        if plan is None and (block_c is None or block_co is None):
            raise ValueError(
                f"no fused-MBConv plan fits VMEM for {(hi, wi, c, co)}; "
                "use the standalone conv + PW composition")
        cb = block_c or plan.block_c
        cob = block_co or plan.block_co
        sh = slab_h or (plan.slab_h if plan is not None else ho)
    else:
        cb, cob, sh = block_c, block_co, slab_h
    sh = min(sh, ho)
    n_slabs = -(-ho // sh)
    ho_p = n_slabs * sh

    # Conv-output channel / Co padding: zero filter columns make padded conv
    # channels compute act(bias-padding) = act(0) = 0, and the matching zero
    # pw_w rows nullify them regardless.
    pad_c = (-c) % cb
    pad_co = (-co) % cob
    if pad_c:
        mb_f = jnp.pad(mb_f, ((0, 0), (0, 0), (0, 0), (0, pad_c)))
        pw_w = jnp.pad(pw_w, ((0, pad_c), (0, 0)))
        if mb_bias is not None:
            mb_bias = jnp.pad(mb_bias, ((0, pad_c),))
    if pad_co:
        pw_w = jnp.pad(pw_w, ((0, 0), (0, pad_co)))
        if pw_bias is not None:
            pw_bias = jnp.pad(pw_bias, ((0, pad_co),))
        if residual is not None:
            residual = jnp.pad(residual,
                               ((0, 0), (0, 0), (0, 0), (0, pad_co)))
    cp, cop = c + pad_c, co + pad_co
    nk = cp // cb

    # Row padding so the slab grid tiles Ho: the last slab's window reads
    # zero rows past the image and its garbage output rows are cropped.
    rows_in = (ho_p - 1) * stride + hf
    x = x[:, :hiu, :wiu, :]
    if rows_in > hiu:
        x = jnp.pad(x, ((0, 0), (0, rows_in - hiu), (0, 0), (0, 0)))
    if ho_p > ho and residual is not None:
        residual = jnp.pad(residual,
                           ((0, 0), (0, ho_p - ho), (0, 0), (0, 0)))

    model = fused_mb_kernel_model(
        b=b, ho=ho, wo=wo, c_in=c_in, c=c, co=co, hf=hf, wf=wf,
        stride=stride, block_c=cb, block_co=cob, slab_h=sh,
        itemsize=x.dtype.itemsize, out_itemsize=odt.itemsize,
        has_mb_bias=mb_bias is not None, has_pw_bias=pw_bias is not None,
        has_residual=residual is not None,
    )
    inputs = [x, mb_f]
    if mb_bias is not None:
        inputs.append(mb_bias.reshape(1, -1))
    inputs.append(pw_w)
    if pw_bias is not None:
        inputs.append(pw_bias.reshape(1, -1))
    if residual is not None:
        inputs.append(residual)
    for arr, br in zip(inputs, model.inputs):
        assert arr.shape == br.array_shape, (br.name, arr.shape,
                                             br.array_shape)

    kernel = functools.partial(
        _fused_mb_kernel, hf=hf, wf=wf, stride=stride, nk=nk,
        mb_activation=mb_activation, activation=activation,
        has_mbb=mb_bias is not None, has_pwb=pw_bias is not None,
        has_res=residual is not None, out_dtype=odt,
    )
    try:
        compiler_params = pltpu.CompilerParams(
            dimension_semantics=model.dimension_semantics
        )
    except AttributeError:
        compiler_params = pltpu.TPUCompilerParams(
            dimension_semantics=model.dimension_semantics
        )

    assert model.output.array_shape == (b, ho_p, wo, cop)
    out = pl.pallas_call(
        kernel,
        grid=model.grid,
        in_specs=in_specs_from_model(model),
        out_specs=out_spec_from_model(model),
        out_shape=jax.ShapeDtypeStruct(model.output.array_shape, odt),
        scratch_shapes=[pltpu.VMEM((sh * wo, cob), jnp.float32)],
        compiler_params=compiler_params,
        interpret=interpret,
    )(*inputs)
    return out[:, :ho, :, :co]
