"""Declarative grid/BlockSpec geometry shared by the kernels and the static
analyzer (DESIGN.md §8).

Every Pallas kernel in this package lowers to a grid plus a set of
BlockSpecs.  Before this module those were built inline inside each
``pl.pallas_call`` call site, which meant the planner (``blocking.py``), the
lowering and any analysis each re-derived the same padding / index-map
arithmetic — exactly the planner<->lowering drift PR 4 had to fix by hand.

Now each kernel module exposes a pure ``*_kernel_model(...)`` builder that
returns a :class:`KernelModel`: the grid, the dimension semantics, and one
:class:`BlockRef` per operand (padded array shape, block shape, index map,
indexing mode).  The kernel constructs its actual ``pl.BlockSpec``s FROM the
model (:func:`in_specs_from_model` / :func:`out_spec_from_model`), and
``repro.analysis`` statically checks the SAME model — so what the verifier
proves (VMEM residency, halo in-bounds, disjoint output tiling, lane/sublane
alignment) is what the hardware will execute, not a parallel re-derivation.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional, Tuple

from jax.experimental import pallas as pl

#: Physical VMEM per TensorCore the derived working set must never exceed
#: (the planner budgets 12 MiB of this to leave Mosaic headroom).
VMEM_HARD_BYTES = 16 * 1024 * 1024


@dataclasses.dataclass(frozen=True)
class BlockRef:
    """One operand's block geometry: the (padded) array the kernel is passed,
    the VMEM block shape, and the grid -> block index map.

    ``unblocked`` marks element-offset (``pl.unblocked``) indexing — the
    index map then returns ELEMENT offsets, not block indices (the fused
    kernel's overlapping halo windows).  ``streamed`` operands are pipelined
    HBM<->VMEM by Mosaic and therefore double-buffered in the VMEM
    accounting.
    """
    name: str
    array_shape: Tuple[int, ...]
    block_shape: Tuple[int, ...]
    index_map: Callable[..., Tuple[int, ...]]
    itemsize: int
    unblocked: bool = False
    streamed: bool = True

    @property
    def block_elems(self) -> int:
        return math.prod(self.block_shape)

    @property
    def block_bytes(self) -> int:
        return self.block_elems * self.itemsize

    def buffer_bytes(self) -> int:
        """VMEM footprint of this operand: 2x when pipelined/double-buffered."""
        return (2 if self.streamed else 1) * self.block_bytes


@dataclasses.dataclass(frozen=True)
class KernelModel:
    """A kernel invocation's complete lowering geometry — what
    ``pl.pallas_call`` will be handed, in checkable form.

    ``scratch_bytes`` covers explicit VMEM scratch allocations (fp32
    accumulators); ``value_bytes`` the persistent in-kernel fp32 values the
    planner budgets (DW intermediate, expanded slab) that are neither
    operands nor scratch.  ``reshapes`` records in-kernel reshape shapes for
    the Mosaic sublane-collapse lint (``analysis/mosaic_check.py``).
    """
    name: str
    grid: Tuple[int, ...]
    dimension_semantics: Tuple[str, ...]
    inputs: Tuple[BlockRef, ...]
    output: BlockRef
    scratch_bytes: int = 0
    value_bytes: int = 0
    reshapes: Tuple[Tuple[Tuple[int, ...], Tuple[int, ...]], ...] = ()

    @property
    def grid_points(self) -> int:
        return math.prod(self.grid)

    def vmem_bytes(self) -> int:
        """Derived VMEM working set of one grid cell: every streamed operand
        double-buffered, plus the output buffer, scratch and in-kernel
        values."""
        return (sum(br.buffer_bytes() for br in self.inputs)
                + self.output.buffer_bytes()
                + self.scratch_bytes + self.value_bytes)


def in_specs_from_model(model: KernelModel) -> list:
    """The ``pl.BlockSpec`` list the kernel passes as ``in_specs``."""
    specs = []
    for br in model.inputs:
        if br.unblocked:
            specs.append(pl.BlockSpec(br.block_shape, br.index_map,
                                      indexing_mode=pl.unblocked))
        else:
            specs.append(pl.BlockSpec(br.block_shape, br.index_map))
    return specs


def out_spec_from_model(model: KernelModel) -> pl.BlockSpec:
    return pl.BlockSpec(model.output.block_shape, model.output.index_map)
