"""Lower a ChainPlan onto kernels: the execute half of spec -> plan -> run.

``core/chain.plan`` decides WHICH contiguous stages of a declared separable
chain fuse (DESIGN.md §5); this module maps that decision onto the actual
executables:

* ``fused3`` segments -> ``separable_fused_pallas(expand_w=...)`` — the
  whole PW-expand -> DW -> PW-project inverted residual as ONE kernel pass
  (expand-on-the-fly, neither intermediate in HBM);
* ``fused2`` segments -> ``separable_fused_pallas`` (the PR-2 DW -> PW
  kernel);
* ``pw`` / ``dw`` segments -> the standalone ``ops.pwconv`` /
  ``ops.dwconv2d`` kernels;
* on the XLA backend every fused segment runs ``ref.separable_fused_ref``
  (same fusion numerics — fp32 intermediates — without Pallas).

The lowering never re-plans: each segment executes at exactly the block
shapes its ``ChainSegment.plan`` carries, so a ``ChainPlan`` is a complete,
reproducible execution recipe (and therefore a cacheable autotuning unit).

Stage objects are duck-typed (``features``/``activation``/``bias`` for PW,
``stride``/``hf``/``wf``/``padding``/``activation``/``bias`` for DW) so this
module depends only on the kernel layer; the spec dataclasses live in
``core/chain.py``.

The dtype policy (``KernelPolicy.dtype_policy``, DESIGN.md §7) is applied
HERE, once per chain: the input and every parameter leaf are cast to the
stream dtype at segment boundaries (no-ops when the caller pre-cast them,
e.g. ``core/network.cast_network_params``), and the LAST kernel pass stores
at the policy's ``out`` dtype via the kernels' ``out_dtype`` epilogue —
accumulators stay fp32 inside every kernel regardless.
"""
from __future__ import annotations

from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.kernels.blocking import ChainPlan
from repro.kernels.epilogue import apply_epilogue
from repro.kernels.fused_mbconv import fused_mbconv_pallas
from repro.kernels.policy import DEFAULT_POLICY, KernelPolicy
from repro.kernels.se_epilogue import dw_se_pallas
from repro.kernels.separable_fused import separable_fused_pallas
from repro.runtime import failures, faultinject

#: Per-stage parameter leaves the lowering consumes: PW stages take
#: ``{"w": (Ci, Co)[, "b": (Co,)]}``, DW stages ``{"f": (Hf, Wf, C)[,
#: "b": (C,)]}``, SE stages ``{"w1": (C, Cse), "b1": (Cse,), "w2":
#: (Cse, C), "b2": (C,)}``, FusedMB stages ``{"f": (Hf, Wf, Ci, C)[,
#: "b": (C,)]}``; params are a sequence aligned with ``spec.stages``.
PARAM_KEYS = {"pw": ("w", "b"), "dw": ("f", "b"),
              "se": ("w1", "b1", "w2", "b2"), "mb": ("f", "b")}

#: Fault-injection point per segment kind (repro.runtime.faultinject,
#: DESIGN.md §9), checked before each dispatch; fused2 and fused3 share one
#: point because they share the kernel, as do fusedmb/mb and dw_se/se.
_INJECT = {"fused3": "lowering:separable_fused",
           "fused2": "lowering:separable_fused",
           "fusedmb": "lowering:fused_mbconv",
           "mb": "lowering:fused_mbconv",
           "dw_se": "lowering:se_epilogue",
           "se": "lowering:se_epilogue",
           "pw": "lowering:pwconv",
           "dw": "lowering:dwconv2d"}


def _cast(a, dtype):
    return None if a is None else a.astype(dtype)


def _run_fused(seg, stages, params, y, res, *, impl, interpret,
               stream_dtype, out_dtype):
    """One fused segment (2- or 3-stage) as a single kernel pass."""
    if seg.kind == "fused3":
        i_ex, i_dw, i_pw = seg.stages
        expand_w = params[i_ex]["w"].astype(stream_dtype)
        expand_act = stages[i_ex].activation
    else:
        i_dw, i_pw = seg.stages
        expand_w, expand_act = None, None
    d = stages[i_dw]
    proj = stages[i_pw]
    dw_f = params[i_dw]["f"].astype(stream_dtype)
    dw_b = _cast(params[i_dw].get("b"), stream_dtype)
    pw_w = params[i_pw]["w"].astype(stream_dtype)
    pw_b = _cast(params[i_pw].get("b"), stream_dtype)
    if impl == "xla":
        out = ref.separable_fused_ref(
            y, dw_f, pw_w, dw_b, pw_b, res,
            expand_w=expand_w, expand_activation=expand_act,
            stride=d.stride, padding=d.padding,
            dw_activation=d.activation, activation=proj.activation,
        )
        return out.astype(out_dtype)
    if d.padding.lower() == "same":
        y = ops.pad_same(y, d.hf, d.wf, d.stride)
    elif d.padding.lower() != "valid":
        raise ValueError(d.padding)
    return separable_fused_pallas(
        y, dw_f, pw_w, dw_b, pw_b, res,
        expand_w=expand_w, expand_activation=expand_act,
        stride=d.stride, dw_activation=d.activation,
        activation=proj.activation,
        block_c=seg.plan.block_c, block_co=seg.plan.block_co,
        slab_h=seg.plan.slab_h, interpret=interpret,
        out_dtype=jnp.dtype(out_dtype).name,
    )


def _run_fused_mb(seg, stages, params, y, res, *, impl, interpret,
                  stream_dtype, out_dtype):
    """One fused-MBConv segment (full conv + PW-project) as one pass."""
    i_mb, i_pw = seg.stages
    mb = stages[i_mb]
    proj = stages[i_pw]
    mb_f = params[i_mb]["f"].astype(stream_dtype)
    mb_b = _cast(params[i_mb].get("b"), stream_dtype)
    pw_w = params[i_pw]["w"].astype(stream_dtype)
    pw_b = _cast(params[i_pw].get("b"), stream_dtype)
    if impl == "xla":
        out = ref.fused_mbconv_ref(
            y, mb_f, pw_w, mb_b, pw_b, res,
            stride=mb.stride, padding=mb.padding,
            mb_activation=mb.activation, activation=proj.activation,
        )
        return out.astype(out_dtype)
    if mb.padding.lower() == "same":
        y = ops.pad_same(y, mb.hf, mb.wf, mb.stride)
    elif mb.padding.lower() != "valid":
        raise ValueError(mb.padding)
    return fused_mbconv_pallas(
        y, mb_f, pw_w, mb_b, pw_b, res,
        stride=mb.stride, mb_activation=mb.activation,
        activation=proj.activation,
        block_c=seg.plan.block_c, block_co=seg.plan.block_co,
        slab_h=seg.plan.slab_h, interpret=interpret,
        out_dtype=jnp.dtype(out_dtype).name,
    )


def _run_dw_se(seg, stages, params, y, *, impl, interpret, stream_dtype,
               out_dtype):
    """One fused DW + SE-epilogue segment as one pass."""
    i_dw, i_se = seg.stages
    d = stages[i_dw]
    se = stages[i_se]
    dw_f = params[i_dw]["f"].astype(stream_dtype)
    dw_b = _cast(params[i_dw].get("b"), stream_dtype)
    sp = params[i_se]
    w1, b1 = sp["w1"].astype(stream_dtype), sp["b1"].astype(stream_dtype)
    w2, b2 = sp["w2"].astype(stream_dtype), sp["b2"].astype(stream_dtype)
    if impl == "xla":
        out = ref.dw_se_ref(
            y, dw_f, w1, b1, w2, b2, dw_b,
            stride=d.stride, padding=d.padding,
            dw_activation=d.activation, se_activation=se.activation,
        )
        return out.astype(out_dtype)
    if d.padding.lower() == "same":
        y = ops.pad_same(y, d.hf, d.wf, d.stride)
    elif d.padding.lower() != "valid":
        raise ValueError(d.padding)
    return dw_se_pallas(
        y, dw_f, w1, b1, w2, b2, dw_b,
        stride=d.stride, dw_activation=d.activation,
        se_activation=se.activation, interpret=interpret,
        out_dtype=jnp.dtype(out_dtype).name,
    )


def _run_se(seg, stages, params, y, policy, *, impl, interpret,
            stream_dtype, out_dtype):
    """One standalone SE segment: pool + two pwconv GEMM passes + the
    sigmoid scale.  On the Pallas path the two (tiny) FCs run through the
    pwconv kernel — the SE gate itself is elementwise XLA work; the
    lowering owns the gate's cast back to the stream width (JX310)."""
    se = stages[seg.stages[0]]
    sp = params[seg.stages[0]]
    w1, b1 = sp["w1"].astype(stream_dtype), sp["b1"].astype(stream_dtype)
    w2, b2 = sp["w2"].astype(stream_dtype), sp["b2"].astype(stream_dtype)
    pooled = jnp.mean(y.astype(jnp.float32), axis=(1, 2)).astype(
        stream_dtype)
    hid = ops.pwconv(pooled, w1, b1, activation=se.activation,
                     impl=impl, interpret=interpret,
                     vmem_budget=policy.vmem_budget)
    pre = ops.pwconv(hid, w2, b2, activation=None,
                     impl=impl, interpret=interpret,
                     vmem_budget=policy.vmem_budget)
    gate = jax.nn.sigmoid(pre.astype(jnp.float32)).astype(stream_dtype)
    return (y * gate[:, None, None, :]).astype(out_dtype)


def lower(spec, chain_plan: ChainPlan,
          policy: KernelPolicy = DEFAULT_POLICY,
          ) -> Callable[[Sequence[dict], jax.Array], jax.Array]:
    """Map a planned chain onto kernels; returns ``run(params, x)``.

    ``params`` is a sequence of per-stage dicts aligned with
    ``spec.stages`` (see :data:`PARAM_KEYS`).  The residual source is the
    chain input ``x``; it rides inside the final fused kernel pass when
    ``chain_plan.residual_fused``, else it is added as a separate op.
    """
    impl = policy.resolved()
    interpret = policy.interpret
    stages = spec.stages
    segments = chain_plan.segments
    dp = policy.dtype_policy

    def run(params: Sequence[dict], x: jax.Array) -> jax.Array:
        assert len(params) == len(stages), (len(params), len(stages))
        sdt = dp.stream_dtype(x.dtype)
        odt = dp.out_dtype(x.dtype)
        y = x.astype(sdt)
        res = y if chain_plan.residual else None
        # the residual add after an unfused tail is a separate op, so the
        # LAST kernel must still store at the stream width in that case
        sep_res = chain_plan.residual and not chain_plan.residual_fused
        for si, seg in enumerate(segments):
            last = si == len(segments) - 1
            k_out = odt if (last and not sep_res) else sdt
            seg_res = res if (chain_plan.residual_fused and last) else None
            try:
                faultinject.check(_INJECT[seg.kind])
                if seg.kind in ("fused3", "fused2"):
                    y = _run_fused(seg, stages, params, y, seg_res,
                                   impl=impl, interpret=interpret,
                                   stream_dtype=sdt, out_dtype=k_out)
                elif seg.kind == "fusedmb":
                    y = _run_fused_mb(seg, stages, params, y, seg_res,
                                      impl=impl, interpret=interpret,
                                      stream_dtype=sdt, out_dtype=k_out)
                elif seg.kind == "dw_se":
                    y = _run_dw_se(seg, stages, params, y,
                                   impl=impl, interpret=interpret,
                                   stream_dtype=sdt, out_dtype=k_out)
                elif seg.kind == "se":
                    y = _run_se(seg, stages, params, y, policy,
                                impl=impl, interpret=interpret,
                                stream_dtype=sdt, out_dtype=k_out)
                elif seg.kind == "mb":
                    # standalone dense conv: XLA-lowered on every impl —
                    # the dense conv is MXU-shaped as-is, the Pallas win is
                    # the fused projection (segment kind "fusedmb")
                    st = stages[seg.stages[0]]
                    p = params[seg.stages[0]]
                    y = ref.conv2d_ref(
                        y, p["f"].astype(sdt), _cast(p.get("b"), sdt),
                        stride=st.stride, padding=st.padding,
                        activation=st.activation,
                    ).astype(k_out)
                elif seg.kind == "pw":
                    st = stages[seg.stages[0]]
                    p = params[seg.stages[0]]
                    y = ops.pwconv(
                        y, p["w"].astype(sdt), _cast(p.get("b"), sdt),
                        activation=st.activation,
                        impl=impl, interpret=interpret,
                        block_g=policy.block_g or seg.plan.block_g,
                        block_co=policy.block_co or seg.plan.block_co,
                        block_ci=policy.block_ci or seg.plan.block_c,
                        vmem_budget=policy.vmem_budget,
                        out_dtype=jnp.dtype(k_out).name,
                    )
                else:  # "dw"
                    st = stages[seg.stages[0]]
                    p = params[seg.stages[0]]
                    # execute the planned channel block verbatim —
                    # re-planning here would silently ignore
                    # policy.vmem_budget (and defeat measured autotuning,
                    # which keys on the plan it timed)
                    y = ops.dwconv2d(
                        y, p["f"].astype(sdt), stride=st.stride,
                        padding=st.padding,
                        impl=impl, interpret=interpret,
                        block_c=seg.plan.block_c,
                        vmem_budget=policy.vmem_budget,
                    )
                    y = apply_epilogue(y, _cast(p.get("b"), sdt),
                                       st.activation)
                    if last:
                        y = y.astype(k_out)
            except Exception as e:
                # tag recognized backend failures with the segment that
                # produced them (the runtime ladder keys its quarantine
                # decision on this); anything else propagates unwrapped
                f = failures.classify(e, segment_kind=seg.kind,
                                      segment_index=si,
                                      stage_indices=seg.stages)
                if f is None or f is e:
                    raise
                raise f from e
        if sep_res:
            y = (y + res).astype(odt)
        return y

    return run
