"""Jitted public wrappers for the kernel package.

Every op has two execution paths:

* ``impl="xla"``      — the pure-jnp oracle (ref.py), used on CPU hosts and as
                        the comparison baseline;
* ``impl="pallas"``   — the TPU Pallas kernel (compiled on TPU, or
                        ``interpret=True`` on CPU for validation).

``impl="auto"`` picks pallas on TPU backends and xla elsewhere, so the same
model code runs in this CPU container and on a real pod.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import blocking, ref
from repro.kernels.dwconv1d import dwconv1d_causal_pallas
from repro.kernels.dwconv2d import dwconv2d_pallas
from repro.kernels.epilogue import apply_epilogue
from repro.kernels.policy import resolve_impl
from repro.kernels.pwconv import pwconv_pallas
from repro.kernels.separable_fused import separable_fused_pallas

# Single source of the "auto -> pallas on TPU else xla" rule
# (kernels/policy.py); `_resolve` stays as an alias for old call sites.
_resolve = resolve_impl


def pad_same(x: jax.Array, hf: int, wf: int, stride: int) -> jax.Array:
    """Explicit SAME padding (so the Pallas kernels only see VALID).

    Public: the chain lowering (kernels/lowering.py) applies it before
    handing fused segments to the VALID-geometry kernels.
    """
    _, hi, wi, _ = x.shape
    ho = -(-hi // stride)
    wo = -(-wi // stride)
    ph = max((ho - 1) * stride + hf - hi, 0)
    pw = max((wo - 1) * stride + wf - wi, 0)
    return jnp.pad(
        x, ((0, 0), (ph // 2, ph - ph // 2), (pw // 2, pw - pw // 2), (0, 0))
    )


_pad_same = pad_same


def dwconv2d(
    x: jax.Array,
    f: jax.Array,
    *,
    stride: int = 1,
    padding: str = "same",
    impl: str = "auto",
    interpret: bool = False,
    block_c: int | None = None,
    vmem_budget: int = blocking.DEFAULT_VMEM_BUDGET,
    out_dtype: str | None = None,
) -> jax.Array:
    """Depthwise 2-D conv, NHWC. x (B,Hi,Wi,C), f (Hf,Wf,C).

    ``block_c`` executes the kernel at an explicit channel block (the chain
    lowering passes its ``ChainSegment.plan`` here so a planned — or
    measured — ``ChainPlan`` runs verbatim); ``None`` defers to the
    dtype-aware planner at ``vmem_budget``.  ``out_dtype`` (dtype NAME)
    selects the store width of the output (DESIGN.md §7); ``None`` keeps
    ``x.dtype``.
    """
    impl = _resolve(impl)
    if impl == "xla":
        y = ref.dwconv2d_ref(x, f, stride=stride, padding=padding)
        return y if out_dtype is None else y.astype(out_dtype)
    if padding.lower() == "same":
        x = _pad_same(x, f.shape[0], f.shape[1], stride)
    elif padding.lower() != "valid":
        raise ValueError(padding)
    return dwconv2d_pallas(x, f, stride=stride, block_c=block_c,
                           vmem_budget=vmem_budget, interpret=interpret,
                           out_dtype=out_dtype)


def dwconv1d_causal(
    x: jax.Array,
    f: jax.Array,
    *,
    impl: str = "auto",
    interpret: bool = False,
    block_l: int = 1024,
    block_d: int = 256,
) -> jax.Array:
    """Causal depthwise 1-D conv. x (B,L,D), f (K,D)."""
    impl = _resolve(impl)
    if impl == "xla":
        return ref.dwconv1d_causal_ref(x, f)
    return dwconv1d_causal_pallas(
        x, f, block_l=block_l, block_d=block_d, interpret=interpret
    )


def separable_fused(
    x: jax.Array,
    dw_f: jax.Array,
    pw_w: jax.Array,
    dw_bias: Optional[jax.Array] = None,
    pw_bias: Optional[jax.Array] = None,
    residual: Optional[jax.Array] = None,
    *,
    expand_w: Optional[jax.Array] = None,
    expand_activation: Optional[str] = "relu6",
    stride: int = 1,
    padding: str = "same",
    dw_activation: Optional[str] = "relu6",
    activation: Optional[str] = None,
    impl: str = "auto",
    interpret: bool = False,
    vmem_budget: int = blocking.DEFAULT_VMEM_BUDGET,
) -> jax.Array:
    """Fused depthwise-separable block: [PW-expand ->] DW -> act -> PW in
    one kernel pass.

    x (B,Hi,Wi,C); dw_f (Hf,Wf,C); pw_w (C,Co) -> (B,Ho,Wo,Co); with
    ``expand_w`` (Ci, C) the input is (B,Hi,Wi,Ci) and the bias-free
    expansion GEMM is computed on the fly inside the kernel.  On the pallas
    path neither the expanded tensor nor the DW intermediate ever touches
    HBM (DESIGN.md §3/§5).  Block shapes — including the row-slab dimension
    that keeps the accumulator VMEM-sized at any resolution — come from
    :func:`repro.kernels.blocking.plan_separable` /
    :func:`~repro.kernels.blocking.plan_separable3`.  When a plan does not
    fit the budget the op degrades exactly like the chain planner
    (DESIGN.md §5): 3-stage fused -> standalone expand + 2-stage fused ->
    unfused Pallas composition.  The unfused fallback is semantically the
    same block but rounds the intermediates to the activation dtype between
    kernels (the fused paths keep them fp32), so sub-fp32 dtypes can differ
    by intermediate-rounding error across the VMEM-feasibility boundary.

    Prefer the declarative chain API (``core/chain.py``) for new code; this
    wrapper remains the kernel-level entry the lowering maps onto.
    """
    impl = resolve_impl(impl)
    if impl == "xla":
        return ref.separable_fused_ref(
            x, dw_f, pw_w, dw_bias, pw_bias, residual,
            expand_w=expand_w, expand_activation=expand_activation,
            stride=stride, padding=padding,
            dw_activation=dw_activation, activation=activation,
        )
    hf, wf = dw_f.shape[0], dw_f.shape[1]
    if padding.lower() == "same":
        x = pad_same(x, hf, wf, stride)
    elif padding.lower() != "valid":
        raise ValueError(padding)
    hi, wi = x.shape[1], x.shape[2]
    ho = (hi - hf) // stride + 1
    wo = (wi - wf) // stride + 1
    if expand_w is not None:
        plan3 = blocking.plan_separable3(
            ho, wo, expand_w.shape[0], expand_w.shape[1], pw_w.shape[-1],
            stride=stride, hf=hf, wf=wf, dtype=x.dtype,
            vmem_budget=vmem_budget, residual=residual is not None)
        if plan3 is not None:
            return separable_fused_pallas(
                x, dw_f, pw_w, dw_bias, pw_bias, residual,
                expand_w=expand_w, expand_activation=expand_activation,
                stride=stride, dw_activation=dw_activation,
                activation=activation, block_c=plan3.block_c,
                block_co=plan3.block_co, slab_h=plan3.slab_h,
                interpret=interpret,
            )
        # Degrade to the 2-stage path: standalone expansion GEMM (its output
        # rounds to the activation dtype), then DW -> PW below.
        x = pwconv(x, expand_w, activation=expand_activation,
                   impl="pallas", interpret=interpret,
                   vmem_budget=vmem_budget)
    plan = blocking.plan_separable(
        ho, wo, x.shape[-1], pw_w.shape[-1], stride=stride, hf=hf, wf=wf,
        dtype=x.dtype, vmem_budget=vmem_budget,
        residual=residual is not None)
    if plan is None:
        # Even the minimal (cb=1, cob=1, slab_h=1) plan exceeds the budget:
        # compose the standalone kernels instead (correct, just not fused).
        y = dwconv2d_pallas(x, dw_f, stride=stride,
                            vmem_budget=vmem_budget, interpret=interpret)
        if dw_bias is not None:
            y = y + dw_bias
        y = apply_epilogue(y, None, dw_activation).astype(x.dtype)
        out = pwconv(
            y, pw_w, pw_bias, activation=activation,
            impl="pallas", interpret=interpret, vmem_budget=vmem_budget,
        )
        if residual is not None:
            out = out + residual
        return out
    return separable_fused_pallas(
        x, dw_f, pw_w, dw_bias, pw_bias, residual,
        stride=stride, dw_activation=dw_activation, activation=activation,
        block_c=plan.block_c, block_co=plan.block_co, slab_h=plan.slab_h,
        interpret=interpret,
    )


def pwconv(
    x: jax.Array,
    w: jax.Array,
    bias: Optional[jax.Array] = None,
    *,
    activation: Optional[str] = None,
    impl: str = "auto",
    interpret: bool = False,
    block_g: int | None = None,
    block_co: int | None = None,
    block_ci: int | None = None,
    vmem_budget: int = blocking.DEFAULT_VMEM_BUDGET,
    out_dtype: str | None = None,
) -> jax.Array:
    """Pointwise conv / GEMM over the last axis. x (..., Ci), w (Ci, Co).

    Block shapes default to :func:`repro.kernels.blocking.plan_pwconv`
    (dtype-aware MXU-aligned grid, sized against ``vmem_budget``); explicit
    overrides win.  ``out_dtype`` (dtype NAME) selects the store width of
    the output (DESIGN.md §7); ``None`` keeps ``x.dtype``.
    """
    impl = _resolve(impl)
    if impl == "xla":
        y = ref.pwconv_ref(x, w, bias=bias, activation=activation)
        return y if out_dtype is None else y.astype(out_dtype)
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    if block_g is None or block_co is None or block_ci is None:
        plan = blocking.plan_pwconv(x2.shape[0], w.shape[0], w.shape[1],
                                    dtype=x.dtype,
                                    vmem_budget=vmem_budget)
        block_g = block_g or plan.block_g
        block_co = block_co or plan.block_co
        block_ci = block_ci or plan.block_c
    y = pwconv_pallas(
        x2, w, bias,
        activation=activation,
        block_g=block_g, block_co=block_co, block_ci=block_ci,
        interpret=interpret, out_dtype=out_dtype,
    )
    return y.reshape(*lead, w.shape[1])
