"""Execution policy for the paper's ops — the single backend-resolution rule.

``resolve_impl`` is the ONE place the "auto -> pallas on TPU, else xla" rule
lives.  It used to be implemented twice (``kernels/ops._resolve`` and
``core.pwconv.KernelPolicy.resolved``), which is exactly the kind of
duplicated decision the declarative chain API removes; both now call here.

``KernelPolicy`` is policy-only: *how* to execute (backend, interpret mode,
VMEM budget, explicit GEMM grid overrides) — never *what* to fuse.  Fusion
is a planner decision (``core/chain.plan`` -> ``ChainPlan``, DESIGN.md §5):
the planner fuses the longest stage run whose working set fits the policy's
``vmem_budget`` and degrades 3-fused -> 2-fused -> unfused on its own.  The
legacy ``fused`` boolean survives one release as a deprecated tri-state
override for the old call sites.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax

from repro.kernels.blocking import DEFAULT_VMEM_BUDGET


#: Dtype names a DtypePolicy may stream/store at (narrow enough to matter,
#: wide enough that fp32 accumulation recovers the precision).
STREAMABLE_DTYPES = ("float32", "bfloat16", "float16")


@dataclasses.dataclass(frozen=True)
class DtypePolicy:
    """Per-segment mixed-precision STREAMING policy (DESIGN.md §7).

    The paper's ops are memory-bound, so the width at which operands move
    HBM<->VMEM is a first-order performance knob.  This policy names it
    explicitly, per chain segment:

    * ``stream`` — dtype name every segment's *streamed* operands move at:
      the activation tensors entering/leaving each kernel pass and the
      weight/filter/bias tiles.  ``None`` keeps the native dtype of the
      input (the legacy behavior — an fp32 model streams fp32).  With
      ``"bfloat16"`` every streamed term of the traffic model halves while
      **accumulators stay fp32** (the kernels already upcast per tile and
      accumulate in fp32 VMEM scratch — ``blocking.ACC_BYTES`` — so only
      the HBM traffic narrows, not the arithmetic).
    * ``out`` — dtype name of the final chain/network output; ``None``
      stores at the stream width (the next block consumes it as-is).
      Pinning ``out="float32"`` makes the LAST kernel pass widen on store,
      inside its epilogue — no extra elementwise cast pass over the output.

    Frozen + hashable: it rides on :class:`KernelPolicy`, participates in
    the autotune cache key (``kernels/autotune.problem_signature`` — a
    bf16-streamed measured plan must never replay onto a native run), and
    the chain planner budgets VMEM at the stream width
    (``core/chain.plan``), so bf16 streaming also affords larger blocks.
    """
    stream: Optional[str] = None
    out: Optional[str] = None

    def __post_init__(self):
        for name in (self.stream, self.out):
            assert name is None or name in STREAMABLE_DTYPES, name

    def stream_dtype(self, native):
        """Dtype streamed operands move at, given the input's dtype."""
        import jax.numpy as jnp
        return jnp.dtype(self.stream) if self.stream else jnp.dtype(native)

    def out_dtype(self, native):
        """Dtype the final output is stored at, given the input's dtype."""
        import jax.numpy as jnp
        return (jnp.dtype(self.out) if self.out
                else self.stream_dtype(native))

    def signature(self) -> dict:
        """Serialized identity for the autotune cache key (DESIGN.md §6)."""
        return {"stream": self.stream, "out": self.out}


#: Stream at the input's native dtype (the legacy behavior).
NATIVE = DtypePolicy()

#: The DESIGN.md §7 default for mixed-precision serving: stream activations
#: and weights as bf16, accumulate fp32, store the network output as bf16.
BF16_STREAM = DtypePolicy(stream="bfloat16")


def resolve_impl(impl: str) -> str:
    """'auto' -> 'pallas' on TPU backends, 'xla' elsewhere; else pass-through.

    Single source of truth for backend resolution (used by ``kernels/ops``,
    ``kernels/lowering`` and ``KernelPolicy.resolved``).
    """
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    if impl not in ("pallas", "xla"):
        raise ValueError(f"unknown impl {impl!r} (want auto|pallas|xla)")
    return impl


@dataclasses.dataclass(frozen=True)
class KernelPolicy:
    """Global execution policy for the paper's ops.

    impl: "auto" | "xla" | "pallas". interpret=True only for CPU validation.
    vmem_budget: HBM->VMEM working-set budget the chain planner and the
    per-kernel planners size blocks against (DESIGN.md §4/§5).
    block_g/co/ci: explicit GEMM grid overrides; None (default) defers to
    the dtype-aware planner (kernels/blocking.plan_pwconv).

    fused: DEPRECATED. Fusion is a planner decision now — ``None`` (the
    default) lets ``core/chain.plan`` fuse whatever fits the budget;
    ``False`` forces the unfused composition (the old default behavior);
    ``True`` is accepted for old call sites and means the same as ``None``.

    autotune: measured plan selection (kernels/autotune.py). ``True`` makes
    ``core/chain.plan``/``execute`` consult the persistent tune cache and,
    on a miss, measure the candidate ladder on the first ``execute`` call
    (the winner is persisted, so later runs replay it without measuring).
    ``False`` (default) keeps today's analytic planner.

    verify: the static-verification debug knob (repro.analysis, DESIGN.md
    §8).  ``True`` makes ``core/chain.plan`` / ``execute`` /
    ``core/network.plan_network`` run the static analyzer (planlint +
    mosaic rules) on every resolved plan and raise
    ``analysis.PlanVerificationError`` on any error-severity diagnostic —
    an infeasible or corrupted plan then fails at plan time with rule ids,
    not on hardware as a Mosaic lowering error.  ``False`` (default) keeps
    verification to the CI sweep (``python -m repro.analysis``).
    tune_cache: path of the on-disk JSON tune cache; ``None`` uses
    ``kernels/autotune.default_cache_path()`` ($REPRO_TUNE_CACHE or
    ~/.cache/repro/autotune.json).

    dtype_policy: per-segment mixed-precision streaming (:class:`DtypePolicy`,
    DESIGN.md §7).  The default :data:`NATIVE` streams at the input's dtype
    — every cast the lowering inserts is then a no-op, so fp32 behavior is
    bitwise-identical to the pre-policy code path.

    on_failure: the runtime hardening knob (repro.runtime, DESIGN.md §9).
    ``"degrade"`` (default) wraps execution in the runtime degradation
    ladder: a classified backend failure (Mosaic/Pallas lowering rejection,
    XLA compile/OOM, numeric-guard trip) quarantines the failing rung
    persistently and retries one rung down (fused3 -> fused2 -> unfused ->
    XLA reference), with bounded attempts and fallback telemetry; the
    steady-state success path is unchanged (bitwise-identical outputs, zero
    fallback events).  ``"raise"`` propagates the taxonomy error
    (``runtime.failures.KernelFailure`` subclass, tagged with the failing
    ChainPlan segment) to the caller instead — for tests, debugging, and
    callers that own their own retry policy.

    numeric_guard: ``True`` checks every chain/network output for
    non-finite values after execution (host-side sync) and treats a trip
    as a ``NumericalFailure`` — degraded or raised per ``on_failure``.
    Off by default: the guard costs a device sync per call.
    """
    impl: str = "auto"
    interpret: bool = False
    vmem_budget: int = DEFAULT_VMEM_BUDGET
    fused: Optional[bool] = None
    autotune: bool = False
    verify: bool = False
    tune_cache: Optional[str] = None
    block_g: Optional[int] = None
    block_co: Optional[int] = None
    block_ci: Optional[int] = None
    dtype_policy: DtypePolicy = NATIVE
    on_failure: str = "degrade"
    numeric_guard: bool = False

    def __post_init__(self):
        assert self.on_failure in ("degrade", "raise"), self.on_failure

    def resolved(self) -> str:
        return resolve_impl(self.impl)

    @property
    def fusion_allowed(self) -> bool:
        """Planner gate from the deprecated knob: only ``fused=False``
        (the explicit legacy opt-out) disables fusion."""
        return self.fused is not False


DEFAULT_POLICY = KernelPolicy()
