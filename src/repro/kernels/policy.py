"""Execution policy for the paper's ops — the single backend-resolution rule.

``resolve_impl`` is the ONE place the "auto -> pallas on TPU, else xla" rule
lives.  It used to be implemented twice (``kernels/ops._resolve`` and
``core.pwconv.KernelPolicy.resolved``), which is exactly the kind of
duplicated decision the declarative chain API removes; both now call here.

``KernelPolicy`` is policy-only: *how* to execute (backend, interpret mode,
VMEM budget, explicit GEMM grid overrides) — never *what* to fuse.  Fusion
is a planner decision (``core/chain.plan`` -> ``ChainPlan``, DESIGN.md §5):
the planner fuses the longest stage run whose working set fits the policy's
``vmem_budget`` and degrades 3-fused -> 2-fused -> unfused on its own.  The
legacy ``fused`` boolean survives one release as a deprecated tri-state
override for the old call sites.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax

from repro.kernels.blocking import DEFAULT_VMEM_BUDGET


def resolve_impl(impl: str) -> str:
    """'auto' -> 'pallas' on TPU backends, 'xla' elsewhere; else pass-through.

    Single source of truth for backend resolution (used by ``kernels/ops``,
    ``kernels/lowering`` and ``KernelPolicy.resolved``).
    """
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    if impl not in ("pallas", "xla"):
        raise ValueError(f"unknown impl {impl!r} (want auto|pallas|xla)")
    return impl


@dataclasses.dataclass(frozen=True)
class KernelPolicy:
    """Global execution policy for the paper's ops.

    impl: "auto" | "xla" | "pallas". interpret=True only for CPU validation.
    vmem_budget: HBM->VMEM working-set budget the chain planner and the
    per-kernel planners size blocks against (DESIGN.md §4/§5).
    block_g/co/ci: explicit GEMM grid overrides; None (default) defers to
    the dtype-aware planner (kernels/blocking.plan_pwconv).

    fused: DEPRECATED. Fusion is a planner decision now — ``None`` (the
    default) lets ``core/chain.plan`` fuse whatever fits the budget;
    ``False`` forces the unfused composition (the old default behavior);
    ``True`` is accepted for old call sites and means the same as ``None``.

    autotune: measured plan selection (kernels/autotune.py). ``True`` makes
    ``core/chain.plan``/``execute`` consult the persistent tune cache and,
    on a miss, measure the candidate ladder on the first ``execute`` call
    (the winner is persisted, so later runs replay it without measuring).
    ``False`` (default) keeps today's analytic planner.
    tune_cache: path of the on-disk JSON tune cache; ``None`` uses
    ``kernels/autotune.default_cache_path()`` ($REPRO_TUNE_CACHE or
    ~/.cache/repro/autotune.json).
    """
    impl: str = "auto"
    interpret: bool = False
    vmem_budget: int = DEFAULT_VMEM_BUDGET
    fused: Optional[bool] = None
    autotune: bool = False
    tune_cache: Optional[str] = None
    block_g: Optional[int] = None
    block_co: Optional[int] = None
    block_ci: Optional[int] = None

    def resolved(self) -> str:
        return resolve_impl(self.impl)

    @property
    def fusion_allowed(self) -> bool:
        """Planner gate from the deprecated knob: only ``fused=False``
        (the explicit legacy opt-out) disables fusion."""
        return self.fused is not False


DEFAULT_POLICY = KernelPolicy()
