"""Output-stationary pointwise-conv / GEMM Pallas kernel (paper Alg. 6, RTRD).

The paper's PWConv contribution: make the GEMM kernel *output-stationary* —
the output tile ``D`` stays in fast storage across the entire reduction (Ci)
loop and is stored exactly once, instead of the BLAS/RTRA pattern where ``D``
round-trips per reduction block.

TPU adaptation (DESIGN.md §2): "registers" become a VMEM-resident fp32
accumulator tile. The Pallas grid is ``(G/Gb, Co/Cob, Ci/Cib)`` with the
reduction axis **innermost** and the output BlockSpec index map ignoring it,
so the accumulator tile is revisited across all Ci steps and written back to
HBM once — RTRD at the VMEM level. The RTRA pathology (reduction outermost)
would spill/refetch the accumulator tile to HBM ``Ci/Cib`` times.

Epilogue fusion (bias + activation) is a beyond-paper addition: it removes an
extra HBM round-trip of the output that a separate bias/act op would cost.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Shared bias+activation tail (kernels/epilogue.py) — the same jnp ops trace
# inside the kernel body; `_epilogue` stays as an alias for old call sites.
from repro.kernels.epilogue import apply_epilogue as _epilogue
from repro.kernels.gridspec import (BlockRef, KernelModel,
                                    in_specs_from_model,
                                    out_spec_from_model)


def pw_clamp_blocks(g: int, ci: int, co: int, block_g: int, block_co: int,
                    block_ci: int) -> tuple[int, int, int]:
    """Clamp requested block sizes to the problem (never below the fp32
    (8, 128) tile) — the kernel and the analyzer apply the same rule."""
    bg = min(block_g, max(8, g))
    bco = min(block_co, max(128, co))
    bci = min(block_ci, max(128, ci))
    return bg, bco, bci


def pw_kernel_model(*, g: int, ci: int, co: int, bg: int, bci: int, bco: int,
                    has_bias: bool, itemsize: int,
                    out_itemsize: int) -> KernelModel:
    """The exact grid/BlockSpec geometry ``pwconv_pallas`` lowers to at the
    (already clamped) blocks — consumed by both the kernel and the static
    analyzer (DESIGN.md §8).  Shapes are the padded shapes handed to
    ``pl.pallas_call``."""
    gp = g + (-g) % bg
    cip = ci + (-ci) % bci
    cop = co + (-co) % bco
    inputs = [
        BlockRef("x", (gp, cip), (bg, bci),
                 lambda i, j, k: (i, k), itemsize),
        BlockRef("w", (cip, cop), (bci, bco),
                 lambda i, j, k: (k, j), itemsize),
    ]
    if has_bias:
        inputs.append(BlockRef("bias", (1, cop), (1, bco),
                               lambda i, j, k: (0, j), itemsize))
    return KernelModel(
        name="pwconv",
        grid=(gp // bg, cop // bco, cip // bci),
        dimension_semantics=("parallel", "parallel", "arbitrary"),
        inputs=tuple(inputs),
        output=BlockRef("out", (gp, cop), (bg, bco),
                        lambda i, j, k: (i, j), out_itemsize),
        scratch_bytes=bg * bco * 4,                # fp32 accumulator
    )


def _rtrd_kernel(*refs, nk: int, activation, out_dtype):
    """Grid (g, j, k); k innermost. acc_ref: VMEM (Gb, Cob) fp32 scratch.

    refs = (x_ref, w_ref, [bias_ref,] out_ref, acc_ref).
    """
    if len(refs) == 5:
        x_ref, w_ref, bias_ref, out_ref, acc_ref = refs
    else:
        x_ref, w_ref, out_ref, acc_ref = refs
        bias_ref = None
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # The output tile (acc) stays resident; only A/B tiles stream. == RTRD.
    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _store():  # single store of the output tile (paper lines 29-34)
        acc = acc_ref[...]
        acc = _epilogue(acc, bias_ref[...] if bias_ref is not None else None,
                        activation)
        out_ref[...] = acc.astype(out_dtype)


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(
    jax.jit,
    static_argnames=(
        "activation", "block_g", "block_co", "block_ci", "interpret",
        "out_dtype",
    ),
)
def pwconv_pallas(
    x: jax.Array,
    w: jax.Array,
    bias: Optional[jax.Array] = None,
    *,
    activation: Optional[str] = None,
    block_g: int = 256,
    block_co: int = 256,
    block_ci: int = 256,
    interpret: bool = False,
    out_dtype: Optional[str] = None,
) -> jax.Array:
    """x: (G, Ci) @ w: (Ci, Co) [+ bias (Co,)] -> (G, Co), fp32 accumulate.

    Block sizes are multiples of the (8, 128) fp32 tile; defaults sized so
    x/w/acc tiles (3 * 256*256*4B = 768 KiB) leave VMEM room for
    double-buffering the streamed A/B tiles.

    ``out_dtype`` (dtype NAME, static): store width of the single output
    write — used by the mixed-precision chain lowering (DESIGN.md §7);
    ``None`` stores at ``x.dtype``.  Accumulation is fp32 either way.
    """
    g, ci = x.shape
    ci2, co = w.shape
    assert ci == ci2, (x.shape, w.shape)
    out_dtype = jnp.dtype(out_dtype) if out_dtype is not None else x.dtype

    bg, bco, bci = pw_clamp_blocks(g, ci, co, block_g, block_co, block_ci)

    xp = _pad_to(_pad_to(x, 0, bg), 1, bci)
    wp = _pad_to(_pad_to(w, 0, bci), 1, bco)
    gp, cip = xp.shape
    cop = wp.shape[1]
    nk = cip // bci

    # Grid and BlockSpecs come from the kernel model — the same object the
    # static analyzer (repro.analysis) checks (DESIGN.md §8).
    model = pw_kernel_model(
        g=g, ci=ci, co=co, bg=bg, bci=bci, bco=bco, has_bias=bias is not None,
        itemsize=x.dtype.itemsize, out_itemsize=out_dtype.itemsize,
    )
    inputs = [xp, wp]
    if bias is not None:
        inputs.append(_pad_to(bias.reshape(1, -1), 1, bco))
    for arr, br in zip(inputs, model.inputs):
        assert arr.shape == br.array_shape, (br.name, arr.shape,
                                             br.array_shape)

    kernel = functools.partial(
        _rtrd_kernel, nk=nk, activation=activation, out_dtype=out_dtype
    )
    try:
        compiler_params = pltpu.CompilerParams(
            dimension_semantics=model.dimension_semantics
        )
    except AttributeError:  # older naming
        compiler_params = pltpu.TPUCompilerParams(
            dimension_semantics=model.dimension_semantics
        )

    out = pl.pallas_call(
        kernel,
        grid=model.grid,
        in_specs=in_specs_from_model(model),
        out_specs=out_spec_from_model(model),
        out_shape=jax.ShapeDtypeStruct(model.output.array_shape, out_dtype),
        scratch_shapes=[pltpu.VMEM((bg, bco), jnp.float32)],
        compiler_params=compiler_params,
        interpret=interpret,
    )(*inputs)
    return out[:g, :co]
