"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernel tests ``assert_allclose`` against, and
the XLA fallback path used on hosts without a TPU (this container). They are
written for clarity, not speed; the jitted dispatch in :mod:`repro.kernels.ops`
picks between these and the Pallas implementations.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.epilogue import apply_epilogue

# ---------------------------------------------------------------------------
# Depthwise 2-D convolution (paper Alg. 1/4), NHWC, filter (Hf, Wf, C).
# ---------------------------------------------------------------------------


def dwconv2d_ref(
    x: jax.Array,
    f: jax.Array,
    *,
    stride: int = 1,
    padding: str = "valid",
) -> jax.Array:
    """Depthwise conv. x: (B, Hi, Wi, C); f: (Hf, Wf, C) -> (B, Ho, Wo, C)."""
    assert x.ndim == 4 and f.ndim == 3 and x.shape[-1] == f.shape[-1]
    c = x.shape[-1]
    # lax depthwise: rhs (Hf, Wf, 1, C) with feature_group_count=C, NHWC/HWIO/NHWC.
    rhs = f[:, :, None, :]
    out = jax.lax.conv_general_dilated(
        x.astype(jnp.float32),
        rhs.astype(jnp.float32),
        window_strides=(stride, stride),
        padding=padding.upper(),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=c,
    )
    return out.astype(x.dtype)


def dwconv2d_loops_ref(
    x: np.ndarray, f: np.ndarray, *, stride: int = 1
) -> np.ndarray:
    """Paper Alg. 1 (unoptimized 5-nested-loop MAC), VALID padding, numpy.

    Deliberately literal — used to cross-check the lax oracle itself.
    """
    b, hi, wi, c = x.shape
    hf, wf, _ = f.shape
    ho = (hi - hf) // stride + 1
    wo = (wi - wf) // stride + 1
    out = np.zeros((b, ho, wo, c), dtype=np.float64)
    for bb in range(b):
        for l in range(ho):
            for k in range(wo):
                for i in range(c):
                    for n in range(hf):
                        for m in range(wf):
                            out[bb, l, k, i] += (
                                x[bb, l * stride + n, k * stride + m, i] * f[n, m, i]
                            )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Depthwise causal 1-D convolution (SSM/Mamba conv preactivation).
# ---------------------------------------------------------------------------


def dwconv1d_causal_ref(x: jax.Array, f: jax.Array) -> jax.Array:
    """Causal depthwise conv. x: (B, L, D); f: (K, D) -> (B, L, D).

    out[b, l, d] = sum_k x[b, l - (K-1) + k, d] * f[k, d]  (zero left-pad).
    """
    assert x.ndim == 3 and f.ndim == 2 and x.shape[-1] == f.shape[-1]
    k = f.shape[0]
    xp = jnp.pad(x.astype(jnp.float32), ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros(x.shape, jnp.float32)
    for i in range(k):  # K is tiny (3..5) and static — unrolled shifts.
        out = out + xp[:, i : i + x.shape[1], :] * f[i][None, None, :].astype(
            jnp.float32
        )
    return out.astype(x.dtype)


def dwconv1d_step_ref(
    state: jax.Array, x_t: jax.Array, f: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Single decode step. state: (B, K-1, D) past inputs; x_t: (B, D).

    Returns (new_state, y_t) with y_t = causal conv output at this position.
    """
    k = f.shape[0]
    window = jnp.concatenate([state, x_t[:, None, :]], axis=1)  # (B, K, D)
    y = jnp.einsum("bkd,kd->bd", window.astype(jnp.float32), f.astype(jnp.float32))
    return window[:, 1:, :] if k > 1 else state, y.astype(x_t.dtype)


# ---------------------------------------------------------------------------
# Pointwise convolution == GEMM (paper Alg. 3/5/6).
# ---------------------------------------------------------------------------


# The bias+activation tail is shared package-wide (kernels/epilogue.py);
# `_epilogue` stays as an alias for old call sites.
_epilogue = apply_epilogue


@jax.custom_vjp
def _mm(x: jax.Array, w: jax.Array) -> jax.Array:
    return jnp.dot(x, w, preferred_element_type=jnp.float32)


def _mm_fwd(x, w):
    return _mm(x, w), (x, w)


def _mm_bwd(res, g):
    """Grads cast to the *param dtype before* any cross-device reduction:
    with bf16 weights the partial-dW all-reduce/reduce-scatter moves half
    the bytes (Megatron-style bf16 gradient reduction). Microbatch
    accumulation upstream still sums in fp32."""
    x, w = res
    dx = jnp.dot(g, w.T, preferred_element_type=jnp.float32).astype(x.dtype)
    ci = x.shape[-1]
    x2 = x.reshape(-1, ci)
    g2 = g.reshape(-1, g.shape[-1])
    dw = jnp.dot(x2.T, g2, preferred_element_type=jnp.float32).astype(w.dtype)
    return dx, dw


_mm.defvjp(_mm_fwd, _mm_bwd)


def pwconv_ref(
    x: jax.Array,
    w: jax.Array,
    *,
    bias: Optional[jax.Array] = None,
    activation: Optional[str] = None,
) -> jax.Array:
    """Pointwise conv / GEMM. x: (..., Ci); w: (Ci, Co) -> (..., Co).

    fp32 accumulation regardless of input dtype (matches MXU semantics);
    backward reduces gradients in the param dtype (see _mm_bwd).
    """
    y = _mm(x, w)
    y = _epilogue(y, bias, activation)
    return y.astype(x.dtype)


def separable_fused_ref(
    x: jax.Array,
    dw_f: jax.Array,
    pw_w: jax.Array,
    dw_bias: Optional[jax.Array] = None,
    pw_bias: Optional[jax.Array] = None,
    residual: Optional[jax.Array] = None,
    *,
    expand_w: Optional[jax.Array] = None,
    expand_activation: Optional[str] = "relu6",
    stride: int = 1,
    padding: str = "valid",
    dw_activation: Optional[str] = "relu6",
    activation: Optional[str] = None,
) -> jax.Array:
    """Oracle for the fused DW+PW block (kernels/separable_fused.py).

    Same math as the fused kernel: the DW output stays fp32 into the GEMM
    (the unfused composition rounds it to the activation dtype in between).
    With ``expand_w`` (Ci, C) the bias-free PW-expand stage runs first, also
    kept fp32 into the DW stage (the 3-stage chain's numerics).
    """
    y = x.astype(jnp.float32)
    if expand_w is not None:
        y = jnp.dot(y, expand_w.astype(jnp.float32),
                    preferred_element_type=jnp.float32)
        y = _epilogue(y, None, expand_activation)
    y = dwconv2d_ref(
        y, dw_f.astype(jnp.float32),
        stride=stride, padding=padding,
    )
    if dw_bias is not None:
        y = y + dw_bias.astype(jnp.float32)
    y = _epilogue(y, None, dw_activation)
    out = jnp.dot(
        y, pw_w.astype(jnp.float32), preferred_element_type=jnp.float32
    )
    out = _epilogue(out, pw_bias, activation)
    if residual is not None:
        out = out + residual.astype(jnp.float32)
    return out.astype(x.dtype)


def conv2d_ref(
    x: jax.Array,
    f: jax.Array,
    bias: Optional[jax.Array] = None,
    *,
    stride: int = 1,
    padding: str = "valid",
    activation: Optional[str] = None,
) -> jax.Array:
    """Full dense conv (the FusedMB stage).  x: (B, Hi, Wi, Ci);
    f: (Hf, Wf, Ci, Co) -> (B, Ho, Wo, Co), fp32 accumulation."""
    assert x.ndim == 4 and f.ndim == 4 and x.shape[-1] == f.shape[2]
    y = jax.lax.conv_general_dilated(
        x.astype(jnp.float32),
        f.astype(jnp.float32),
        window_strides=(stride, stride),
        padding=padding.upper(),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    y = _epilogue(y, bias, activation)
    return y.astype(x.dtype)


def fused_mbconv_ref(
    x: jax.Array,
    mb_f: jax.Array,
    pw_w: jax.Array,
    mb_bias: Optional[jax.Array] = None,
    pw_bias: Optional[jax.Array] = None,
    residual: Optional[jax.Array] = None,
    *,
    stride: int = 1,
    padding: str = "valid",
    mb_activation: Optional[str] = "relu6",
    activation: Optional[str] = None,
) -> jax.Array:
    """Oracle for the fused-MBConv block (kernels/fused_mbconv.py): full
    conv -> act -> PW-project, the conv output kept fp32 into the GEMM
    (the unfused composition rounds it to the activation dtype between)."""
    y = jax.lax.conv_general_dilated(
        x.astype(jnp.float32),
        mb_f.astype(jnp.float32),
        window_strides=(stride, stride),
        padding=padding.upper(),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    y = _epilogue(y, mb_bias.astype(jnp.float32)
                  if mb_bias is not None else None, mb_activation)
    out = jnp.dot(
        y, pw_w.astype(jnp.float32), preferred_element_type=jnp.float32
    )
    out = _epilogue(out, pw_bias, activation)
    if residual is not None:
        out = out + residual.astype(jnp.float32)
    return out.astype(x.dtype)


def se_ref(
    x: jax.Array,
    w1: jax.Array,
    b1: jax.Array,
    w2: jax.Array,
    b2: jax.Array,
    *,
    activation: str = "relu",
) -> jax.Array:
    """Squeeze-excite oracle: global-avg-pool -> FC-reduce (``activation``)
    -> FC-expand -> sigmoid -> channelwise scale.  x: (B, H, W, C);
    w1: (C, Cse); w2: (Cse, C) -> (B, H, W, C), all fp32 internally."""
    xf = x.astype(jnp.float32)
    pooled = jnp.mean(xf, axis=(1, 2))                       # (B, C)
    hid = _epilogue(jnp.dot(pooled, w1.astype(jnp.float32),
                            preferred_element_type=jnp.float32),
                    b1.astype(jnp.float32), activation)
    gate = jax.nn.sigmoid(jnp.dot(hid, w2.astype(jnp.float32),
                                  preferred_element_type=jnp.float32)
                          + b2.astype(jnp.float32))          # (B, C)
    return (xf * gate[:, None, None, :]).astype(x.dtype)


def dw_se_ref(
    x: jax.Array,
    dw_f: jax.Array,
    w1: jax.Array,
    b1: jax.Array,
    w2: jax.Array,
    b2: jax.Array,
    dw_bias: Optional[jax.Array] = None,
    *,
    stride: int = 1,
    padding: str = "valid",
    dw_activation: Optional[str] = "relu6",
    se_activation: str = "relu",
) -> jax.Array:
    """Oracle for the fused DW + SE-epilogue pass
    (kernels/se_epilogue.py): the DW output stays fp32 into the pool, the
    two gate FCs and the final scale (the unfused composition rounds it to
    the activation dtype in between)."""
    y = dwconv2d_ref(x.astype(jnp.float32), dw_f.astype(jnp.float32),
                     stride=stride, padding=padding)
    if dw_bias is not None:
        y = y + dw_bias.astype(jnp.float32)
    y = _epilogue(y, None, dw_activation)
    pooled = jnp.mean(y, axis=(1, 2))                        # (B, C)
    hid = _epilogue(jnp.dot(pooled, w1.astype(jnp.float32),
                            preferred_element_type=jnp.float32),
                    b1.astype(jnp.float32), se_activation)
    gate = jax.nn.sigmoid(jnp.dot(hid, w2.astype(jnp.float32),
                                  preferred_element_type=jnp.float32)
                          + b2.astype(jnp.float32))
    return (y * gate[:, None, None, :]).astype(x.dtype)


def matmul_rtra_ref(
    a: jax.Array, b: jax.Array, *, block_k: int = 128
) -> jax.Array:
    """Paper Alg. 5 loop structure (A-stationary, k-outermost): the BLAS/RTRA
    baseline. Semantically identical to ``a @ b``; the loop embodies the
    output-tile round-trip per reduction block that the paper identifies as
    the AI flaw of BLAS kernels. Used for traffic modeling + as a second oracle.
    """
    g, ci = a.shape
    ci2, co = b.shape
    assert ci == ci2
    nk = max(1, (ci + block_k - 1) // block_k)
    pad = nk * block_k - ci
    if pad:
        a = jnp.pad(a, ((0, 0), (0, pad)))
        b = jnp.pad(b, ((0, pad), (0, 0)))
    a3 = a.reshape(g, nk, block_k).transpose(1, 0, 2)  # (nk, G, bk)
    b3 = b.reshape(nk, block_k, co)

    def body(k, acc):  # out tile is re-read and re-written every k step (RTRA)
        return acc + jnp.dot(
            a3[k], b3[k], preferred_element_type=jnp.float32
        )

    out = jax.lax.fori_loop(0, nk, body, jnp.zeros((g, co), jnp.float32))
    return out.astype(a.dtype)
