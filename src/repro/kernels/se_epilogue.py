"""DW + squeeze-excite epilogue Pallas kernel: DW conv -> global-avg-pool
-> FC-reduce -> act -> FC-expand -> sigmoid -> channelwise scale, in ONE
pass (the MnasNet-A1 SE placement, DESIGN.md §10).

MnasNet puts SE directly after the DW stage, and the SE gate consumes
exactly the tensor the DW kernel just produced — composed through HBM the
DW output takes a full round-trip (store by DW, re-load by the pool AND
re-load by the scale) purely to compute two tiny FCs over its spatial
mean.  This kernel keeps the DW output VMEM-resident and applies the whole
gate as an in-kernel epilogue: it is stored exactly once, already scaled.

Residency contract — and why there is NO block ladder here: the squeeze FC
mixes ALL channels of the pooled vector, and the pool itself spans ALL
spatial positions, so the kernel requires full-channel (``block_c == C``)
full-spatial (``n_slabs == 1``) residency per batch image.  A
partial-channel or slabbed variant would compute the gate from a partial
mean — a WRONG answer, not a slower one — so ``blocking.plan_dw_se``
either fits the whole working set or returns None and ``core/chain.plan``
degrades to a standalone DW + the standalone two-GEMM SE pass (segment
kinds ``dw`` + ``se``).  The static analyzer enforces the same contract as
rule PL114.

Grid: ``(B,)``, fully parallel — one grid cell owns one image's whole DW
output.  Zero-padding safety for the sigmoid (which does NOT map 0 -> 0
and therefore can never join ``kernels/epilogue.ACTIVATIONS``): padded
channels would carry zero DW output, and ``0 * sigmoid(anything) == 0`` —
but with ``block_c == C`` there is no channel padding in the first place.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import blocking
from repro.kernels.epilogue import apply_epilogue as _epilogue
from repro.kernels.gridspec import (BlockRef, KernelModel,
                                    in_specs_from_model,
                                    out_spec_from_model)


def dw_se_kernel_model(*, b: int, hiu: int, wiu: int, ho: int, wo: int,
                       c: int, c_se: int, hf: int, wf: int,
                       itemsize: int, out_itemsize: int,
                       has_dw_bias: bool) -> KernelModel:
    """The exact grid/BlockSpec geometry ``dw_se_pallas`` lowers to —
    consumed by BOTH the kernel and ``repro.analysis`` (DESIGN.md §8).
    Full-channel, full-spatial blocks by construction (see module doc);
    the gate weights are tiny and fetched whole."""
    inputs = [BlockRef(
        "x", (b, hiu, wiu, c), (1, hiu, wiu, c),
        lambda i: (i, 0, 0, 0), itemsize)]
    inputs.append(BlockRef("dw_f", (hf, wf, c), (hf, wf, c),
                           lambda i: (0, 0, 0), itemsize))
    if has_dw_bias:
        inputs.append(BlockRef("dw_bias", (1, c), (1, c),
                               lambda i: (0, 0), itemsize))
    inputs.append(BlockRef("w1", (c, c_se), (c, c_se),
                           lambda i: (0, 0), itemsize))
    inputs.append(BlockRef("b1", (1, c_se), (1, c_se),
                           lambda i: (0, 0), itemsize))
    inputs.append(BlockRef("w2", (c_se, c), (c_se, c),
                           lambda i: (0, 0), itemsize))
    inputs.append(BlockRef("b2", (1, c), (1, c),
                           lambda i: (0, 0), itemsize))
    out_ref = BlockRef("out", (b, ho, wo, c), (1, ho, wo, c),
                       lambda i: (i, 0, 0, 0), out_itemsize)
    return KernelModel(
        name="dw_se",
        grid=(b,),
        dimension_semantics=("parallel",),
        inputs=tuple(inputs),
        output=out_ref,
        scratch_bytes=0,
        value_bytes=ho * wo * c * 4,          # DW intermediate (fp32)
        reshapes=(((ho, wo, c), (ho * wo, c)),),
    )


def _dw_se_kernel(*refs, hf: int, wf: int, stride: int,
                  dw_activation, se_activation, has_dwb: bool, out_dtype):
    """refs = (x, dw_f, [dw_bias,] w1, b1, w2, b2, out).

    Blocks: x (1, Hiu, Wiu, C) — one image's whole (VALID) input window;
    dw_f (Hf, Wf, C); dw_bias (1, C); w1 (C, Cse); b1 (1, Cse);
    w2 (Cse, C); b2 (1, C); out (1, Ho, Wo, C).
    """
    it = iter(refs)
    x_ref = next(it)
    f_ref = next(it)
    dwb_ref = next(it) if has_dwb else None
    w1_ref = next(it)
    b1_ref = next(it)
    w2_ref = next(it)
    b2_ref = next(it)
    out_ref = next(it)

    _, ho, wo, c = out_ref.shape
    x = x_ref[0].astype(jnp.float32)
    f = f_ref[...].astype(jnp.float32)
    s = stride

    # --- DW stage: shift-and-FMA over ALL channels (dwconv2d Alg. 4) ---
    dw = jnp.zeros((ho, wo, c), jnp.float32)
    for n in range(hf):
        for m in range(wf):
            win = jax.lax.slice(
                x,
                (n, m, 0),
                (n + (ho - 1) * s + 1, m + (wo - 1) * s + 1, c),
                (s, s, 1),
            )
            dw = dw + win * f[n, m][None, None, :]
    dw = _epilogue(
        dw, dwb_ref[0][None, None, :] if dwb_ref is not None else None,
        dw_activation,
    )

    # --- SE epilogue: pool -> reduce FC -> act -> expand FC -> sigmoid ---
    # (every intermediate is a VMEM value; the DW output is never stored
    # unscaled)
    pooled = jnp.mean(dw.reshape(ho * wo, c), axis=0, keepdims=True)
    hid = jnp.dot(pooled, w1_ref[...].astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    hid = _epilogue(hid, b1_ref[0][None, :].astype(jnp.float32),
                    se_activation)
    gate = jnp.dot(hid, w2_ref[...].astype(jnp.float32),
                   preferred_element_type=jnp.float32)
    gate = jax.nn.sigmoid(gate + b2_ref[0][None, :].astype(jnp.float32))

    out_ref[0] = (dw * gate.reshape(1, 1, c)).astype(out_dtype)


@functools.partial(
    jax.jit,
    static_argnames=("stride", "dw_activation", "se_activation",
                     "interpret", "out_dtype"),
)
def dw_se_pallas(
    x: jax.Array,
    dw_f: jax.Array,
    w1: jax.Array,
    b1: jax.Array,
    w2: jax.Array,
    b2: jax.Array,
    dw_bias: Optional[jax.Array] = None,
    *,
    stride: int = 1,
    dw_activation: Optional[str] = "relu6",
    se_activation: str = "relu",
    interpret: bool = False,
    out_dtype: Optional[str] = None,
) -> jax.Array:
    """Fused DW + squeeze-excite pass.  x (B,Hi,Wi,C); dw_f (Hf,Wf,C);
    w1 (C,Cse); b1 (Cse,); w2 (Cse,C); b2 (C,) [+ dw_bias (C,)]
    -> (B,Ho,Wo,C), the DW output channelwise-scaled by the SE gate.

    VALID geometry — SAME padding is applied by the wrapper (lowering.py).
    Raises ValueError when the full-channel full-spatial working set
    exceeds the VMEM budget (callers should have consulted
    ``blocking.plan_dw_se`` and degraded to standalone DW + SE instead).
    """
    b, hi, wi, c = x.shape
    odt = jnp.dtype(out_dtype) if out_dtype is not None else x.dtype
    hf, wf, cf = dw_f.shape
    c1, c_se = w1.shape
    assert c == cf == c1 and w2.shape == (c_se, c), (
        x.shape, dw_f.shape, w1.shape, w2.shape)
    ho = (hi - hf) // stride + 1
    wo = (wi - wf) // stride + 1
    assert ho >= 1 and wo >= 1, "input smaller than filter"
    hiu = (ho - 1) * stride + hf
    wiu = (wo - 1) * stride + wf

    plan = blocking.plan_dw_se(hiu, wiu, ho, wo, c, c_se, hf, wf,
                               dtype=x.dtype)
    if plan is None:
        raise ValueError(
            f"dw_se working set exceeds VMEM for {(hi, wi, c, c_se)}; "
            "use the standalone DW + SE composition")

    x = x[:, :hiu, :wiu, :]
    model = dw_se_kernel_model(
        b=b, hiu=hiu, wiu=wiu, ho=ho, wo=wo, c=c, c_se=c_se, hf=hf, wf=wf,
        itemsize=x.dtype.itemsize, out_itemsize=odt.itemsize,
        has_dw_bias=dw_bias is not None,
    )
    inputs = [x, dw_f]
    if dw_bias is not None:
        inputs.append(dw_bias.reshape(1, -1))
    inputs.extend([w1, b1.reshape(1, -1), w2, b2.reshape(1, -1)])
    for arr, br in zip(inputs, model.inputs):
        assert arr.shape == br.array_shape, (br.name, arr.shape,
                                             br.array_shape)

    kernel = functools.partial(
        _dw_se_kernel, hf=hf, wf=wf, stride=stride,
        dw_activation=dw_activation, se_activation=se_activation,
        has_dwb=dw_bias is not None, out_dtype=odt,
    )
    try:
        compiler_params = pltpu.CompilerParams(
            dimension_semantics=model.dimension_semantics
        )
    except AttributeError:
        compiler_params = pltpu.TPUCompilerParams(
            dimension_semantics=model.dimension_semantics
        )

    return pl.pallas_call(
        kernel,
        grid=model.grid,
        in_specs=in_specs_from_model(model),
        out_specs=out_spec_from_model(model),
        out_shape=jax.ShapeDtypeStruct(model.output.array_shape, odt),
        compiler_params=compiler_params,
        interpret=interpret,
    )(*inputs)
