"""Fused depthwise-separable block Pallas kernel (DW3x3 -> act -> PW GEMM),
with an optional expand-on-the-fly stage (PW-expand -> DW -> PW-project in
ONE pass — the full MobileNetV2 inverted residual).

The paper's thesis one level up (DESIGN.md §3): ``dwconv2d`` and ``pwconv``
are both memory-bound, and composing them through HBM makes the DW output —
a tensor the size of the block's activation — take a full HBM round-trip
(one store by the DW kernel, one load per Co panel by the PW kernel) purely
as an artifact of op granularity. This kernel computes

    DW(HfxWf, stride) (+ folded-BN bias) -> activation -> PW GEMM
    (+ PW bias, activation, optional residual add)

in ONE grid pass. The DW output tile is produced in VMEM and immediately
consumed as the A-operand of the output-stationary PW reduction; it never
exists in HBM.

Grid and residency (mirrors ``pwconv``'s RTRD structure, plus a spatial
slab dimension):

* grid ``(B, n_slabs, Co/Cob, C/Cb)`` with the channel reduction
  **innermost** and the output BlockSpec ignoring it — the fp32 accumulator
  ``(slab_h*Wo, Cob)`` stays VMEM-resident across the whole reduction of
  its slab and is stored exactly once.
* the **row-slab dimension** bounds the accumulator: each grid cell owns
  ``slab_h`` output rows, and the input BlockSpec (``pl.unblocked``
  element-offset indexing) fetches the overlapping
  ``(slab_h-1)*stride + Hf`` input-row window for that slab — adjacent
  slabs re-fetch a ``Hf - stride`` row halo at each interior seam. This is
  what lifts the old ~1.5M-pixel accumulator ceiling (DESIGN.md §3): any
  resolution now fuses, at the cost of the (tiny) halo re-read counted in
  ``core.intensity.separable_traffic_fused``.
* per reduction step, the kernel runs the ``dwconv2d`` shift-and-FMA over
  one channel slab (VPU work), applies bias+activation, reshapes to
  ``(slab_h*Wo, Cb)`` and feeds the MXU matmul against the ``(Cb, Cob)``
  weight tile. DW output lives only as that VMEM value.

Traffic win (``core.intensity.separable_traffic_*``): with a single Co panel
(the common MobileNet case — the planner targets it) the fused block removes
exactly the intermediate round-trip, ``2 * B*Ho*Wo*C * dtype`` bytes, minus
the halo re-reads when slabbed. Channel padding is harmless for any
activation: padded DW channels multiply zero-padded PW weight rows, so their
contribution is exactly zero. Row padding (when ``slab_h`` does not divide
``Ho``) computes zero-input garbage rows that are cropped before return.

Expand-on-the-fly (the 3-stage V2 chain, DESIGN.md §5): with ``expand_w``
``(Ci, C)`` given, the kernel's input is the RAW ``Ci``-channel tensor and
each reduction step first computes its expanded-channel slab as a per-slab
GEMM — ``x_window.reshape(slab_hi*Wiu, Ci) @ expand_w[:, k*Cb:(k+1)*Cb]``
into a VMEM fp32 value — applies the expand activation, and feeds that
value to the DW shift-and-FMA in place of the streamed input.  Neither the
expanded tensor (``B*Hi*Wi*C`` — 6x the input at the usual expansion
factor) nor the DW output ever exists in HBM.  Restriction: the expansion
must be bias-free, because SAME padding is applied to the raw input before
the kernel and a bias would make padding pixels expand to ``act(bias) != 0``
(every supported activation maps 0 -> 0, so bias-free expand commutes with
zero padding).  ``core/chain.plan`` degrades to the 2-stage path when the
spec declares an expand bias.

All block choices come from ``kernels.blocking.plan_separable`` /
``plan_separable3`` (dtype-aware VMEM budget, Co-panel and row-slab
enumeration); when even the minimal plan exceeds the budget the planner
returns None and callers fall back to the unfused composition
(``ops.separable_fused``).

TPU note: the overlapping input windows use ``pl.unblocked`` indexing,
validated in interpret mode like the rest of this package; Mosaic sublane
alignment of un-tiled row offsets is part of the ROADMAP hardware item.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import blocking
from repro.kernels.epilogue import apply_epilogue as _epilogue
from repro.kernels.gridspec import (BlockRef, KernelModel,
                                    in_specs_from_model,
                                    out_spec_from_model)


def fused_kernel_model(*, b: int, ho: int, wo: int, c_in: int, c: int,
                       co: int, hf: int, wf: int, stride: int,
                       block_c: int, block_co: int, slab_h: int,
                       itemsize: int, out_itemsize: int,
                       has_expand: bool, has_dw_bias: bool,
                       has_pw_bias: bool, has_residual: bool) -> KernelModel:
    """The exact grid/BlockSpec geometry ``separable_fused_pallas`` lowers
    to at these blocks — the single source of truth consumed by BOTH the
    kernel (specs built from this model) and the static analyzer
    (``repro.analysis``), so planner<->lowering drift is structurally
    impossible (DESIGN.md §8).

    ``c_in`` is the raw input channel count (== ``c`` without expand).
    Shapes are the PADDED shapes the kernel hands to ``pl.pallas_call``
    after channel/Co/row padding.
    """
    cb, cob = block_c, block_co
    sh = min(slab_h, ho)
    n_slabs = -(-ho // sh)
    ho_p = n_slabs * sh
    slab_hi = (sh - 1) * stride + hf
    wiu = (wo - 1) * stride + wf
    pad_c = (-c) % cb
    pad_co = (-co) % cob
    cp, cop = c + pad_c, co + pad_co
    nk = cp // cb
    rows_in = (ho_p - 1) * stride + hf

    # x window: element-offset (unblocked) indexing — adjacent slabs'
    # windows overlap by the (hf - stride)-row halo.  With expand the
    # window carries ALL raw channels; without, one channel slab.
    if has_expand:
        x_ref = BlockRef(
            "x", (b, rows_in, wiu, c_in), (1, slab_hi, wiu, c_in),
            lambda i, s, j, k, sh=sh, st=stride: (i, s * sh * st, 0, 0),
            itemsize, unblocked=True)
    else:
        x_ref = BlockRef(
            "x", (b, rows_in, wiu, cp), (1, slab_hi, wiu, cb),
            lambda i, s, j, k, sh=sh, st=stride, cb=cb:
                (i, s * sh * st, 0, k * cb),
            itemsize, unblocked=True)
    inputs = [x_ref]
    if has_expand:
        inputs.append(BlockRef("expand_w", (c_in, cp), (c_in, cb),
                               lambda i, s, j, k: (0, k), itemsize))
    inputs.append(BlockRef("dw_f", (hf, wf, cp), (hf, wf, cb),
                           lambda i, s, j, k: (0, 0, k), itemsize))
    if has_dw_bias:
        inputs.append(BlockRef("dw_bias", (1, cp), (1, cb),
                               lambda i, s, j, k: (0, k), itemsize))
    inputs.append(BlockRef("pw_w", (cp, cop), (cb, cob),
                           lambda i, s, j, k: (k, j), itemsize))
    if has_pw_bias:
        inputs.append(BlockRef("pw_bias", (1, cop), (1, cob),
                               lambda i, s, j, k: (0, j), itemsize))
    if has_residual:
        inputs.append(BlockRef("residual", (b, ho_p, wo, cop),
                               (1, sh, wo, cob),
                               lambda i, s, j, k: (i, s, 0, j), itemsize))
    out_ref = BlockRef("out", (b, ho_p, wo, cop), (1, sh, wo, cob),
                       lambda i, s, j, k: (i, s, 0, j), out_itemsize)
    reshapes = [((sh, wo, cb), (sh * wo, cb))]
    value_bytes = sh * wo * cb * 4                 # DW intermediate (fp32)
    if has_expand:
        reshapes.insert(0, ((slab_hi, wiu, c_in), (slab_hi * wiu, c_in)))
        value_bytes += slab_hi * wiu * cb * 4      # expanded slab (fp32)
    return KernelModel(
        name="separable_fused3" if has_expand else "separable_fused2",
        grid=(b, n_slabs, cop // cob, nk),
        dimension_semantics=("parallel", "parallel", "parallel",
                             "arbitrary"),
        inputs=tuple(inputs),
        output=out_ref,
        scratch_bytes=sh * wo * cob * 4,           # fp32 accumulator
        value_bytes=value_bytes,
        reshapes=tuple(reshapes),
    )


def _fused_kernel(*refs, hf: int, wf: int, stride: int, nk: int,
                  dw_activation, activation, has_exp: bool,
                  expand_activation, has_dwb: bool, has_pwb: bool,
                  has_res: bool, out_dtype):
    """refs = (x, [expand_w,] f, [dw_bias,] w, [pw_bias,] [residual,] out,
    acc).

    Blocks: x (1, slab_hi, Wiu, Cb) — the overlapping input window of this
    row slab (with expand: (1, slab_hi, Wiu, Ci), the RAW input, identical
    for every reduction step); expand_w (Ci, Cb); f (Hf, Wf, Cb); dw_bias
    (1, Cb); w (Cb, Cob); pw_bias (1, Cob); residual (1, slab_h, Wo, Cob);
    out (1, slab_h, Wo, Cob); acc VMEM scratch (slab_h*Wo, Cob) fp32.
    """
    it = iter(refs)
    x_ref = next(it)
    ew_ref = next(it) if has_exp else None
    f_ref = next(it)
    dwb_ref = next(it) if has_dwb else None
    w_ref = next(it)
    pwb_ref = next(it) if has_pwb else None
    res_ref = next(it) if has_res else None
    out_ref = next(it)
    acc_ref = next(it)

    _, slab_h, wo, cob = out_ref.shape
    cb = f_ref.shape[2]
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0].astype(jnp.float32)
    if ew_ref is not None:
        # --- expand stage: this step's expanded-channel slab, on the fly ---
        # (slab_hi*Wiu, Ci) @ (Ci, Cb) -> fp32 VMEM value; never in HBM.
        slab_hi, wiu, ci = x.shape
        ex = jnp.dot(
            x.reshape(slab_hi * wiu, ci),
            ew_ref[...].astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        x = _epilogue(ex, None, expand_activation).reshape(slab_hi, wiu, cb)

    # --- DW stage: shift-and-FMA over the channel slab (dwconv2d Alg. 4) ---
    f = f_ref[...].astype(jnp.float32)
    s = stride
    dw = jnp.zeros((slab_h, wo, cb), jnp.float32)
    for n in range(hf):
        for m in range(wf):
            win = jax.lax.slice(
                x,
                (n, m, 0),
                (n + (slab_h - 1) * s + 1, m + (wo - 1) * s + 1, cb),
                (s, s, 1),
            )
            dw = dw + win * f[n, m][None, None, :]
    dw = _epilogue(
        dw, dwb_ref[0][None, None, :] if dwb_ref is not None else None,
        dw_activation,
    )

    # --- PW stage: DW tile (VMEM value, never stored) is the A-operand ---
    a = dw.reshape(slab_h * wo, cb)
    acc_ref[...] += jnp.dot(
        a, w_ref[...].astype(jnp.float32), preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _store():  # single store of the slab's output block
        acc = _epilogue(
            acc_ref[...],
            pwb_ref[...] if pwb_ref is not None else None,
            activation,
        )
        y = acc.reshape(slab_h, wo, cob)
        if res_ref is not None:
            y = y + res_ref[0].astype(jnp.float32)
        out_ref[0] = y.astype(out_dtype)


@functools.partial(
    jax.jit,
    static_argnames=("stride", "dw_activation", "activation",
                     "expand_activation", "block_c", "block_co", "slab_h",
                     "interpret", "out_dtype"),
)
def separable_fused_pallas(
    x: jax.Array,
    dw_f: jax.Array,
    pw_w: jax.Array,
    dw_bias: Optional[jax.Array] = None,
    pw_bias: Optional[jax.Array] = None,
    residual: Optional[jax.Array] = None,
    *,
    expand_w: Optional[jax.Array] = None,
    expand_activation: Optional[str] = "relu6",
    stride: int = 1,
    dw_activation: Optional[str] = "relu6",
    activation: Optional[str] = None,
    block_c: int | None = None,
    block_co: int | None = None,
    slab_h: int | None = None,
    interpret: bool = False,
    out_dtype: Optional[str] = None,
) -> jax.Array:
    """Fused DW+PW block. x (B,Hi,Wi,C); dw_f (Hf,Wf,C); pw_w (C,Co)
    [+ dw_bias (C,), pw_bias (Co,), residual (B,Ho,Wo,Co)] -> (B,Ho,Wo,Co).

    With ``expand_w`` (Ci, C) the input is the RAW (B,Hi,Wi,Ci) tensor and
    the kernel runs the full 3-stage chain — bias-free PW-expand (computed
    on the fly per row slab) -> DW -> PW-project — in one pass.

    ``out_dtype`` (a dtype NAME, static so it participates in the jit key)
    selects the store width of the single output write — the mixed-precision
    chain lowering pins the last pass of a bf16-streamed block to the
    policy's ``out`` dtype (DESIGN.md §7); ``None`` stores at ``x.dtype``.
    The accumulator is fp32 VMEM scratch regardless.

    VALID geometry — SAME padding is applied by the wrapper (ops.py /
    lowering.py).  Block shapes not given explicitly come from
    :func:`repro.kernels.blocking.plan_separable` (or ``plan_separable3``
    with expand); raises ValueError when even the minimal plan exceeds the
    VMEM budget (callers should have consulted the planner and taken a
    degraded path instead).
    """
    b, hi, wi, c_in = x.shape
    odt = jnp.dtype(out_dtype) if out_dtype is not None else x.dtype
    hf, wf, cf = dw_f.shape
    cw, co = pw_w.shape
    if expand_w is not None:
        ci_raw, c = expand_w.shape
        assert ci_raw == c_in and c == cf == cw, (
            x.shape, expand_w.shape, dw_f.shape, pw_w.shape)
    else:
        c = c_in
        assert c == cf == cw, (x.shape, dw_f.shape, pw_w.shape)
    ho = (hi - hf) // stride + 1
    wo = (wi - wf) // stride + 1
    assert ho >= 1 and wo >= 1, "input smaller than filter"
    hiu = (ho - 1) * stride + hf
    wiu = (wo - 1) * stride + wf

    if block_c is None or block_co is None or slab_h is None:
        if expand_w is not None:
            plan = blocking.plan_separable3(
                ho, wo, c_in, c, co, stride=stride, hf=hf, wf=wf,
                dtype=x.dtype, residual=residual is not None)
        else:
            plan = blocking.plan_separable(
                ho, wo, c, co, stride=stride, hf=hf, wf=wf, dtype=x.dtype,
                residual=residual is not None)
        if plan is None and (block_c is None or block_co is None):
            raise ValueError(
                f"no fused block plan fits VMEM for {(hi, wi, c, co)}; "
                "use the unfused composition (ops.separable_fused does this)"
            )
        cb = block_c or plan.block_c
        cob = block_co or plan.block_co
        sh = slab_h or (plan.slab_h if plan is not None else ho)
    else:
        cb, cob, sh = block_c, block_co, slab_h
    sh = min(sh, ho)
    n_slabs = -(-ho // sh)
    ho_p = n_slabs * sh
    slab_hi = (sh - 1) * stride + hf

    # Channel / Co padding (zero rows of pw_w nullify padded DW channels;
    # with expand, zero COLUMNS of expand_w make the padded expanded
    # channels exactly zero — every activation maps 0 -> 0).
    pad_c = (-c) % cb
    pad_co = (-co) % cob
    if pad_c:
        if expand_w is not None:
            expand_w = jnp.pad(expand_w, ((0, 0), (0, pad_c)))
        else:
            x = jnp.pad(x, ((0, 0), (0, 0), (0, 0), (0, pad_c)))
        dw_f = jnp.pad(dw_f, ((0, 0), (0, 0), (0, pad_c)))
        pw_w = jnp.pad(pw_w, ((0, pad_c), (0, 0)))
        if dw_bias is not None:
            dw_bias = jnp.pad(dw_bias, ((0, pad_c),))
    if pad_co:
        pw_w = jnp.pad(pw_w, ((0, 0), (0, pad_co)))
        if pw_bias is not None:
            pw_bias = jnp.pad(pw_bias, ((0, pad_co),))
    if pad_co and residual is not None:
        residual = jnp.pad(residual, ((0, 0), (0, 0), (0, 0), (0, pad_co)))
    cp, cop = c + pad_c, co + pad_co
    nk = cp // cb

    # Row padding so the slab grid tiles Ho: the last slab's window reads
    # zero rows past the image and its garbage output rows are cropped.
    rows_in = (ho_p - 1) * stride + hf
    x = x[:, :hiu, :wiu, :]
    if rows_in > hiu:
        x = jnp.pad(x, ((0, 0), (0, rows_in - hiu), (0, 0), (0, 0)))
    if ho_p > ho and residual is not None:
        residual = jnp.pad(residual, ((0, 0), (0, ho_p - ho), (0, 0), (0, 0)))

    # The grid and every BlockSpec come from the kernel model — the same
    # object the static analyzer (repro.analysis) checks, so what is proven
    # statically is what executes (DESIGN.md §8).  Input windows of adjacent
    # slabs overlap by (hf - stride) halo rows, so the x BlockSpec uses
    # element-offset (unblocked) indexing; with expand the window carries
    # ALL raw channels (Ci is small; the reduction steps slab the EXPANDED
    # channels via the expand_w block instead).
    model = fused_kernel_model(
        b=b, ho=ho, wo=wo, c_in=c_in, c=c, co=co, hf=hf, wf=wf,
        stride=stride, block_c=cb, block_co=cob, slab_h=sh,
        itemsize=x.dtype.itemsize, out_itemsize=odt.itemsize,
        has_expand=expand_w is not None, has_dw_bias=dw_bias is not None,
        has_pw_bias=pw_bias is not None, has_residual=residual is not None,
    )
    inputs = [x]
    if expand_w is not None:
        inputs.append(expand_w)
    inputs.append(dw_f)
    if dw_bias is not None:
        inputs.append(dw_bias.reshape(1, -1))
    inputs.append(pw_w)
    if pw_bias is not None:
        inputs.append(pw_bias.reshape(1, -1))
    if residual is not None:
        inputs.append(residual)
    for arr, br in zip(inputs, model.inputs):
        assert arr.shape == br.array_shape, (br.name, arr.shape,
                                             br.array_shape)
    in_specs = in_specs_from_model(model)

    kernel = functools.partial(
        _fused_kernel, hf=hf, wf=wf, stride=stride, nk=nk,
        dw_activation=dw_activation, activation=activation,
        has_exp=expand_w is not None, expand_activation=expand_activation,
        has_dwb=dw_bias is not None, has_pwb=pw_bias is not None,
        has_res=residual is not None, out_dtype=odt,
    )
    try:
        compiler_params = pltpu.CompilerParams(
            dimension_semantics=model.dimension_semantics
        )
    except AttributeError:
        compiler_params = pltpu.TPUCompilerParams(
            dimension_semantics=model.dimension_semantics
        )

    assert model.output.array_shape == (b, ho_p, wo, cop)
    out = pl.pallas_call(
        kernel,
        grid=model.grid,
        in_specs=in_specs,
        out_specs=out_spec_from_model(model),
        out_shape=jax.ShapeDtypeStruct(model.output.array_shape, odt),
        scratch_shapes=[pltpu.VMEM((sh * wo, cob), jnp.float32)],
        compiler_params=compiler_params,
        interpret=interpret,
    )(*inputs)
    return out[:, :ho, :, :co]
