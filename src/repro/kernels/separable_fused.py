"""Fused depthwise-separable block Pallas kernel (DW3x3 -> act -> PW GEMM).

The paper's thesis one level up (DESIGN.md §3): ``dwconv2d`` and ``pwconv``
are both memory-bound, and composing them through HBM makes the DW output —
a tensor the size of the block's activation — take a full HBM round-trip
(one store by the DW kernel, one load per Co panel by the PW kernel) purely
as an artifact of op granularity. This kernel computes

    DW(HfxWf, stride) (+ folded-BN bias) -> activation -> PW GEMM
    (+ PW bias, activation, optional residual add)

in ONE grid pass. The DW output tile is produced in VMEM and immediately
consumed as the A-operand of the output-stationary PW reduction; it never
exists in HBM.

Grid and residency (mirrors ``pwconv``'s RTRD structure):

* grid ``(B, Co/Cob, C/Cb)`` with the channel reduction **innermost** and the
  output BlockSpec ignoring it — the fp32 accumulator ``(Ho*Wo, Cob)`` stays
  VMEM-resident across the whole reduction and is stored exactly once.
* per reduction step, the kernel runs the ``dwconv2d`` shift-and-FMA over one
  channel slab (VPU work), applies bias+activation, reshapes to
  ``(Ho*Wo, Cb)`` and feeds the MXU matmul against the ``(Cb, Cob)`` weight
  tile. DW output lives only as that VMEM value.

Traffic win (``core.intensity.separable_traffic_*``): with a single Co panel
(the common MobileNet case — the chooser below targets it) the fused block
removes exactly the intermediate round-trip, ``2 * B*Ho*Wo*C * dtype`` bytes.
Channel padding is harmless for any activation: padded DW channels multiply
zero-padded PW weight rows, so their contribution is exactly zero.

When fusion is NOT profitable or feasible (``_block_sizes`` returns None —
the ``Ho*Wo`` accumulator panel cannot fit VMEM even at the smallest blocks),
callers fall back to the unfused composition; see ``ops.separable_fused``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pwconv import _epilogue


def _snap(cb: int, c: int) -> int:
    """Snap a raw channel-count budget to a usable block: all of ``c``, a
    multiple of 128 lanes, or the tiny-VMEM power-of-two fallback — the same
    preference order as ``dwconv2d._block_c``."""
    if c <= cb:
        return c
    if cb >= 128:
        return (cb // 128) * 128
    p = 1
    while p * 2 <= cb:
        p *= 2
    return p


def _co_candidates(co: int) -> list[int]:
    """Descending Co-block candidates: all of Co first (single panel — the
    traffic-optimal case), then multiples of 128, then powers of two."""
    cands = [co]
    k = ((co - 1) // 128) * 128
    while k >= 128:
        cands.append(k)
        k -= 128
    p = 64
    while p >= 1:
        if p < co:
            cands.append(p)
        p //= 2
    return cands


def _vmem_bytes(hiu: int, wiu: int, ho: int, wo: int, cb: int, cob: int,
                residual: bool = False) -> int:
    """fp32 working-set bytes of the fused kernel at blocks ``(cb, cob)``:
    2x double-buffered input slab + DW intermediate + fp32 accumulator +
    output tile + 2x PW weight tile (+ residual input tile). The single
    source of truth for the chooser below and benchmarks/kernel_vmem.py."""
    out_side = (2 + (2 if residual else 0)) * ho * wo * cob * 4
    per_c = (2 * hiu * wiu + ho * wo + 2 * cob) * 4
    return out_side + cb * per_c


def _block_sizes(
    hiu: int, wiu: int, ho: int, wo: int, c: int, co: int,
    vmem_budget: int = 12 * 1024 * 1024,
    residual: bool = False,
) -> Optional[tuple[int, int]]:
    """Pick ``(block_c, block_co)`` fitting the VMEM budget, or None.

    fp32 accounting via :func:`_vmem_bytes`, consistent with
    ``dwconv2d._block_c``. Prefers a single Co panel (block_co=co), then the
    largest channel slab that still fits.
    """
    for cob in _co_candidates(co):
        base = _vmem_bytes(hiu, wiu, ho, wo, 0, cob, residual=residual)
        rem = vmem_budget - base
        if rem <= 0:
            continue
        per_c = _vmem_bytes(hiu, wiu, ho, wo, 1, cob) - _vmem_bytes(
            hiu, wiu, ho, wo, 0, cob)
        cb_raw = rem // per_c
        if cb_raw < 1:
            continue
        return _snap(int(cb_raw), c), cob
    return None


def _fused_kernel(*refs, hf: int, wf: int, stride: int, nk: int,
                  dw_activation, activation, has_dwb: bool, has_pwb: bool,
                  has_res: bool, out_dtype):
    """refs = (x, f, [dw_bias,] w, [pw_bias,] [residual,] out, acc).

    Blocks: x (1, Hiu, Wiu, Cb); f (Hf, Wf, Cb); dw_bias (1, Cb);
    w (Cb, Cob); pw_bias (1, Cob); residual (1, Ho, Wo, Cob);
    out (1, Ho, Wo, Cob); acc VMEM scratch (Ho*Wo, Cob) fp32.
    """
    it = iter(refs)
    x_ref = next(it)
    f_ref = next(it)
    dwb_ref = next(it) if has_dwb else None
    w_ref = next(it)
    pwb_ref = next(it) if has_pwb else None
    res_ref = next(it) if has_res else None
    out_ref = next(it)
    acc_ref = next(it)

    _, ho, wo, cob = out_ref.shape
    cb = x_ref.shape[3]
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # --- DW stage: shift-and-FMA over the channel slab (dwconv2d Alg. 4) ---
    x = x_ref[0].astype(jnp.float32)
    f = f_ref[...].astype(jnp.float32)
    s = stride
    dw = jnp.zeros((ho, wo, cb), jnp.float32)
    for n in range(hf):
        for m in range(wf):
            win = jax.lax.slice(
                x,
                (n, m, 0),
                (n + (ho - 1) * s + 1, m + (wo - 1) * s + 1, cb),
                (s, s, 1),
            )
            dw = dw + win * f[n, m][None, None, :]
    dw = _epilogue(
        dw, dwb_ref[0][None, None, :] if dwb_ref is not None else None,
        dw_activation,
    )

    # --- PW stage: DW tile (VMEM value, never stored) is the A-operand ---
    a = dw.reshape(ho * wo, cb)
    acc_ref[...] += jnp.dot(
        a, w_ref[...].astype(jnp.float32), preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _store():  # single store of the block output
        acc = _epilogue(
            acc_ref[...],
            pwb_ref[...] if pwb_ref is not None else None,
            activation,
        )
        y = acc.reshape(ho, wo, cob)
        if res_ref is not None:
            y = y + res_ref[0].astype(jnp.float32)
        out_ref[0] = y.astype(out_dtype)


@functools.partial(
    jax.jit,
    static_argnames=("stride", "dw_activation", "activation", "block_c",
                     "block_co", "interpret"),
)
def separable_fused_pallas(
    x: jax.Array,
    dw_f: jax.Array,
    pw_w: jax.Array,
    dw_bias: Optional[jax.Array] = None,
    pw_bias: Optional[jax.Array] = None,
    residual: Optional[jax.Array] = None,
    *,
    stride: int = 1,
    dw_activation: Optional[str] = "relu6",
    activation: Optional[str] = None,
    block_c: int | None = None,
    block_co: int | None = None,
    interpret: bool = False,
) -> jax.Array:
    """Fused DW+PW block. x (B,Hi,Wi,C); dw_f (Hf,Wf,C); pw_w (C,Co)
    [+ dw_bias (C,), pw_bias (Co,), residual (B,Ho,Wo,Co)] -> (B,Ho,Wo,Co).

    VALID geometry — SAME padding is applied by the wrapper (ops.py).
    Raises ValueError when no block shape fits VMEM (callers should have
    consulted :func:`_block_sizes` and taken the unfused path instead).
    """
    b, hi, wi, c = x.shape
    hf, wf, cf = dw_f.shape
    ci, co = pw_w.shape
    assert c == cf == ci, (x.shape, dw_f.shape, pw_w.shape)
    ho = (hi - hf) // stride + 1
    wo = (wi - wf) // stride + 1
    assert ho >= 1 and wo >= 1, "input smaller than filter"
    hiu = (ho - 1) * stride + hf
    wiu = (wo - 1) * stride + wf

    if block_c is None or block_co is None:
        picked = _block_sizes(hiu, wiu, ho, wo, c, co)
        if picked is None:
            raise ValueError(
                f"no fused block shape fits VMEM for {(hi, wi, c, co)}; "
                "use the unfused composition (ops.separable_fused does this)"
            )
        cb = block_c or picked[0]
        cob = block_co or picked[1]
    else:
        cb, cob = block_c, block_co

    # Channel / Co padding (zero rows of pw_w nullify padded DW channels).
    pad_c = (-c) % cb
    pad_co = (-co) % cob
    if pad_c:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, 0), (0, pad_c)))
        dw_f = jnp.pad(dw_f, ((0, 0), (0, 0), (0, pad_c)))
        pw_w = jnp.pad(pw_w, ((0, pad_c), (0, 0)))
        if dw_bias is not None:
            dw_bias = jnp.pad(dw_bias, ((0, pad_c),))
    if pad_co:
        pw_w = jnp.pad(pw_w, ((0, 0), (0, pad_co)))
        if pw_bias is not None:
            pw_bias = jnp.pad(pw_bias, ((0, pad_co),))
        if residual is not None:
            residual = jnp.pad(
                residual, ((0, 0), (0, 0), (0, 0), (0, pad_co)))
    cp, cop = c + pad_c, co + pad_co
    nk = cp // cb

    x = x[:, :hiu, :wiu, :]

    in_specs = [
        pl.BlockSpec((1, hiu, wiu, cb), lambda i, j, k: (i, 0, 0, k)),
        pl.BlockSpec((hf, wf, cb), lambda i, j, k: (0, 0, k)),
    ]
    inputs = [x, dw_f]
    if dw_bias is not None:
        in_specs.append(pl.BlockSpec((1, cb), lambda i, j, k: (0, k)))
        inputs.append(dw_bias.reshape(1, -1))
    in_specs.append(pl.BlockSpec((cb, cob), lambda i, j, k: (k, j)))
    inputs.append(pw_w)
    if pw_bias is not None:
        in_specs.append(pl.BlockSpec((1, cob), lambda i, j, k: (0, j)))
        inputs.append(pw_bias.reshape(1, -1))
    if residual is not None:
        in_specs.append(
            pl.BlockSpec((1, ho, wo, cob), lambda i, j, k: (i, 0, 0, j)))
        inputs.append(residual)

    kernel = functools.partial(
        _fused_kernel, hf=hf, wf=wf, stride=stride, nk=nk,
        dw_activation=dw_activation, activation=activation,
        has_dwb=dw_bias is not None, has_pwb=pw_bias is not None,
        has_res=residual is not None, out_dtype=x.dtype,
    )
    try:
        compiler_params = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        )
    except AttributeError:
        compiler_params = pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        )

    out = pl.pallas_call(
        kernel,
        grid=(b, cop // cob, nk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, ho, wo, cob), lambda i, j, k: (i, 0, 0, j)),
        out_shape=jax.ShapeDtypeStruct((b, ho, wo, cop), x.dtype),
        scratch_shapes=[pltpu.VMEM((ho * wo, cob), jnp.float32)],
        compiler_params=compiler_params,
        interpret=interpret,
    )(*inputs)
    return out[..., :co]
