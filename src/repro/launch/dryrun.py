import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS_EXTRA", "")
)
"""Multi-pod dry run: lower + compile every (arch x shape x mesh) cell.

For each cell this:
  1. builds the production mesh (16,16) or (2,16,16),
  2. builds sharding specs for the train state / serve cache and inputs,
  3. ``jax.jit(step, in_shardings=..., out_shardings=..., donate...)``
     ``.lower(*ShapeDtypeStructs).compile()``,
  4. prints memory_analysis / cost_analysis and writes a JSON artifact with
     the three roofline terms (repro.roofline.analysis).

Shape cells marked inapplicable (long_500k on full-attention archs) are
recorded as skipped with the DESIGN.md rationale.

Usage:
  python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out artifacts/dryrun
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, input_specs, shape_skip_reason
from repro.configs.registry import ARCH_IDS, get_config
from repro.launch.mesh import make_production_mesh
from repro.roofline import analysis
from repro.sharding.rules import (
    ShardingRules,
    batch_pspecs,
    cache_pspecs,
    named,
    param_specs,
    use_rules,
    zero1_specs,
)


def make_rules(mesh, *, mode: str, multi_pod: bool,
               seq_parallel: bool = False,
               serve_weight_fsdp: bool = False) -> ShardingRules:
    """serve_weight_fsdp: 2-D weight sharding even at serve time, for models
    whose TP-16 shard alone exceeds HBM (e.g. 110B dense on v5e)."""
    fsdp = "data" if (mode == "train" or serve_weight_fsdp) else None
    return ShardingRules(
        mesh=mesh,
        batch_axes=("pod", "data") if multi_pod else ("data",),
        model_axis="model",
        fsdp_axis=fsdp,
        seq_axis="model" if seq_parallel else None,
        expert_fsdp_axis="data",   # experts always need the extra axis
    )


def pick_microbatches(cfg, shape_meta, rules) -> int:
    """Heuristic: bound per-device tokens per microbatch so layer-stash
    activations and MoE dispatch buffers fit HBM (baseline; tuned in §Perf)."""
    if shape_meta["kind"] != "train":
        return 1
    dp = 1
    for a in rules.batch_axes:
        dp *= rules.mesh.shape[a]
    b, s = shape_meta["global_batch"], shape_meta["seq_len"]
    tokens_local = b // dp * s
    if cfg.moe is not None:
        budget = 4096       # bounds EP dispatch buffers (~tokens*topk*d)
    elif cfg.d_model >= 3072:
        budget = 8192
    else:
        budget = 16384
    mb = max(1, tokens_local // budget)
    # microbatch count must divide the local batch rows
    while (b // dp) % mb != 0:
        mb -= 1
    return mb


def lower_group_program(cfg, meta, rules, mesh, *, microbatches: int = 1):
    """Lower ONE layer group (no outer scans) for per-layer cost accounting
    (analysis.analyze combines it with the full program; see its docstring).

    Returns (compiled, trips)."""
    import jax.numpy as jnp
    from repro.models import transformer as T
    from repro.serve import serve_step as S
    from repro.sharding.rules import shard_act

    pattern = T.layer_pattern(cfg)
    if cfg.encdec is not None:
        pattern = [T.LayerVariant(kind="dec")]
    groups = cfg.n_layers // len(pattern)
    params_shapes = jax.eval_shape(
        lambda: T.init_params(cfg, jax.random.PRNGKey(0)))
    strip = lambda t: jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape[1:], s.dtype), t)
    group_shapes = {f"blocks_v{vi}": strip(params_shapes[f"blocks_v{vi}"])
                    for vi in range(len(pattern))}
    gspecs = named(mesh, param_specs(group_shapes, rules))

    b, s = meta["global_batch"], meta["seq_len"]
    kind = meta["kind"]
    act = cfg.jax_dtype
    if kind == "train":
        b = max(b // microbatches, 1)
        trips = groups * microbatches
    else:
        trips = groups
    enc_kv = None

    if kind == "decode":
        x_sds = jax.ShapeDtypeStruct((b, 1, cfg.d_model), act)
        prefix = cfg.meta_tokens + cfg.fusion_tokens
        cache_full = S.cache_specs(cfg, b, s + prefix)
        cg = {f"v{vi}": strip(cache_full[f"v{vi}"])
              for vi in range(len(pattern))}
        if cfg.encdec is not None:
            cg["enc"] = {"enc_k": strip(cache_full["enc_k"]),
                         "enc_v": strip(cache_full["enc_v"])}
        cg_specs = named(mesh, cache_pspecs(cg, rules, stacked=False))
        pos_sds = jax.ShapeDtypeStruct((b,), jnp.int32)

        def gfn(p_group, c_group, x, pos):
            enc_kv = None
            if cfg.encdec is not None:
                enc_kv = (c_group["enc"]["enc_k"], c_group["enc"]["enc_v"])
            new_c = {}
            for vi, variant in enumerate(pattern):
                x, new_c[f"v{vi}"] = T.layer_decode(
                    p_group[f"blocks_v{vi}"], x, c_group[f"v{vi}"], pos,
                    cfg, variant, enc_kv=enc_kv)
            return x, new_c

        # pin the cache OUTPUT sharding — otherwise XLA may choose a
        # replicated output and all-gather the whole updated cache
        out_cache_specs = named(mesh, cache_pspecs(
            {k: v for k, v in cg.items() if k != "enc"}, rules,
            stacked=False))
        jitted = jax.jit(gfn, in_shardings=(
            gspecs, cg_specs,
            named(mesh, batch_pspecs(x_sds, rules)),
            named(mesh, batch_pspecs(pos_sds, rules))),
            out_shardings=(named(mesh, batch_pspecs(x_sds, rules)),
                           out_cache_specs),
            donate_argnums=(1,))
        return jitted.lower(group_shapes, cg, x_sds, pos_sds).compile(), trips

    x_sds = jax.ShapeDtypeStruct((b, s, cfg.d_model), act)
    pos_sds = jax.ShapeDtypeStruct((b, s), jnp.int32)
    x_spec = named(mesh, batch_pspecs(x_sds, rules))
    pos_spec = named(mesh, batch_pspecs(pos_sds, rules))

    def fwd(p_group, x, positions):
        x = shard_act(x, "btd")
        for vi, variant in enumerate(pattern):
            def blk(x, p_layer=p_group[f"blocks_v{vi}"], variant=variant):
                y, _ = T.layer_forward(p_layer, x, cfg, variant,
                                       positions=positions)
                return y
            x = (jax.checkpoint(blk)(x) if cfg.remat == "block"
                 else blk(x))
            x = shard_act(x, "btd")
        return x

    if kind == "train":
        def gfn(p_group, x, positions):
            def loss(p, x):
                return jnp.sum(jnp.square(
                    fwd(p, x, positions).astype(jnp.float32)))
            l, (gp, gx) = jax.value_and_grad(loss, argnums=(0, 1))(p_group, x)
            return l, gp, gx

        # dW must come out SHARDED like the weights (as in the real
        # train_step, where it feeds the sharded optimizer state) — without
        # this XLA all-reduces dW to replicated and wildly overstates the
        # per-layer collective bytes.
        jitted = jax.jit(
            gfn, in_shardings=(gspecs, x_spec, pos_spec),
            out_shardings=(jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec()), gspecs, x_spec))
    else:  # prefill
        def gfn(p_group, x, positions):
            return fwd(p_group, x, positions)

        jitted = jax.jit(gfn, in_shardings=(gspecs, x_spec, pos_spec),
                         out_shardings=x_spec)
    return jitted.lower(group_shapes, x_sds, pos_sds).compile(), trips


def lower_cell(arch: str, shape: str, *, multi_pod: bool,
               microbatches: int | None = None, seq_parallel: bool | None = None,
               donate: bool = True, extra_cfg=None, no_fsdp: bool = False,
               pure_dp: bool = False):
    """Returns (compiled, record_stub) or raises."""
    cfg = extra_cfg or get_config(arch)
    meta = SHAPES[shape]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mode = "train" if meta["kind"] == "train" else "serve"
    big = cfg.moe is not None or cfg.d_model >= 8192
    if seq_parallel is None:
        # sequence-parallel activations: always for 32k prefill; for train
        # on MoE / d>=8k archs (bounds the per-layer remat stash)
        seq_parallel = meta["kind"] == "prefill" or (
            meta["kind"] == "train" and big)
    if meta["kind"] == "train" and cfg.moe is not None and extra_cfg is None:
        import dataclasses as dc
        cfg = dc.replace(cfg, moe=dc.replace(cfg.moe, capacity_factor=1.5))
    # dense models whose bf16 TP-16 shard alone exceeds ~half of v5e HBM
    # get 2-D weight sharding at serve time too
    serve_weight_fsdp = cfg.n_params() * 2 / 16 > 8e9
    rules = make_rules(mesh, mode=mode, multi_pod=multi_pod,
                       seq_parallel=seq_parallel,
                       serve_weight_fsdp=serve_weight_fsdp)
    if no_fsdp:  # pure DP+TP (small models: weights replicated over data)
        import dataclasses as dc
        rules = dc.replace(rules, fsdp_axis=None, expert_fsdp_axis=None)
    if pure_dp:  # fold the model axis into data parallelism (TP degree 1)
        import dataclasses as dc
        rules = dc.replace(
            rules, model_axis=None, fsdp_axis=None, expert_fsdp_axis=None,
            seq_axis=None,
            batch_axes=tuple(rules.batch_axes) + ("model",))

    from repro.models import transformer as T
    from repro.serve import serve_step as S
    from repro.train.train_step import TrainConfig, init_train_state, \
        make_train_step

    specs_in = input_specs(cfg, shape)

    with use_rules(rules):
        if meta["kind"] == "train":
            mb = microbatches or pick_microbatches(cfg, meta, rules)
            from repro.optim.adamw import AdamWConfig
            tcfg = TrainConfig(
                microbatches=mb,
                optimizer=AdamWConfig(
                    moments_dtype="bfloat16" if big else "float32"),
            )
            state_shapes = jax.eval_shape(
                lambda: init_train_state(cfg, tcfg, jax.random.PRNGKey(0)))
            pspecs = param_specs(state_shapes["params"], rules)
            opt_specs = {
                "mu": zero1_specs(state_shapes["params"], pspecs, rules),
                "nu": zero1_specs(state_shapes["params"], pspecs, rules),
                "step": jax.sharding.PartitionSpec(),
            }
            state_specs = {"params": pspecs, "opt": opt_specs}
            bspecs = batch_pspecs(specs_in, rules)
            step_fn = make_train_step(cfg, tcfg)
            jitted = jax.jit(
                step_fn,
                in_shardings=(named(mesh, state_specs),
                              named(mesh, bspecs)),
                donate_argnums=(0,),
            )
            lowered = jitted.lower(state_shapes, specs_in)
            detail = {"microbatches": mb, "mode": "train",
                      "seq_parallel": seq_parallel}
        elif meta["kind"] == "prefill":
            pspecs = param_specs(
                jax.eval_shape(lambda: T.init_params(cfg, jax.random.PRNGKey(0))),
                rules,
            )
            bspecs = batch_pspecs(specs_in, rules)

            def prefill_fn(params, batch):
                return S.prefill(cfg, params, batch["tokens"],
                                 max_len=meta["seq_len"],
                                 frontend=batch.get("frontend"))

            params_shapes = jax.eval_shape(
                lambda: T.init_params(cfg, jax.random.PRNGKey(0)))
            jitted = jax.jit(prefill_fn,
                             in_shardings=(named(mesh, pspecs),
                                           named(mesh, bspecs)))
            lowered = jitted.lower(params_shapes, specs_in)
            detail = {"mode": "prefill", "seq_parallel": seq_parallel}
        else:  # decode
            params_shapes = jax.eval_shape(
                lambda: T.init_params(cfg, jax.random.PRNGKey(0)))
            pspecs = param_specs(params_shapes, rules)
            prefix = cfg.meta_tokens + cfg.fusion_tokens
            max_len = meta["seq_len"] + prefix
            cache_shapes = S.cache_specs(cfg, meta["global_batch"], max_len)
            cspecs = cache_pspecs(cache_shapes, rules)
            bspecs = batch_pspecs(specs_in, rules)

            def serve_fn(params, cache, batch):
                return S.decode_step(cfg, params, cache, batch["tokens"])

            jitted = jax.jit(
                serve_fn,
                in_shardings=(named(mesh, pspecs), named(mesh, cspecs),
                              named(mesh, bspecs)),
                # logits auto; cache output MUST keep the input layout
                # (unpinned, XLA replicates the updated cache on the way out)
                out_shardings=(None, named(mesh, cspecs)),
                donate_argnums=(1,) if donate else (),
            )
            lowered = jitted.lower(params_shapes, cache_shapes, specs_in)
            detail = {"mode": "decode", "cache_len": max_len}

        t0 = time.monotonic()
        compiled = lowered.compile()
        detail["compile_s"] = time.monotonic() - t0
        # single-layer-group program for scan-trip cost accounting
        try:
            gcompiled, trips = lower_group_program(
                cfg, meta, rules, mesh,
                microbatches=detail.get("microbatches", 1))
            detail["trips"] = trips
        except Exception as e:  # accounting is best-effort; full compile is
            gcompiled, trips = None, 1       # the hard deliverable
            detail["group_error"] = f"{type(e).__name__}: {e}"[:300]
    return compiled, cfg, detail, gcompiled, trips


def run_cell(arch: str, shape: str, mesh_kind: str, out_dir: str,
             verbose: bool = True, **kw):
    cfg = get_config(arch)
    meta = SHAPES[shape]
    multi = mesh_kind == "multi"
    n_dev = 512 if multi else 256
    label = f"{arch}__{shape}__{mesh_kind}"
    skip = shape_skip_reason(cfg, shape)
    record = {"arch": arch, "shape": shape, "mesh": mesh_kind}
    if skip:
        record["status"] = "skipped"
        record["reason"] = skip
        print(f"[dryrun] SKIP {label}: {skip}")
    else:
        try:
            compiled, cfg, detail, gcompiled, trips = lower_cell(
                arch, shape, multi_pod=multi, **kw)
            rec = analysis.analyze(
                compiled, n_devices=n_dev,
                model_flops_global=analysis.model_flops(cfg, meta),
                label=label, group_compiled=gcompiled, trips=trips,
            )
            record.update(rec)
            record.update(detail)
            record["status"] = "ok"
            if verbose:
                ma = record["memory_analysis"]
                print(f"[dryrun] OK {label}: compile={detail['compile_s']:.1f}s "
                      f"args={_gb(ma['argument_size_in_bytes'])} "
                      f"temp={_gb(ma['temp_size_in_bytes'])} "
                      f"compute={record['compute_s']*1e3:.2f}ms "
                      f"memory={record['memory_s']*1e3:.2f}ms "
                      f"coll={record['collective_s']*1e3:.2f}ms "
                      f"dominant={record['dominant']}")
        except Exception as e:
            record["status"] = "failed"
            record["error"] = f"{type(e).__name__}: {e}"
            record["traceback"] = traceback.format_exc()[-3000:]
            print(f"[dryrun] FAIL {label}: {type(e).__name__}: {str(e)[:500]}")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        with open(os.path.join(out_dir, f"{label}.json"), "w") as f:
            json.dump(record, f, indent=2, default=str)
    return record


def _gb(x):
    return f"{x/2**30:.2f}GiB" if x is not None else "?"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=ARCH_IDS + [None])
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--seq-parallel", action="store_true", default=None)
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--pure-dp", action="store_true")
    ap.add_argument("--kv-quant", action="store_true")
    args = ap.parse_args()

    archs = ARCH_IDS if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                extra_cfg = None
                if args.kv_quant:
                    import dataclasses as dc
                    extra_cfg = dc.replace(get_config(arch), kv_quant=True)
                rec = run_cell(arch, shape, mesh_kind, args.out,
                               microbatches=args.microbatches,
                               seq_parallel=args.seq_parallel,
                               no_fsdp=args.no_fsdp, pure_dp=args.pure_dp,
                               extra_cfg=extra_cfg)
                n_fail += rec["status"] == "failed"
    print(f"[dryrun] done; {n_fail} failures")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
