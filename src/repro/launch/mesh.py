"""Production mesh construction.

Never touches jax device state at import time — mesh creation is a function.
Single pod: (data=16, model=16) = 256 chips (v5e pod slice).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the pod axis carries
only data parallelism (gradient all-reduce crosses DCN once per step).
"""
from __future__ import annotations

import jax


def _make(shape, axes):
    try:
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
        )
    except TypeError:  # older jax without axis_types
        return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make(shape, axes)


def make_host_mesh(model: int = 1):
    """Small mesh over however many local devices exist (tests/examples)."""
    n = jax.device_count()
    assert n % model == 0, (n, model)
    return _make((n // model, model), ("data", "model"))
