"""Production mesh construction.

Never touches jax device state at import time — mesh creation is a function.
Single pod: (data=16, model=16) = 256 chips (v5e pod slice).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the pod axis carries
only data parallelism (gradient all-reduce crosses DCN once per step).
"""
from __future__ import annotations

import jax


def make_mesh_compat(shape, axes):
    """jax.make_mesh with Auto axis_types where the API exists.

    Older jax (< 0.5) has neither ``jax.sharding.AxisType`` (AttributeError)
    nor the ``axis_types`` kwarg (TypeError); Auto was its only behavior, so
    plain make_mesh is equivalent there. Tests use this too — the tier-1
    suite must run on the pinned 0.4.x toolchain.
    """
    try:
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
        )
    except (TypeError, AttributeError):
        return jax.make_mesh(shape, axes)


_make = make_mesh_compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make(shape, axes)


def make_host_mesh(model: int = 1):
    """Small mesh over however many local devices exist (tests/examples)."""
    n = jax.device_count()
    assert n % model == 0, (n, model)
    return _make((n // model, model), ("data", "model"))
