"""Serving launcher: batched prefill + decode on a host mesh.

  python -m repro.launch.serve --arch smollm-360m --smoke --batch 4 \
      --prompt-len 32 --gen 32
"""
from __future__ import annotations

import argparse
import time


def main():
    import jax
    import jax.numpy as jnp

    from repro.configs.registry import ARCH_IDS, get_config
    from repro.launch.mesh import make_host_mesh
    from repro.launch.dryrun import make_rules
    from repro.models import transformer as T
    from repro.serve import serve_step as S
    from repro.serve.sampler import generate
    from repro.sharding.rules import use_rules

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    mesh = make_host_mesh(model=args.model_parallel)
    rules = make_rules(mesh, mode="serve", multi_pod=False)

    with use_rules(rules), mesh:
        params = T.init_params(cfg, jax.random.PRNGKey(args.seed))
        prompts = jax.random.randint(
            jax.random.PRNGKey(args.seed + 1),
            (args.batch, args.prompt_len), 0, cfg.vocab_size)
        frontend = None
        if cfg.fusion_tokens:
            frontend = jnp.zeros(
                (args.batch, cfg.fusion_tokens, cfg.d_model), cfg.jax_dtype)
        if cfg.encdec is not None:
            frontend = jnp.zeros(
                (args.batch, cfg.encdec.enc_seq, cfg.d_model), cfg.jax_dtype)

        t0 = time.monotonic()
        logits, cache = jax.jit(
            lambda p, t: S.prefill(cfg, p, t, max_len=args.max_len,
                                   frontend=frontend)
        )(params, prompts)
        logits.block_until_ready()
        t_prefill = time.monotonic() - t0

        first = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        step = jax.jit(lambda c, t: S.decode_step(cfg, params, c, t))
        t0 = time.monotonic()
        toks, cache = generate(step, cache, first, args.gen,
                               jax.random.PRNGKey(2),
                               temperature=args.temperature)
        toks.block_until_ready()
        t_gen = time.monotonic() - t0
        tps = args.batch * args.gen / t_gen
    print(f"[serve] prefill {args.batch}x{args.prompt_len} in "
          f"{t_prefill*1e3:.0f} ms; generated {args.gen} tok/seq in "
          f"{t_gen*1e3:.0f} ms = {tps:.1f} tok/s")
    print("[serve] sample tokens:", toks[0, :16].tolist())


if __name__ == "__main__":
    main()
