"""Training launcher.

Local (this container): small meshes over host devices, e.g.
  python -m repro.launch.train --arch smollm-360m --smoke --steps 50

Cluster: set COORDINATOR_ADDRESS / NUM_PROCESSES / PROCESS_ID (GKE/TPU env)
and the launcher calls jax.distributed.initialize before touching devices;
the mesh then spans all pods. Elastic restarts resume from the newest
committed checkpoint under --ckpt-dir (see train/trainer.py).

XLA flags for collective/compute overlap on real hardware are set here
(latency-hiding scheduler, async collectives) — harmless no-ops on CPU.
"""
from __future__ import annotations

import argparse
import os


def _setup_distributed():
    if os.environ.get("COORDINATOR_ADDRESS"):
        import jax
        jax.distributed.initialize(
            coordinator_address=os.environ["COORDINATOR_ADDRESS"],
            num_processes=int(os.environ["NUM_PROCESSES"]),
            process_id=int(os.environ["PROCESS_ID"]),
        )


def _overlap_flags():
    flags = (
        " --xla_tpu_enable_async_collective_fusion=true"
        " --xla_tpu_enable_async_collective_fusion_fuse_all_gather=true"
        " --xla_tpu_overlap_compute_collective_tc=true"
        " --xla_enable_async_all_gather=true"
    )
    os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + (
        flags if os.environ.get("JAX_PLATFORMS", "") != "cpu" else ""
    )


def main():
    _overlap_flags()
    _setup_distributed()
    import jax
    import jax.numpy as jnp

    from repro.configs.registry import ARCH_IDS, get_config
    from repro.data.pipeline import DataConfig
    from repro.launch.mesh import make_host_mesh, make_production_mesh
    from repro.launch.dryrun import make_rules
    from repro.sharding.rules import (batch_pspecs, named, param_specs,
                                      use_rules, zero1_specs)
    from repro.train.train_step import (TrainConfig, init_train_state,
                                        make_train_step)
    from repro.train.trainer import LoopConfig, train_loop
    from repro.optim.adamw import AdamWConfig
    from repro.optim.compress import CompressionConfig

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--ckpt-dir", default="artifacts/ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compress", default="none",
                    choices=["none", "topk", "int8"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.production_mesh:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
    else:
        mesh = make_host_mesh(model=args.model_parallel)
    rules = make_rules(mesh, mode="train", multi_pod=args.multi_pod)

    tcfg = TrainConfig(
        optimizer=AdamWConfig(lr=args.lr, total_steps=args.steps,
                              warmup_steps=max(args.steps // 20, 5)),
        microbatches=args.microbatches,
        compression=CompressionConfig(kind=args.compress),
    )
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                      global_batch=args.global_batch, seed=args.seed)

    with use_rules(rules), mesh:
        state = init_train_state(cfg, tcfg, jax.random.PRNGKey(args.seed))
        pspecs = param_specs(state["params"], rules)
        state_specs = {
            "params": pspecs,
            "opt": {"mu": zero1_specs(state["params"], pspecs, rules),
                    "nu": zero1_specs(state["params"], pspecs, rules),
                    "step": jax.sharding.PartitionSpec()},
        }
        if "err" in state:
            state_specs["err"] = pspecs
        shardings = named(mesh, state_specs)
        state = jax.device_put(state, shardings)
        step_fn = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0,))

        def run_step(state, batch):
            batch = jax.device_put(
                batch, named(mesh, batch_pspecs(batch, rules)))
            return step_fn(state, batch)

        state, info = train_loop(
            run_step, state, dcfg,
            LoopConfig(total_steps=args.steps, ckpt_every=args.ckpt_every),
            args.ckpt_dir, shardings=shardings,
        )
    print(f"[train] done: {len(info['history'])} steps, "
          f"final loss {info['history'][-1]['loss']:.4f}, "
          f"stragglers {info['stragglers']}")


if __name__ == "__main__":
    main()
