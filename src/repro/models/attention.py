"""GQA attention: dense, blockwise (online-softmax), and decode paths.

* Dense path — small sequences (smoke tests, short training).
* Blockwise path — O(S·chunk) memory via online softmax, scanned over a
  *static list of (q-chunk, kv-chunk) pairs* that enumerates only the causal
  (or sliding-window) lower triangle, so HLO FLOPs match useful FLOPs (no
  masked-out block is ever computed). Pairs are ordered row-major (all kv
  chunks of one q chunk consecutively), so the online-softmax state carries
  only one q chunk at a time.
* Decode path — one query token against a (possibly seq-sharded) KV cache;
  softmax reductions over the sharded axis lower to tiny all-reduces
  (flash-decoding under GSPMD).

Supports: GQA (kv-head replication only when head count isn't shardable),
qk-norm (qwen3), qkv-bias (qwen1.5), sliding window (hymba/llama4), NoPE,
bidirectional + cross attention (whisper).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pwconv import DEFAULT_POLICY, KernelPolicy
from repro.models.layers import apply_rope, init_linear, linear, rms_norm

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def init_attention(key, d_model: int, n_heads: int, n_kv_heads: int,
                   head_dim: int, *, qkv_bias: bool = False,
                   qk_norm: bool = False, dtype=jnp.float32):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "w_q": init_linear(k1, d_model, n_heads * head_dim, bias=qkv_bias,
                           dtype=dtype),
        "w_k": init_linear(k2, d_model, n_kv_heads * head_dim, bias=qkv_bias,
                           dtype=dtype),
        "w_v": init_linear(k3, d_model, n_kv_heads * head_dim, bias=qkv_bias,
                           dtype=dtype),
        "w_o": init_linear(k4, n_heads * head_dim, d_model, dtype=dtype),
    }
    if qk_norm:
        p["q_norm"] = {"scale": jnp.zeros((head_dim,), jnp.float32)}
        p["k_norm"] = {"scale": jnp.zeros((head_dim,), jnp.float32)}
    return p


def _project_qkv(p, x, xkv, n_heads, n_kv_heads, head_dim, *, qk_norm,
                 policy):
    b, s, _ = x.shape
    skv = xkv.shape[1]
    q = linear(p["w_q"], x, policy=policy).reshape(b, s, n_heads, head_dim)
    k = linear(p["w_k"], xkv, policy=policy).reshape(b, skv, n_kv_heads, head_dim)
    v = linear(p["w_v"], xkv, policy=policy).reshape(b, skv, n_kv_heads, head_dim)
    if qk_norm:
        q = rms_norm(q, p["q_norm"]["scale"])
        k = rms_norm(k, p["k_norm"]["scale"])
    return q, k, v


# ---------------------------------------------------------------------------
# Dense attention (small S) — also the oracle for the blockwise path
# ---------------------------------------------------------------------------


def _gqa_scores(q, k):
    """q (B,Sq,Hq,dh), k (B,Sk,Hkv,dh) -> scores (B,Hq,Sq,Sk) fp32."""
    b, sq, hq, dh = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, sq, hkv, g, dh)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                   k.astype(jnp.float32))
    return s.reshape(b, hkv * g, sq, k.shape[1])


def _gqa_out(probs, v):
    """probs (B,Hq,Sq,Sk), v (B,Sk,Hkv,dh) -> (B,Sq,Hq,dh)."""
    b, hq, sq, sk = probs.shape
    hkv = v.shape[2]
    g = hq // hkv
    pg = probs.reshape(b, hkv, g, sq, sk)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", pg, v.astype(jnp.float32))
    return out.reshape(b, sq, hq, v.shape[-1])


def dense_attention(q, k, v, *, causal: bool, window: Optional[int] = None,
                    kv_len: Optional[jax.Array] = None,
                    q_offset: int | jax.Array = 0,
                    sink: int = 0) -> jax.Array:
    """Reference/simple path. Returns (B, Sq, Hq, dh) in q.dtype.

    sink: first `sink` kv positions are always attendable (meta/sink tokens),
    even outside the sliding window.
    """
    b, sq, hq, dh = q.shape
    sk = k.shape[1]
    scores = _gqa_scores(q, k) * (dh ** -0.5)
    qi = jnp.arange(sq)[:, None] + q_offset            # absolute q positions
    kj = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kj <= qi
    if window is not None:
        mask &= (kj > qi - window) | (kj < sink)
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    if kv_len is not None:                             # per-batch valid length
        scores = jnp.where(kj[None, None] < kv_len[:, None, None, None],
                           scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return _gqa_out(probs, v).astype(q.dtype)


# ---------------------------------------------------------------------------
# Blockwise attention (online softmax over a static causal pair list)
# ---------------------------------------------------------------------------


def _pair_list(nq: int, nk: int, causal: bool, window_chunks: Optional[int],
               sink_chunks: int = 0):
    """Static (qi, ki) pairs, row-major, only not-fully-masked blocks."""
    pairs = []
    for qi in range(nq):
        for ki in range(nk):
            if causal and ki > qi:
                continue
            if (window_chunks is not None and ki < qi - window_chunks
                    and ki >= sink_chunks):
                continue
            pairs.append((qi, ki))
    return np.asarray(pairs, np.int32)


def _pair_flags(pairs):
    is_last = np.zeros(len(pairs), bool)
    row_end = {}
    for idx, (qi, ki) in enumerate(pairs):
        row_end[qi] = idx
    for qi, idx in row_end.items():
        is_last[idx] = True
    is_first = np.zeros(len(pairs), bool)
    seen = set()
    for idx, (qi, ki) in enumerate(pairs):
        if qi not in seen:
            is_first[idx] = True
            seen.add(qi)
    return is_first, is_last


def _block_mask(qi, ki, qc, kc, causal, window, sink, sk):
    qpos = qi * qc + jnp.arange(qc)[:, None]
    kpos = ki * kc + jnp.arange(kc)[None, :]
    mask = jnp.ones((qc, kc), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= (kpos > qpos - window) | (kpos < sink)
    mask &= kpos < sk
    return mask


def _flash_fwd(q, k, v, statics):
    """Pair-scan forward. Returns (out (nq,B,qc,Hq,dh), lse (nq,B,Hq,qc))."""
    (causal, window, sink, qc, kc, nq, nk, sk, pairs, is_first,
     is_last) = statics
    _, b, _, hq, dh = q.shape
    scale = dh ** -0.5

    def body(carry, inp):
        out, lse, m, l, acc = carry
        qi, ki, first, last = inp
        qb = jax.lax.dynamic_index_in_dim(q, qi, 0, keepdims=False)
        kb = jax.lax.dynamic_index_in_dim(k, ki, 0, keepdims=False)
        vb = jax.lax.dynamic_index_in_dim(v, ki, 0, keepdims=False)
        m = jnp.where(first, jnp.full_like(m, NEG_INF), m)
        l = jnp.where(first, jnp.zeros_like(l), l)
        acc = jnp.where(first, jnp.zeros_like(acc), acc)

        s = _gqa_scores(qb, kb) * scale                   # (B,Hq,qc,kc) f32
        mask = _block_mask(qi, ki, qc, kc, causal, window, sink, sk)
        s = jnp.where(mask[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(axis=-1)
        acc = acc * corr.transpose(0, 2, 1)[..., None] + _gqa_out(p, vb)
        m = m_new

        def finalize(bufs):
            out, lse = bufs
            res = acc / jnp.maximum(l, 1e-30).transpose(0, 2, 1)[..., None]
            out = jax.lax.dynamic_update_index_in_dim(
                out, res.astype(out.dtype), qi, 0)
            lse = jax.lax.dynamic_update_index_in_dim(
                lse, m + jnp.log(jnp.maximum(l, 1e-30)), qi, 0)
            return out, lse

        out, lse = jax.lax.cond(last, finalize, lambda bufs: bufs,
                                (out, lse))
        return (out, lse, m, l, acc), None

    out0 = jnp.zeros((nq, b, qc, hq, dh), q.dtype)
    lse0 = jnp.zeros((nq, b, hq, qc), jnp.float32)
    m0 = jnp.full((b, hq, qc), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hq, qc), jnp.float32)
    acc0 = jnp.zeros((b, qc, hq, dh), jnp.float32)
    xs = (jnp.asarray(pairs[:, 0]), jnp.asarray(pairs[:, 1]),
          jnp.asarray(is_first), jnp.asarray(is_last))
    (out, lse, _, _, _), _ = jax.lax.scan(
        body, (out0, lse0, m0, l0, acc0), xs)
    return out, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _flash(q, k, v, statics):
    out, _ = _flash_fwd(q, k, v, statics)
    return out


def _flash_vjp_fwd(q, k, v, statics):
    out, lse = _flash_fwd(q, k, v, statics)
    return out, (q, k, v, out, lse)


def _flash_vjp_bwd(statics, res, dout):
    """Flash backward: recompute P per block pair from saved lse.

    Residuals are O(S) (q, k, v, out, lse) — never the (S x S) score matrix.
    """
    (causal, window, sink, qc, kc, nq, nk, sk, pairs, is_first,
     is_last) = statics
    q, k, v, out, lse = res
    b = q.shape[1]
    hq, dh = q.shape[3], q.shape[4]
    hkv = k.shape[3]
    g = hq // hkv
    scale = dh ** -0.5
    # D_i = rowsum(dO * O)  per (nq, B, Hq, qc)
    d_term = jnp.einsum("nbqhd,nbqhd->nbhq", dout.astype(jnp.float32),
                        out.astype(jnp.float32))

    def body(carry, inp):
        dq, dk, dv, dq_acc = carry
        qi, ki, first, last = inp
        qb = jax.lax.dynamic_index_in_dim(q, qi, 0, keepdims=False)
        kb = jax.lax.dynamic_index_in_dim(k, ki, 0, keepdims=False)
        vb = jax.lax.dynamic_index_in_dim(v, ki, 0, keepdims=False)
        dob = jax.lax.dynamic_index_in_dim(dout, qi, 0, keepdims=False)
        lseb = jax.lax.dynamic_index_in_dim(lse, qi, 0, keepdims=False)
        dterm_b = jax.lax.dynamic_index_in_dim(d_term, qi, 0, keepdims=False)
        dq_acc = jnp.where(first, jnp.zeros_like(dq_acc), dq_acc)

        s = _gqa_scores(qb, kb) * scale                   # (B,Hq,qc,kc)
        mask = _block_mask(qi, ki, qc, kc, causal, window, sink, sk)
        s = jnp.where(mask[None, None], s, NEG_INF)
        p = jnp.exp(s - lseb[..., None])                  # (B,Hq,qc,kc)

        dof = dob.astype(jnp.float32)                     # (B,qc,Hq,dh)
        vf = vb.astype(jnp.float32)
        pg = p.reshape(b, hkv, g, qc, kc)
        dog = dof.reshape(b, qc, hkv, g, dh)
        # dV_j += P^T dO
        dvb = jnp.einsum("bhgqk,bqhgd->bkhd", pg, dog)
        # dP = dO V^T ; dS = P * (dP - D) * scale
        dp = jnp.einsum("bqhgd,bkhd->bhgqk", dog, vf)
        ds = pg * (dp - dterm_b.reshape(b, hkv, g, qc)[..., None]) * scale
        # dQ_i += dS K ; dK_j += dS^T Q
        dqb = jnp.einsum("bhgqk,bkhd->bqhgd", ds,
                         kb.astype(jnp.float32)).reshape(b, qc, hq, dh)
        dkb = jnp.einsum("bhgqk,bqhgd->bkhd", ds,
                         qb.astype(jnp.float32).reshape(b, qc, hkv, g, dh))
        dq_acc = dq_acc + dqb
        dk = dk.at[ki].add(dkb)
        dv = dv.at[ki].add(dvb)

        def wr(dq):
            return jax.lax.dynamic_update_index_in_dim(
                dq, dq_acc.astype(dq.dtype), qi, 0)
        dq = jax.lax.cond(last, wr, lambda dq: dq, dq)
        return (dq, dk, dv, dq_acc), None

    dq0 = jnp.zeros(q.shape, jnp.float32)
    dk0 = jnp.zeros(k.shape, jnp.float32)
    dv0 = jnp.zeros(v.shape, jnp.float32)
    dqa0 = jnp.zeros((b, qc, hq, dh), jnp.float32)
    xs = (jnp.asarray(pairs[:, 0]), jnp.asarray(pairs[:, 1]),
          jnp.asarray(is_first), jnp.asarray(is_last))
    (dq, dk, dv, _), _ = jax.lax.scan(body, (dq0, dk0, dv0, dqa0), xs)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_flash.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


def blockwise_attention(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None,
                        sink: int = 0,
                        chunk: int = 1024) -> jax.Array:
    """Flash attention in pure JAX: online softmax over a static causal
    block-pair list, custom VJP (scores recomputed in backward -> O(S)
    residuals). q (B,Sq,Hq,dh); k/v (B,Sk,Hkv,dh)."""
    b, sq, hq, dh = q.shape
    sk = k.shape[1]
    hkv = k.shape[2]
    qc = min(chunk, sq)
    kc = min(chunk, sk)
    pad_q = (-sq) % qc
    pad_k = (-sk) % kc
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    nq, nk = (sq + pad_q) // qc, (sk + pad_k) // kc
    wc = None if window is None else max(1, -(-window // kc))
    sc = 0 if not sink else -(-sink // kc)
    pairs = _pair_list(nq, nk, causal, wc, sc)
    is_first, is_last = _pair_flags(pairs)
    statics = (causal, window, sink, qc, kc, nq, nk, sk,
               _Hashable(pairs), _Hashable(is_first), _Hashable(is_last))

    qr = q.reshape(b, nq, qc, hq, dh).swapaxes(0, 1)     # (nq,B,qc,Hq,dh)
    kr = k.reshape(b, nk, kc, hkv, dh).swapaxes(0, 1)
    vr = v.reshape(b, nk, kc, hkv, dh).swapaxes(0, 1)
    out = _flash(qr, kr, vr, statics)
    out = out.swapaxes(0, 1).reshape(b, nq * qc, hq, dh)
    return out[:, :sq]


class _Hashable:
    """Hashable ndarray wrapper (for custom_vjp nondiff static args)."""

    def __init__(self, arr: np.ndarray):
        self.arr = arr
        self._key = arr.tobytes()

    def __hash__(self):
        return hash(self._key)

    def __eq__(self, other):
        return isinstance(other, _Hashable) and self._key == other._key

    def __getitem__(self, i):
        return self.arr[i]

    def __len__(self):
        return len(self.arr)

    def __array__(self, dtype=None, copy=None):
        return np.asarray(self.arr, dtype=dtype)


# ---------------------------------------------------------------------------
# Full attention layer (self / cross; train or prefill)
# ---------------------------------------------------------------------------


def attention(
    p, x, *, n_heads: int, n_kv_heads: int, head_dim: int,
    positions: Optional[jax.Array] = None,
    causal: bool = True,
    window: Optional[int] = None,
    sink: int = 0,
    rope_theta: Optional[float] = 1e4,
    qk_norm: bool = False,
    xkv: Optional[jax.Array] = None,           # cross attention source
    chunk: int = 1024,
    policy: KernelPolicy = DEFAULT_POLICY,
    return_kv: bool = False,
):
    """Returns attention block output (B, S, d_model) [, (k, v)]."""
    b, s, _ = x.shape
    src = x if xkv is None else xkv
    q, k, v = _project_qkv(p, x, src, n_heads, n_kv_heads, head_dim,
                           qk_norm=qk_norm, policy=policy)
    if rope_theta is not None and xkv is None:
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    from repro.sharding.rules import shard_act
    q = shard_act(q, "heads4")
    if s <= chunk and src.shape[1] <= chunk:
        out = dense_attention(q, k, v, causal=causal and xkv is None,
                              window=window, sink=sink)
    else:
        out = blockwise_attention(q, k, v, causal=causal and xkv is None,
                                  window=window, sink=sink, chunk=chunk)
    out = out.reshape(b, s, n_heads * head_dim)
    out = linear(p["w_o"], out, policy=policy)
    if return_kv:
        # captured KV becomes the decode cache: shard its sequence dim the
        # way the cache is sharded (flash-decoding layout)
        k = shard_act(k, "cache")
        v = shard_act(v, "cache")
        return out, (k, v)
    return out


# ---------------------------------------------------------------------------
# Decode: one token against a KV cache
# ---------------------------------------------------------------------------


def _quantize_vec(x):
    """x (..., dh) -> (int8 values, f32 scale (...,))."""
    scale = jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1),
                        1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def attention_decode(
    p, x_t, cache_k, cache_v, pos, *, n_heads: int, n_kv_heads: int,
    head_dim: int, window: Optional[int] = None,
    rope_theta: Optional[float] = 1e4, qk_norm: bool = False,
    ring: bool = False, sink: int = 0,
    scales: Optional[tuple] = None,   # (k_scale, v_scale) for int8 caches
    policy: KernelPolicy = DEFAULT_POLICY,
):
    """x_t (B,1,d); cache_k/v (B,Sc,Hkv,dh); pos (B,) current index.

    ring=True: the cache is a StreamingLLM-style buffer: `sink` permanent
    slots + a ring of (Sc - sink) sliding-window slots. Positions past the
    buffer wrap within the ring part; every populated slot is attendable.

    scales: int8-quantized cache (per-(B,S,Hkv) vector scales) — halves the
    per-token HBM read volume of the cache.
    Returns (out (B,1,d), new_k, new_v[, new_scales]).
    """
    b = x_t.shape[0]
    q, k, v = _project_qkv(p, x_t, x_t, n_heads, n_kv_heads, head_dim,
                           qk_norm=qk_norm, policy=policy)
    if rope_theta is not None:
        q = apply_rope(q, pos[:, None], rope_theta)
        k = apply_rope(k, pos[:, None], rope_theta)
    smax = cache_k.shape[1]
    if ring:
        ring_len = smax - sink
        slot = jnp.where(pos < smax, pos, sink + (pos - sink) % ring_len)
    else:
        slot = pos
    # one-hot (mask+select) cache write: elementwise, so GSPMD keeps the
    # sequence-sharded layout (a scatter at a dynamic index would force the
    # partitioner to replicate the whole cache layer)
    wmask = (jnp.arange(smax)[None, :] == slot[:, None])[..., None, None]
    if scales is not None:
        k_scale, v_scale = scales
        k8, ks_new = _quantize_vec(k[:, 0])          # (B,Hkv,dh)/(B,Hkv)
        v8, vs_new = _quantize_vec(v[:, 0])
        cache_k = jnp.where(wmask, k8[:, None], cache_k)
        cache_v = jnp.where(wmask, v8[:, None], cache_v)
        smask = wmask[..., 0, 0][..., None]
        k_scale = jnp.where(smask, ks_new[:, None], k_scale)
        v_scale = jnp.where(smask, vs_new[:, None], v_scale)
    else:
        cache_k = jnp.where(wmask, k[:, 0][:, None].astype(cache_k.dtype),
                            cache_k)
        cache_v = jnp.where(wmask, v[:, 0][:, None].astype(cache_v.dtype),
                            cache_v)

    from repro.sharding.rules import shard_act
    cache_k = shard_act(cache_k, "cache")
    cache_v = shard_act(cache_v, "cache")
    q = shard_act(q, "q_decode")
    if scales is not None:
        k_eff = cache_k.astype(jnp.bfloat16) * k_scale[..., None].astype(
            jnp.bfloat16)
        v_eff = cache_v.astype(jnp.bfloat16) * v_scale[..., None].astype(
            jnp.bfloat16)
    else:
        k_eff, v_eff = cache_k, cache_v
    scores = _gqa_scores(q, k_eff) * (head_dim ** -0.5)  # (B,Hq,1,Smax)
    scores = shard_act(scores, "scores_decode")
    j = jnp.arange(smax)[None, :]
    if ring:
        valid = j < jnp.minimum(pos + 1, smax)[:, None]
    else:
        valid = j <= pos[:, None]
        if window is not None:
            valid &= (j > (pos[:, None] - window)) | (j < sink)
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = _gqa_out(probs, v_eff).astype(x_t.dtype)         # (B,1,Hq,dh)
    out = out.reshape(b, 1, n_heads * head_dim)
    proj = linear(p["w_o"], out, policy=policy)
    if scales is not None:
        return proj, cache_k, cache_v, (k_scale, v_scale)
    return proj, cache_k, cache_v
