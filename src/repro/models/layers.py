"""Primitive layers: norms, RoPE, Linear (routed through the paper's PWConv),
embedding, and chunked cross-entropy.

Params are plain nested dicts. Every key used here is registered in
``repro.sharding.rules.LOGICAL_AXES`` so sharding specs can be derived by
name.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.pwconv import DEFAULT_POLICY, KernelPolicy, pointwise

# ---------------------------------------------------------------------------
# Norms (fp32 internals regardless of activation dtype)
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layer_norm(
    x: jax.Array, scale: jax.Array, bias: Optional[jax.Array] = None,
    eps: float = 1e-5,
) -> jax.Array:
    xf = x.astype(jnp.float32)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mean) * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    return y.astype(x.dtype)


def norm(x, params, kind: str = "rms"):
    if kind == "rms":
        return rms_norm(x, params["scale"])
    return layer_norm(x, params["scale"], params.get("bias"))


def init_norm(kind: str, d: int, with_bias: bool = False):
    p = {"scale": jnp.zeros((d,), jnp.float32)}
    if kind == "layer" and with_bias:
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


# ---------------------------------------------------------------------------
# Linear == the paper's PWConv
# ---------------------------------------------------------------------------


def init_linear(key, d_in: int, d_out: int, *, bias: bool = False,
                dtype=jnp.float32, scale: Optional[float] = None):
    std = scale if scale is not None else d_in ** -0.5
    p = {"w": (jax.random.normal(key, (d_in, d_out), jnp.float32) * std).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p, x: jax.Array, *, activation: Optional[str] = None,
           policy: KernelPolicy = DEFAULT_POLICY) -> jax.Array:
    return pointwise(x, p["w"], p.get("b"), activation=activation,
                     policy=policy)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, dh); positions: (B, S) int32."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                                  # (dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs      # (B,S,dh/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding + chunked cross-entropy (vocab up to 256k -> never materialize
# full (B, S, V) logits; scan over sequence chunks instead)
# ---------------------------------------------------------------------------


def init_embedding(key, vocab: int, d: int, dtype=jnp.float32):
    return {"table": (jax.random.normal(key, (vocab, d), jnp.float32)
                      * d ** -0.5).astype(dtype)}


def embed(p, tokens: jax.Array) -> jax.Array:
    return jnp.take(p["table"], tokens, axis=0)


def unembed_logits(x: jax.Array, table: jax.Array) -> jax.Array:
    """x (..., d) @ table.T (V, d) -> (..., V) in fp32."""
    return jnp.dot(x, table.T, preferred_element_type=jnp.float32)


def chunked_cross_entropy(
    x: jax.Array,            # (B, S, d) final hidden states
    table: jax.Array,        # (V, d) unembedding
    labels: jax.Array,       # (B, S) int32; -1 = ignore
    *,
    chunk: int = 512,
    z_loss: float = 0.0,
) -> tuple[jax.Array, jax.Array]:
    """Mean token NLL + count, computed in sequence chunks to bound the
    (B, chunk, V) logits working set. Returns (sum_nll, n_tokens)."""
    b, s, d = x.shape
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    nc = (s + pad) // chunk
    xs = x.reshape(b, nc, chunk, d).swapaxes(0, 1)          # (nc, B, chunk, d)
    ls = labels.reshape(b, nc, chunk).swapaxes(0, 1)

    @jax.checkpoint  # recompute (B, chunk, V) logits in backward
    def chunk_loss(xc, lc):
        logits = unembed_logits(xc, table)                   # (B, chunk, V) f32
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(
            logits, jnp.maximum(lc, 0)[..., None], axis=-1
        )[..., 0]
        valid = (lc >= 0).astype(jnp.float32)
        nll = (lse - tgt) * valid
        return jnp.sum(nll), jnp.sum(valid), jnp.sum(jnp.square(lse) * valid)

    def body(carry, inp):
        nll_sum, n_tok, zsum = carry
        xc, lc = inp
        nll, nv, zs = chunk_loss(xc, lc)
        return (nll_sum + nll, n_tok + nv, zsum + zs), None

    (nll_sum, n_tok, zsum), _ = jax.lax.scan(
        body, (jnp.float32(0.0), jnp.float32(0.0), jnp.float32(0.0)), (xs, ls)
    )
    if z_loss:
        nll_sum = nll_sum + z_loss * zsum
    return nll_sum, n_tok


# ---------------------------------------------------------------------------
# Separable-conv backbones (the paper's workload, network-level)
# ---------------------------------------------------------------------------
# Thin model-layer wrappers over the whole-network chain engine
# (core/network.py, DESIGN.md §7): the backbone plans once and runs as ONE
# jitted call; mixed-precision streaming rides the policy's DtypePolicy.

def init_backbone(key, net, dtype=jnp.float32) -> dict:
    """Params for a declared separable backbone (a ``core.NetworkSpec``,
    e.g. ``mobilenet_v2_spec()``)."""
    from repro.core import network as _network
    return {"blocks": _network.init_network(key, net, dtype)}


def backbone(p, x: jax.Array, *, net,
             policy: KernelPolicy = DEFAULT_POLICY) -> jax.Array:
    """Run a declared separable-conv backbone end to end: every block's
    ChainPlan resolved once, the whole network as one jitted call."""
    from repro.core import network as _network
    return _network.execute_network(net, p["blocks"], x, policy=policy)
