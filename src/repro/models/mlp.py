"""Dense SwiGLU MLP — three PWConv (paper-op) projections."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.pwconv import DEFAULT_POLICY, KernelPolicy
from repro.models.layers import init_linear, linear


def init_mlp(key, d_model: int, d_ff: int, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": init_linear(k1, d_model, d_ff, dtype=dtype),
        "w_up": init_linear(k2, d_model, d_ff, dtype=dtype),
        "w_down": init_linear(k3, d_ff, d_model, dtype=dtype),
    }


def mlp(p, x: jax.Array, *, policy: KernelPolicy = DEFAULT_POLICY) -> jax.Array:
    g = linear(p["w_gate"], x, activation="silu", policy=policy)
    u = linear(p["w_up"], x, policy=policy)
    return linear(p["w_down"], g * u, policy=policy)
