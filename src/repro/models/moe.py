"""Mixture-of-Experts with real expert parallelism (EP).

Dispatch is sort-based with fixed per-destination capacity and an
``all_to_all`` over the tensor-parallel ("model") mesh axis, written with
``shard_map`` so the collective pattern is explicit (and visible to the
roofline collective parser). Experts are sharded over the model axis; tokens
enter sharded over (data..., model) — batch over data, sequence over model
(sequence parallelism into the MoE block).

On a 1-device mesh every collective degenerates to the identity, so the same
code path runs in CPU tests and is compared against ``moe_dense_ref``.

FLOP accounting: expert compute is a capacity-padded batched einsum
(E_local, C, d) x (E_local, d, ff) — top_k * T * (3 * d * ff) * cap-waste,
never the n_experts-times blowup of mask-based MoE implementations.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

try:  # jax >= 0.6 public API
    from jax import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs):
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=False)
except ImportError:  # pragma: no cover - older jax
    from jax.experimental.shard_map import shard_map as _shard_map_old

    def shard_map(f, *, mesh, in_specs, out_specs):
        return _shard_map_old(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=False)

from repro.configs.base import MoEConfig
from repro.core.pwconv import DEFAULT_POLICY, KernelPolicy
from repro.models.layers import init_linear
from repro.models.mlp import init_mlp, mlp


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def init_moe(key, d_model: int, cfg: MoEConfig, d_ff_shared: int,
             dtype=jnp.float32):
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    e, ff = cfg.n_experts, cfg.d_ff_expert
    std = d_model ** -0.5
    p = {
        "router": init_linear(k1, d_model, e, dtype=jnp.float32),
        "w_gate_e": (jax.random.normal(k2, (e, d_model, ff)) * std).astype(dtype),
        "w_up_e": (jax.random.normal(k3, (e, d_model, ff)) * std).astype(dtype),
        "w_down_e": (jax.random.normal(k4, (e, ff, d_model)) * ff ** -0.5
                     ).astype(dtype),
    }
    if cfg.n_shared:
        p["shared"] = init_mlp(k5, d_model, d_ff_shared, dtype=dtype)
    return p


# ---------------------------------------------------------------------------
# Router
# ---------------------------------------------------------------------------


def router_topk(logits: jax.Array, top_k: int, norm_topk: bool):
    """logits (T, E) -> (weights (T,k) f32, ids (T,k) i32, probs (T,E) f32)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    weights, ids = jax.lax.top_k(probs, top_k)
    if norm_topk:
        weights = weights / jnp.maximum(
            weights.sum(-1, keepdims=True), 1e-9
        )
    return weights, ids, probs


def load_balance_loss(probs: jax.Array, ids: jax.Array, n_experts: int):
    """Switch-style aux loss: E * sum_e f_e * p_e."""
    f = jnp.mean(
        jax.nn.one_hot(ids, n_experts, dtype=jnp.float32).sum(1), axis=0
    )
    pbar = probs.mean(0)
    return n_experts * jnp.sum(f * pbar)


# ---------------------------------------------------------------------------
# Dense reference (exact; no capacity, no EP) — test oracle
# ---------------------------------------------------------------------------


def moe_dense_ref(p, x: jax.Array, cfg: MoEConfig,
                  policy: KernelPolicy = DEFAULT_POLICY):
    """x (..., d). Computes every expert for every token; combines by router
    weights. O(E) flops — oracle only."""
    lead = x.shape[:-1]
    d = x.shape[-1]
    xt = x.reshape(-1, d)
    logits = xt.astype(jnp.float32) @ p["router"]["w"]
    weights, ids, probs = router_topk(logits, cfg.top_k, cfg.norm_topk)
    xf = xt.astype(jnp.float32)
    g = jnp.einsum("td,edf->tef", xf, p["w_gate_e"].astype(jnp.float32))
    u = jnp.einsum("td,edf->tef", xf, p["w_up_e"].astype(jnp.float32))
    h = jax.nn.silu(g) * u
    y_all = jnp.einsum("tef,efd->ted", h, p["w_down_e"].astype(jnp.float32))
    onehot = jax.nn.one_hot(ids, cfg.n_experts, dtype=jnp.float32)
    cw = (onehot * weights[..., None]).sum(1)            # (T, E)
    y = jnp.einsum("te,ted->td", cw, y_all)
    out = y.astype(x.dtype).reshape(*lead, d)
    if "shared" in p:
        out = out + mlp(p["shared"], x, policy=policy)
    aux = load_balance_loss(probs, ids, cfg.n_experts)
    return out, {"aux_loss": aux, "drop_frac": jnp.float32(0.0)}


# ---------------------------------------------------------------------------
# EP path: sort + capacity + all_to_all under shard_map
# ---------------------------------------------------------------------------


def _ranks_by_group(group_ids: jax.Array, n_groups: int):
    """rank of each element within its group (stable, by position)."""
    onehot = jax.nn.one_hot(group_ids, n_groups, dtype=jnp.int32)  # (N, G)
    ranks = jnp.cumsum(onehot, axis=0) - 1                         # (N, G)
    return jnp.take_along_axis(ranks, group_ids[:, None], axis=1)[:, 0]


def _moe_local(p, xt, cfg: MoEConfig, tp: int, axis_name: Optional[str]):
    """Per-device MoE body. xt: (T_l, d) local tokens.

    Returns (y (T_l, d) f32, aux dict). Collectives: 2x all_to_all over
    `axis_name` (absent on a 1-way axis).
    """
    t_l, d = xt.shape
    e = cfg.n_experts
    e_local = e // tp
    k = cfg.top_k

    logits = xt.astype(jnp.float32) @ p["router"]["w"]
    weights, ids, probs = router_topk(logits, k, cfg.norm_topk)
    aux = load_balance_loss(probs, ids, e)

    # ---- copies -> destination slots -------------------------------------
    n_copies = t_l * k
    flat_ids = ids.reshape(-1)                       # expert id per copy
    flat_w = weights.reshape(-1)
    src_token = jnp.arange(n_copies) // k
    owner = flat_ids // e_local                      # destination device
    cap_send = -(-t_l * k // tp)                     # balanced share
    cap_send = int(cap_send * cfg.capacity_factor)
    cap_send = max(8, (cap_send + 7) // 8 * 8)
    cap_send = min(cap_send, t_l * k)                # never exceeds all copies
    rank = _ranks_by_group(owner, tp)
    keep = rank < cap_send
    slot = owner * cap_send + jnp.clip(rank, 0, cap_send - 1)

    send_x = jnp.zeros((tp * cap_send, d), xt.dtype)
    send_x = send_x.at[jnp.where(keep, slot, tp * cap_send)].set(
        xt[src_token], mode="drop"
    )
    # metadata: local expert id (+1, 0 = invalid)
    send_e = jnp.zeros((tp * cap_send,), jnp.int32)
    send_e = send_e.at[jnp.where(keep, slot, tp * cap_send)].set(
        flat_ids % e_local + 1, mode="drop"
    )

    # ---- all_to_all to expert owners --------------------------------------
    if axis_name is not None and tp > 1:
        recv_x = jax.lax.all_to_all(
            send_x.reshape(tp, cap_send, d), axis_name, 0, 0, tiled=False
        ).reshape(tp * cap_send, d)
        recv_e = jax.lax.all_to_all(
            send_e.reshape(tp, cap_send), axis_name, 0, 0, tiled=False
        ).reshape(tp * cap_send)
    else:
        recv_x, recv_e = send_x, send_e

    # ---- pack into per-expert capacity buffers ----------------------------
    t_r = tp * cap_send
    cap_e = -(-t_r // max(e_local, 1))
    cap_e = int(cap_e * cfg.capacity_factor)
    cap_e = max(8, (cap_e + 7) // 8 * 8)
    cap_e = min(cap_e, t_r)
    valid_r = recv_e > 0
    eloc = jnp.clip(recv_e - 1, 0, e_local - 1)
    rank_e = _ranks_by_group(jnp.where(valid_r, eloc, e_local), e_local + 1)
    keep_r = valid_r & (rank_e < cap_e)
    pos = eloc * cap_e + jnp.clip(rank_e, 0, cap_e - 1)
    ebuf = jnp.zeros((e_local * cap_e, d), xt.dtype)
    ebuf = ebuf.at[jnp.where(keep_r, pos, e_local * cap_e)].set(
        recv_x, mode="drop"
    )

    # ---- expert compute (batched over local experts) ----------------------
    eb = ebuf.reshape(e_local, cap_e, d)
    wg, wu, wd = p["w_gate_e"], p["w_up_e"], p["w_down_e"]   # sharded on E
    g = jnp.einsum("ecd,edf->ecf", eb, wg,
                   preferred_element_type=jnp.float32)
    u = jnp.einsum("ecd,edf->ecf", eb, wu,
                   preferred_element_type=jnp.float32)
    h = (jax.nn.silu(g) * u).astype(xt.dtype)
    y_e = jnp.einsum("ecf,efd->ecd", h, wd,
                     preferred_element_type=jnp.float32)
    # transport the routed outputs in the payload dtype (bf16 at scale);
    # the weighted combine below stays fp32
    y_e = y_e.astype(xt.dtype).reshape(e_local * cap_e, d)

    # ---- route back --------------------------------------------------------
    y_recv = jnp.where(
        keep_r[:, None],
        y_e[jnp.clip(pos, 0, e_local * cap_e - 1)],
        jnp.zeros((), xt.dtype),
    )
    if axis_name is not None and tp > 1:
        y_send = jax.lax.all_to_all(
            y_recv.reshape(tp, cap_send, d), axis_name, 0, 0, tiled=False
        ).reshape(tp * cap_send, d)
    else:
        y_send = y_recv

    # ---- combine ------------------------------------------------------------
    y_copy = jnp.where(
        keep[:, None],
        y_send[jnp.clip(slot, 0, tp * cap_send - 1)].astype(jnp.float32),
        0.0,
    )
    y = jnp.zeros((t_l, d), jnp.float32)
    y = y.at[src_token].add(y_copy * flat_w[:, None])
    # drop metric: send-side drops are exact locally; receive-side drops are
    # measured on the copies this device received (same global mean after
    # pmean). Combined multiplicatively.
    send_keep = jnp.mean(keep.astype(jnp.float32))
    recv_keep = jnp.sum(keep_r.astype(jnp.float32)) / jnp.maximum(
        jnp.sum(valid_r.astype(jnp.float32)), 1.0
    )
    drop = 1.0 - send_keep * recv_keep
    return y, aux, drop


def moe_forward(
    p, x: jax.Array, cfg: MoEConfig, *,
    mesh: Optional[Mesh] = None,
    data_axes: tuple = (),
    model_axis: Optional[str] = None,
    shard_seq: bool = True,
    policy: KernelPolicy = DEFAULT_POLICY,
):
    """x (B, S, d) -> (y (B, S, d), aux dict). EP over `model_axis`."""
    b, s, d = x.shape
    if mesh is None or model_axis is None:
        xt = x.reshape(-1, d)
        y, aux, drop = _moe_local(p, xt, cfg, tp=1, axis_name=None)
        out = y.astype(x.dtype).reshape(b, s, d)
    else:
        tp = mesh.shape[model_axis]
        seq_spec = model_axis if (shard_seq and s % tp == 0 and s >= tp) else None
        x_spec = P(data_axes if data_axes else None, seq_spec, None)
        ep_specs = {
            "router": {"w": P(None, None)},
            "w_gate_e": P(model_axis, None, None),
            "w_up_e": P(model_axis, None, None),
            "w_down_e": P(model_axis, None, None),
        }
        p_ep = {k: p[k] for k in ("router", "w_gate_e", "w_up_e", "w_down_e")}

        def body(p_local, x_local):
            bl, sl, _ = x_local.shape
            y, aux, drop = _moe_local(
                p_local, x_local.reshape(-1, d), cfg, tp=tp,
                axis_name=model_axis,
            )
            # aux/drop are per-shard scalars; mean across the mesh
            axes = tuple(a for a in (*data_axes, model_axis) if a)
            aux = jax.lax.pmean(aux, axes)
            drop = jax.lax.pmean(drop, axes)
            return y.astype(x.dtype).reshape(bl, sl, d), aux, drop

        out, aux, drop = shard_map(
            body, mesh=mesh,
            in_specs=(ep_specs, x_spec),
            out_specs=(x_spec, P(), P()),
        )(p_ep, x)
    res = {"aux_loss": aux, "drop_frac": drop}
    if "shared" in p:
        out = out + mlp(p["shared"], x, policy=policy)
    return out, res
