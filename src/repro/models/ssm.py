"""Selective SSM (Mamba-style) mixer — the DWConv-1d consumer.

The conv preactivation is the paper's depthwise convolution
(kernels/dwconv1d.py on TPU; jnp ref elsewhere). The selective scan is
chunked: a ``lax.scan`` over time chunks carrying the (B, d_inner, N) state,
with an associative scan inside each chunk — bounds the materialized
(B, chunk, d_inner, N) discretized tensors.

Used by hymba-1.5b (parallel attn+mamba heads) and available standalone.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from repro.core.dwconv import depthwise1d_causal, depthwise1d_step
from repro.core.pwconv import DEFAULT_POLICY, KernelPolicy
from repro.models.layers import init_linear, linear


def init_mamba(key, d_model: int, cfg: SSMConfig, dtype=jnp.float32):
    di = d_model * cfg.expand
    n = cfg.d_state
    dt_rank = max(1, d_model // 16)
    ks = jax.random.split(key, 6)
    # dt bias init so softplus(bias) spans [dt_min, dt_max] (mamba init)
    u = jax.random.uniform(ks[5], (di,))
    dt0 = jnp.exp(u * (jnp.log(cfg.dt_max) - jnp.log(cfg.dt_min))
                  + jnp.log(cfg.dt_min))
    dt_bias = dt0 + jnp.log(-jnp.expm1(-dt0))  # inverse softplus
    return {
        "w_in": init_linear(ks[0], d_model, 2 * di, dtype=dtype),
        "conv": (jax.random.normal(ks[1], (cfg.conv_k, di)) *
                 cfg.conv_k ** -0.5).astype(jnp.float32),
        "w_bcdt": init_linear(ks[2], di, 2 * n + dt_rank, dtype=dtype),
        "w_dt": init_linear(ks[3], dt_rank, di, dtype=dtype),
        "dt_bias": dt_bias.astype(jnp.float32),
        "a_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, n + 1, dtype=jnp.float32), (di, n)).copy()),
        "d_skip": jnp.ones((di,), jnp.float32),
        "w_out": init_linear(ks[4], di, d_model, dtype=dtype),
    }


def selective_scan(
    u: jax.Array,            # (B, L, di) conv+silu output
    dt: jax.Array,           # (B, L, di) softplus'd step sizes
    a: jax.Array,            # (di, N)  negative (=-exp(a_log))
    b: jax.Array,            # (B, L, N)
    c: jax.Array,            # (B, L, N)
    d_skip: jax.Array,       # (di,)
    *,
    chunk: int = 128,
    h0: Optional[jax.Array] = None,  # (B, di, N)
):
    """Returns (y (B, L, di) f32, h_last (B, di, N) f32)."""
    nb, l, di = u.shape
    n = a.shape[1]
    chunk = min(chunk, l)
    pad = (-l) % chunk
    if pad:
        u = jnp.pad(u, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    nc = (l + pad) // chunk

    def to_chunks(x):
        return x.reshape(nb, nc, chunk, -1).swapaxes(0, 1)

    xs = (to_chunks(u.astype(jnp.float32)), to_chunks(dt.astype(jnp.float32)),
          to_chunks(b.astype(jnp.float32)), to_chunks(c.astype(jnp.float32)))
    h_init = (jnp.zeros((nb, di, n), jnp.float32) if h0 is None
              else h0.astype(jnp.float32))

    def body(h, inp):
        uc, dtc, bc, cc = inp                              # (nb, chunk, .)
        da = jnp.exp(dtc[..., None] * a[None, None])       # (nb,c,di,N)
        dbu = (dtc * uc)[..., None] * bc[:, :, None, :]    # (nb,c,di,N)

        def op(lhs, rhs):
            return (rhs[0] * lhs[0], rhs[0] * lhs[1] + rhs[1])

        a_cum, hs = jax.lax.associative_scan(op, (da, dbu), axis=1)
        hs = hs + a_cum * h[:, None]                       # add carry-in
        y = jnp.einsum("bcdn,bcn->bcd", hs, cc)
        return hs[:, -1], y

    h_last, ys = jax.lax.scan(body, h_init, xs)
    y = ys.swapaxes(0, 1).reshape(nb, nc * chunk, di)[:, :l]
    y = y + u[:, :l].astype(jnp.float32) * d_skip[None, None]
    return y, h_last


def selective_step(h, u_t, dt_t, a, b_t, c_t, d_skip):
    """One decode step. h (B,di,N); u_t/dt_t (B,di); b_t/c_t (B,N)."""
    da = jnp.exp(dt_t[..., None] * a[None])                # (B,di,N)
    dbu = (dt_t * u_t)[..., None] * b_t[:, None, :]
    h = da * h + dbu
    y = jnp.einsum("bdn,bn->bd", h, c_t) + u_t * d_skip[None]
    return h, y


def _proj_scan_inputs(p, xi, cfg: SSMConfig, policy):
    """xi (..., di) conv+silu output -> (dt, b, c)."""
    n = cfg.d_state
    bcdt = linear(p["w_bcdt"], xi, policy=policy).astype(jnp.float32)
    b, c, dt_low = jnp.split(bcdt, [n, 2 * n], axis=-1)
    dt = linear(p["w_dt"], dt_low.astype(xi.dtype), policy=policy)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    return dt, b, c


def mamba_mixer(p, x: jax.Array, cfg: SSMConfig, *,
                policy: KernelPolicy = DEFAULT_POLICY,
                h0=None, conv_state=None, return_state: bool = False):
    """Full-sequence mixer. x (B, L, d) -> (B, L, d).

    return_state: also return the decode cache {h, conv} after the last
    position (conv = last K-1 *pre-conv* inputs, matching mamba_mixer_step).
    """
    xz = linear(p["w_in"], x, policy=policy)
    xi_raw, z = jnp.split(xz, 2, axis=-1)                  # (B, L, di)
    xi = depthwise1d_causal(xi_raw, p["conv"].astype(xi_raw.dtype),
                            policy=policy)
    xi = jax.nn.silu(xi)
    dt, b, c = _proj_scan_inputs(p, xi, cfg, policy)
    a = -jnp.exp(p["a_log"])
    y, h_last = selective_scan(xi, dt, a, b, c, p["d_skip"],
                               chunk=cfg.chunk, h0=h0)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = linear(p["w_out"], y, policy=policy)
    if return_state:
        kc = p["conv"].shape[0]
        tail = xi_raw[:, -(kc - 1):, :].astype(jnp.float32)
        pad = (kc - 1) - tail.shape[1]
        if pad > 0:
            tail = jnp.pad(tail, ((0, 0), (pad, 0), (0, 0)))
        return out, {"h": h_last, "conv": tail}
    return out


def init_mamba_state(batch: int, d_model: int, cfg: SSMConfig):
    di = d_model * cfg.expand
    return {
        "h": jnp.zeros((batch, di, cfg.d_state), jnp.float32),
        "conv": jnp.zeros((batch, max(cfg.conv_k - 1, 1), di), jnp.float32),
    }


def mamba_mixer_step(p, x_t: jax.Array, state: dict, cfg: SSMConfig, *,
                     policy: KernelPolicy = DEFAULT_POLICY):
    """One decode step. x_t (B, 1, d); state from init_mamba_state."""
    bsz = x_t.shape[0]
    xz = linear(p["w_in"], x_t[:, 0], policy=policy)       # (B, 2di)
    xi, z = jnp.split(xz, 2, axis=-1)
    conv_state, xi = depthwise1d_step(
        state["conv"].astype(xi.dtype), xi, p["conv"].astype(xi.dtype)
    )
    xi = jax.nn.silu(xi)
    dt, b, c = _proj_scan_inputs(p, xi, cfg, policy)
    a = -jnp.exp(p["a_log"])
    h, y = selective_step(state["h"], xi.astype(jnp.float32), dt, a, b, c,
                          p["d_skip"])
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x_t.dtype)
    out = linear(p["w_out"], y, policy=policy)[:, None, :]
    return out, {"h": h, "conv": conv_state.astype(jnp.float32)}
