"""Model assembly for every assigned architecture.

Layer stacking is a *periodic pattern scan*: a config expands to a repeating
pattern of layer variants (e.g. llama4: 3 sliding-window layers + 1 global
NoPE layer; xLSTM: [mLSTM, sLSTM]; dense: [attn_mlp]). Parameters are stacked
per variant position with a leading (n_layers/period) axis and the model body
is one ``lax.scan`` over pattern groups — HLO size stays O(period), which is
what keeps 80/94-layer models compilable for the 512-device dry run.

Forward modes:
* hidden_states    — full sequence (train / prefill), blockwise attention.
* loss_fn          — chunked cross-entropy (+ MoE aux losses).
* prefill          — hidden_states + per-layer cache capture.
* decode_step      — one token through the pattern with stacked caches.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.pwconv import DEFAULT_POLICY, KernelPolicy
from repro.models import attention as attn_lib
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models import xlstm as xlstm_lib
from repro.models.layers import (
    chunked_cross_entropy,
    embed,
    init_embedding,
    init_linear,
    init_norm,
    linear,
    norm,
    unembed_logits,
)
from repro.models.mlp import init_mlp, mlp
from repro.sharding.rules import current_rules, shard_act


# ---------------------------------------------------------------------------
# Layer variants and patterns
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LayerVariant:
    kind: str                      # attn_mlp | hymba | mlstm | slstm | enc | dec
    window: Optional[int] = None
    rope: bool = True
    use_moe: bool = False
    sink: int = 0


def layer_pattern(cfg: ModelConfig) -> list[LayerVariant]:
    if cfg.family == "ssm" and cfg.xlstm is not None:
        every = max(cfg.xlstm.slstm_every, 1)
        return [LayerVariant(kind="mlstm")] * (every - 1) + [
            LayerVariant(kind="slstm")
        ]
    if cfg.family == "hybrid":
        return [LayerVariant(kind="hymba", window=cfg.sliding_window,
                             sink=cfg.meta_tokens)]
    import math
    ge = cfg.global_every if (cfg.global_every and cfg.sliding_window) else 1
    me = cfg.moe_every if cfg.moe is not None else 1
    period = math.lcm(ge, me)
    variants = []
    for i in range(period):
        is_global = ge > 1 and (i % ge == ge - 1)
        variants.append(LayerVariant(
            kind="attn_mlp",
            window=None if is_global else cfg.sliding_window,
            rope=not (is_global and cfg.nope_on_global),
            use_moe=cfg.moe is not None and (i % me == me - 1),
        ))
    return variants


# ---------------------------------------------------------------------------
# Single-layer init / forward / decode by variant kind
# ---------------------------------------------------------------------------


def _init_attn_params(key, cfg: ModelConfig):
    return attn_lib.init_attention(
        key, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
        qkv_bias=cfg.qkv_bias, qk_norm=cfg.qk_norm, dtype=cfg.jax_dtype,
    )


def init_layer(key, cfg: ModelConfig, variant: LayerVariant):
    ks = jax.random.split(key, 6)
    dtype = cfg.jax_dtype
    if variant.kind == "mlstm":
        return xlstm_lib.init_mlstm_block(ks[0], cfg.d_model, cfg.n_heads,
                                          cfg.xlstm, dtype=dtype)
    if variant.kind == "slstm":
        return xlstm_lib.init_slstm_block(ks[0], cfg.d_model, cfg.n_heads,
                                          cfg.xlstm, dtype=dtype)
    p = {
        "ln_attn": init_norm(cfg.norm_type, cfg.d_model),
        "attn": _init_attn_params(ks[0], cfg),
    }
    if variant.kind == "hymba":
        p["mamba"] = ssm_lib.init_mamba(ks[1], cfg.d_model, cfg.ssm,
                                        dtype=dtype)
        p["ln_out_attn"] = init_norm("rms", cfg.d_model)
        p["ln_out_mamba"] = init_norm("rms", cfg.d_model)
    if variant.kind == "dec":
        p["ln_cross"] = init_norm(cfg.norm_type, cfg.d_model)
        p["cross"] = _init_attn_params(ks[2], cfg)
    if not cfg.parallel_block:
        p["ln_mlp"] = init_norm(cfg.norm_type, cfg.d_model)
    if variant.use_moe:
        p["moe"] = moe_lib.init_moe(ks[3], cfg.d_model, cfg.moe, cfg.d_ff,
                                    dtype=dtype)
    else:
        p["mlp"] = init_mlp(ks[3], cfg.d_model, cfg.d_ff, dtype=dtype)
    return p


def _moe_kwargs():
    r = current_rules()
    if r is None or r.mesh is None:
        return dict(mesh=None)
    return dict(mesh=r.mesh, data_axes=r.batch_axes,
                model_axis=r.model_axis)


def layer_forward(p, x, cfg: ModelConfig, variant: LayerVariant, *,
                  positions=None, xkv=None, causal=True,
                  policy: KernelPolicy = DEFAULT_POLICY,
                  capture_kv: bool = False):
    """x (B,S,d) -> (x', aux) where aux = {moe metrics, captured kv/state}."""
    aux: dict[str, Any] = {}
    if variant.kind == "mlstm":
        res = xlstm_lib.mlstm_block(
            p, x, n_heads=cfg.n_heads, cfg=cfg.xlstm, chunk=cfg.attn_chunk // 8,
            policy=policy, return_cache=capture_kv,
        )
        if capture_kv:
            res, aux["state"] = res
        return res, aux
    if variant.kind == "slstm":
        res = xlstm_lib.slstm_block(
            p, x, n_heads=cfg.n_heads, cfg=cfg.xlstm, chunk=cfg.attn_chunk // 8,
            policy=policy, return_cache=capture_kv,
        )
        if capture_kv:
            res, aux["state"] = res
        return res, aux

    attn_kwargs = dict(
        n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim,
        positions=positions, window=variant.window, sink=variant.sink,
        rope_theta=cfg.rope_theta if variant.rope else None,
        qk_norm=cfg.qk_norm, chunk=cfg.attn_chunk, policy=policy,
        causal=causal,
    )
    xn = norm(x, p["ln_attn"], cfg.norm_type)
    res = attn_lib.attention(p["attn"], xn, return_kv=capture_kv,
                             **attn_kwargs)
    attn_out, kv = res if capture_kv else (res, None)
    if capture_kv:
        aux["kv"] = kv

    if variant.kind == "hymba":
        mres = ssm_lib.mamba_mixer(p["mamba"], xn, cfg.ssm, policy=policy,
                                   return_state=capture_kv)
        if capture_kv:
            mamba_out, aux["state"] = mres
        else:
            mamba_out = mres
        mixed = 0.5 * (norm(attn_out, p["ln_out_attn"], "rms")
                       + norm(mamba_out, p["ln_out_mamba"], "rms"))
        x = x + mixed
        xn2 = norm(x, p["ln_mlp"], cfg.norm_type)
        x = x + mlp(p["mlp"], xn2, policy=policy)
        return x, aux

    if variant.kind == "dec":
        x = x + attn_out
        xc = norm(x, p["ln_cross"], cfg.norm_type)
        cross_kwargs = dict(attn_kwargs)
        cross_kwargs.update(positions=None, window=None, sink=0)
        cres = attn_lib.attention(p["cross"], xc, xkv=xkv,
                                  return_kv=capture_kv, **cross_kwargs)
        cross_out, ckv = cres if capture_kv else (cres, None)
        if capture_kv:
            aux["cross_kv"] = ckv
        x = x + cross_out
        xn2 = norm(x, p["ln_mlp"], cfg.norm_type)
        return x + mlp(p["mlp"], xn2, policy=policy), aux

    if cfg.parallel_block:  # command-r: shared input norm, parallel residual
        mlp_out = mlp(p["mlp"], xn, policy=policy)
        return x + attn_out + mlp_out, aux

    x = x + attn_out
    xn2 = norm(x, p["ln_mlp"], cfg.norm_type)
    if variant.use_moe:
        y, moe_aux = moe_lib.moe_forward(p["moe"], xn2, cfg.moe,
                                         policy=policy, **_moe_kwargs())
        aux.update(moe_aux)
        return x + y, aux
    return x + mlp(p["mlp"], xn2, policy=policy), aux


# ---------------------------------------------------------------------------
# Per-layer decode (one token) + cache containers
# ---------------------------------------------------------------------------


def init_layer_cache(cfg: ModelConfig, variant: LayerVariant, batch: int,
                     max_len: int):
    dh, hkv = cfg.head_dim, cfg.n_kv_heads
    kdtype = cfg.jax_dtype
    if variant.kind == "mlstm":
        return xlstm_lib.init_mlstm_cache(batch, cfg.d_model, cfg.n_heads,
                                          cfg.xlstm)
    if variant.kind == "slstm":
        return xlstm_lib.init_slstm_cache(batch, cfg.d_model, cfg.n_heads,
                                          cfg.xlstm)
    if variant.window is not None and max_len > variant.window + variant.sink:
        s_c = variant.window + variant.sink      # streaming ring buffer
    else:
        s_c = max_len
    if cfg.kv_quant:
        cache = {
            "k": jnp.zeros((batch, s_c, hkv, dh), jnp.int8),
            "v": jnp.zeros((batch, s_c, hkv, dh), jnp.int8),
            "k_scale": jnp.zeros((batch, s_c, hkv), jnp.float32),
            "v_scale": jnp.zeros((batch, s_c, hkv), jnp.float32),
        }
    else:
        cache = {
            "k": jnp.zeros((batch, s_c, hkv, dh), kdtype),
            "v": jnp.zeros((batch, s_c, hkv, dh), kdtype),
        }
    if variant.kind == "hymba":
        cache["mamba"] = ssm_lib.init_mamba_state(batch, cfg.d_model, cfg.ssm)
    return cache


def layer_decode(p, x_t, cache, pos, cfg: ModelConfig, variant: LayerVariant,
                 *, enc_kv=None, policy: KernelPolicy = DEFAULT_POLICY):
    """x_t (B,1,d), per-layer cache -> (x_t', cache')."""
    if variant.kind == "mlstm":
        return xlstm_lib.mlstm_block_step(p, x_t, cache, n_heads=cfg.n_heads,
                                          cfg=cfg.xlstm, policy=policy)
    if variant.kind == "slstm":
        return xlstm_lib.slstm_block_step(p, x_t, cache, n_heads=cfg.n_heads,
                                          cfg=cfg.xlstm, policy=policy)

    ring = (variant.window is not None
            and cache["k"].shape[1] < 10**9
            and cache["k"].shape[1] == variant.window + variant.sink)
    xn = norm(x_t, p["ln_attn"], cfg.norm_type)
    scales = ((cache["k_scale"], cache["v_scale"])
              if cfg.kv_quant else None)
    res = attn_lib.attention_decode(
        p["attn"], xn, cache["k"], cache["v"], pos,
        n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim,
        window=variant.window, sink=variant.sink, ring=ring,
        scales=scales,
        rope_theta=cfg.rope_theta if variant.rope else None,
        qk_norm=cfg.qk_norm, policy=policy,
    )
    if cfg.kv_quant:
        attn_out, new_k, new_v, (ks, vs) = res
        cache = dict(cache, k=new_k, v=new_v, k_scale=ks, v_scale=vs)
    else:
        attn_out, new_k, new_v = res
        cache = dict(cache, k=new_k, v=new_v)

    if variant.kind == "hymba":
        mamba_out, mstate = ssm_lib.mamba_mixer_step(
            p["mamba"], xn, cache["mamba"], cfg.ssm, policy=policy
        )
        cache["mamba"] = mstate
        mixed = 0.5 * (norm(attn_out, p["ln_out_attn"], "rms")
                       + norm(mamba_out, p["ln_out_mamba"], "rms"))
        x_t = x_t + mixed
        xn2 = norm(x_t, p["ln_mlp"], cfg.norm_type)
        return x_t + mlp(p["mlp"], xn2, policy=policy), cache

    if variant.kind == "dec":
        x_t = x_t + attn_out
        xc = norm(x_t, p["ln_cross"], cfg.norm_type)
        enc_k, enc_v = enc_kv
        q, _, _ = attn_lib._project_qkv(
            p["cross"], xc, xc, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
            qk_norm=cfg.qk_norm, policy=policy,
        )
        cross = attn_lib.dense_attention(q, enc_k, enc_v, causal=False)
        cross = cross.reshape(x_t.shape[0], 1, cfg.n_heads * cfg.head_dim)
        x_t = x_t + linear(p["cross"]["w_o"], cross, policy=policy)
        xn2 = norm(x_t, p["ln_mlp"], cfg.norm_type)
        return x_t + mlp(p["mlp"], xn2, policy=policy), cache

    if cfg.parallel_block:
        return x_t + attn_out + mlp(p["mlp"], xn, policy=policy), cache

    x_t = x_t + attn_out
    xn2 = norm(x_t, p["ln_mlp"], cfg.norm_type)
    if variant.use_moe:
        y, _ = moe_lib.moe_forward(p["moe"], xn2, cfg.moe, policy=policy,
                                   **_moe_kwargs())
        return x_t + y, cache
    return x_t + mlp(p["mlp"], xn2, policy=policy), cache


# ---------------------------------------------------------------------------
# Whole-model params
# ---------------------------------------------------------------------------


def _stack_init(fn, key, n):
    return jax.vmap(fn)(jax.random.split(key, n))


def init_params(cfg: ModelConfig, key) -> dict:
    pattern = layer_pattern(cfg)
    if cfg.encdec is not None:
        pattern = [LayerVariant(kind="dec")]
    period = len(pattern)
    assert cfg.n_layers % period == 0, (cfg.n_layers, period)
    groups = cfg.n_layers // period
    ks = jax.random.split(key, 8)
    params: dict[str, Any] = {
        "embedding": init_embedding(ks[0], cfg.vocab_size, cfg.d_model,
                                    dtype=cfg.jax_dtype),
        "ln_final": init_norm(cfg.norm_type, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = init_embedding(ks[1], cfg.vocab_size, cfg.d_model,
                                           dtype=cfg.jax_dtype)
    for vi, variant in enumerate(pattern):
        params[f"blocks_v{vi}"] = _stack_init(
            lambda k, v=variant: init_layer(k, cfg, v),
            jax.random.fold_in(ks[2], vi), groups,
        )
    if cfg.meta_tokens:
        params["meta"] = (jax.random.normal(
            ks[5], (cfg.meta_tokens, cfg.d_model)) * 0.02
        ).astype(cfg.jax_dtype)
    if cfg.encdec is not None:
        enc_variant = LayerVariant(kind="attn_mlp")
        params["enc_blocks"] = _stack_init(
            lambda k: init_layer(k, cfg, enc_variant), ks[6],
            cfg.encdec.n_enc_layers,
        )
        params["enc_ln_final"] = init_norm(cfg.norm_type, cfg.d_model)
        params["enc_pos"] = (jax.random.normal(
            ks[7], (cfg.encdec.enc_seq, cfg.d_model)) * 0.02
        ).astype(cfg.jax_dtype)
    return params


# ---------------------------------------------------------------------------
# Full-sequence forward
# ---------------------------------------------------------------------------


def _maybe_remat(fn, cfg: ModelConfig):
    return jax.checkpoint(fn) if cfg.remat == "block" else fn


def _run_encoder(cfg, params, frames, policy):
    """Whisper encoder over stubbed frame embeddings (B, Senc, d)."""
    x = frames + params["enc_pos"][None].astype(frames.dtype)
    x = shard_act(x, "btd")
    variant = LayerVariant(kind="attn_mlp")

    def body(x, p_layer):
        def blk(x):
            y, _ = layer_forward(p_layer, x, cfg, variant, causal=False,
                                 policy=policy)
            return y
        return _maybe_remat(blk, cfg)(x), None

    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return norm(x, params["enc_ln_final"], cfg.norm_type)


def hidden_states(cfg: ModelConfig, params, tokens, *, frontend=None,
                  policy: KernelPolicy = DEFAULT_POLICY,
                  capture_kv: bool = False):
    """tokens (B, S) -> (hidden (B, P+S, d), prefix_len P, aux).

    frontend: stubbed modality embeddings (VLM patches / llama4 fusion), or
    encoder frames for enc-dec models (consumed by the encoder).
    aux: accumulated MoE metrics and (if capture_kv) per-layer kv stacks.
    """
    b, s = tokens.shape
    x = embed(params["embedding"], tokens)
    prefix = 0
    enc_out = None
    if cfg.encdec is not None:
        assert frontend is not None, "enc-dec model needs encoder frames"
        enc_out = _run_encoder(cfg, params, frontend, policy)
    else:
        pieces = []
        if cfg.meta_tokens:
            meta = jnp.broadcast_to(params["meta"][None],
                                    (b, cfg.meta_tokens, cfg.d_model))
            pieces.append(meta.astype(x.dtype))
            prefix += cfg.meta_tokens
        if frontend is not None:
            pieces.append(frontend.astype(x.dtype))
            prefix += frontend.shape[1]
        if pieces:
            x = jnp.concatenate(pieces + [x], axis=1)
    x = shard_act(x, "btd")
    total = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(total)[None], (b, total))

    pattern = layer_pattern(cfg)
    if cfg.encdec is not None:
        pattern = [LayerVariant(kind="dec")]

    aux_init = {"aux_loss": jnp.float32(0.0), "drop_frac": jnp.float32(0.0)}
    kv_stacks: dict[int, Any] = {}

    def group_body(carry, p_group):
        x, aux = carry
        capt = {}
        for vi, variant in enumerate(pattern):
            p_layer = p_group[f"blocks_v{vi}"]

            def blk(x, p_layer=p_layer, variant=variant):
                return layer_forward(
                    p_layer, x, cfg, variant, positions=positions,
                    xkv=enc_out, policy=policy, capture_kv=capture_kv,
                )
            y, a = _maybe_remat(blk, cfg)(x)
            x = shard_act(y, "btd")
            if "aux_loss" in a:
                aux = {
                    "aux_loss": aux["aux_loss"] + a["aux_loss"],
                    "drop_frac": aux["drop_frac"] + a["drop_frac"],
                }
            if capture_kv:
                capt[f"v{vi}"] = {k: a[k] for k in ("kv", "cross_kv", "state")
                                  if k in a}
        return (x, aux), capt if capture_kv else None

    if cfg.encdec is not None:
        stacked = {"blocks_v0": params["blocks_v0"]}
        groups = cfg.n_layers
    else:
        stacked = {f"blocks_v{vi}": params[f"blocks_v{vi}"]
                   for vi in range(len(pattern))}
        groups = cfg.n_layers // len(pattern)

    if cfg.scan_layers:
        (x, aux), capt = jax.lax.scan(group_body, (x, aux_init), stacked)
    else:
        capts = []
        aux = aux_init
        for g in range(groups):
            p_group = jax.tree_util.tree_map(lambda a: a[g], stacked)
            (x, aux), c = group_body((x, aux), p_group)
            capts.append(c)
        capt = (jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *capts)
                if capture_kv else None)

    x = norm(x, params["ln_final"], cfg.norm_type)
    aux = {k: v / max(cfg.n_layers, 1) for k, v in aux.items()}
    if capture_kv:
        aux["kv_stacks"] = capt
    if cfg.encdec is not None:
        aux["enc_out"] = enc_out
    return x, prefix, aux


def loss_fn(cfg: ModelConfig, params, batch, *,
            policy: KernelPolicy = DEFAULT_POLICY):
    """batch: {tokens, labels [, frontend]} -> (loss, metrics)."""
    tokens = shard_act(batch["tokens"], "tokens")
    x, prefix, aux = hidden_states(cfg, params, tokens,
                                   frontend=batch.get("frontend"),
                                   policy=policy)
    x = x[:, prefix:, :]
    table = params["embedding" if cfg.tie_embeddings else "unembed"]["table"]
    nll_sum, n_tok = chunked_cross_entropy(
        x, table, batch["labels"], chunk=cfg.loss_chunk
    )
    loss = nll_sum / jnp.maximum(n_tok, 1.0)
    metrics = {"nll": loss, "tokens": n_tok}
    if cfg.moe is not None:
        loss = loss + cfg.moe.router_aux_weight * aux["aux_loss"]
        metrics["moe_aux"] = aux["aux_loss"]
        metrics["moe_drop"] = aux["drop_frac"]
    metrics["loss"] = loss
    return loss, metrics
