"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel) + sLSTM (scalar
memory, sequential scan). arXiv:2405.04517.

mLSTM stabilized exponential gating:
    m_t = max(logf_t + m_{t-1}, i_t)
    C_t = exp(logf_t + m_{t-1} - m_t) C_{t-1} + exp(i_t - m_t) k_t v_t^T
    n_t = exp(logf_t + m_{t-1} - m_t) n_{t-1} + exp(i_t - m_t) k_t
    h_t = (C_t^T q_t) / max(|n_t . q_t|, exp(-m_t))

Two implementations with *identical* semantics (tested equal):
* ``mlstm_recurrent`` — lax.scan over time; decode + oracle.
* ``mlstm_chunkwise`` — log-space cumulative gates inside a chunk (intra part
  is a masked quadratic form, inter part through the carried (C, n, m) state).
  Training memory is O(n_chunks * state), not O(L * state).

The depthwise conv preactivations use the paper's DWConv-1d kernel.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import XLSTMConfig
from repro.core.dwconv import depthwise1d_causal, depthwise1d_step
from repro.core.pwconv import DEFAULT_POLICY, KernelPolicy
from repro.models.layers import init_linear, linear, rms_norm

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# mLSTM cell
# ---------------------------------------------------------------------------


def mlstm_recurrent(q, k, v, igate, logf, state=None):
    """q/k/v: (B, L, H, dh); igate/logf: (B, L, H). Returns (h, state).

    state = (c (B,H,dk,dv), n (B,H,dk), m (B,H)).
    """
    b, l, h, dh = q.shape
    scale = dh ** -0.5
    if state is None:
        state = (jnp.zeros((b, h, dh, dh), jnp.float32),
                 jnp.zeros((b, h, dh), jnp.float32),
                 jnp.full((b, h), -jnp.inf, jnp.float32))

    def step(carry, inp):
        c, n, m = carry
        qt, kt, vt, it, ft = inp                     # (B,H,dh) / (B,H)
        m_new = jnp.maximum(ft + m, it)
        fac_f = jnp.exp(ft + m - m_new)[..., None]
        fac_i = jnp.exp(it - m_new)[..., None]
        c = fac_f[..., None] * c + fac_i[..., None] * (
            kt[..., :, None] * vt[..., None, :]
        )
        n = fac_f * n + fac_i * kt
        num = jnp.einsum("bhkv,bhk->bhv", c, qt * scale)
        den = jnp.maximum(
            jnp.abs(jnp.einsum("bhk,bhk->bh", n, qt * scale)),
            jnp.exp(-m_new),
        )
        return (c, n, m_new), num / den[..., None]

    tm = lambda x: jnp.moveaxis(x.astype(jnp.float32), 1, 0)  # time-major
    (c, n, m), hs = jax.lax.scan(
        step, state, (tm(q), tm(k), tm(v), tm(igate), tm(logf))
    )
    return jnp.moveaxis(hs, 0, 1), (c, n, m)


def mlstm_step(q1, k1, v1, i1, f1, state):
    """One decode step. q1/k1/v1 (B,H,dh); i1/f1 (B,H)."""
    h, state = mlstm_recurrent(
        q1[:, None], k1[:, None], v1[:, None], i1[:, None], f1[:, None],
        state,
    )
    return h[:, 0], state


def mlstm_chunkwise(q, k, v, igate, logf, *, chunk: int = 128, state=None):
    """Chunkwise-parallel mLSTM, exactly equal to mlstm_recurrent."""
    b, l, h, dh = q.shape
    scale = dh ** -0.5
    chunk = min(chunk, l)
    pad = (-l) % chunk
    if pad:
        z3 = ((0, 0), (0, pad), (0, 0), (0, 0))
        q, k, v = (jnp.pad(x, z3) for x in (q, k, v))
        igate = jnp.pad(igate, ((0, 0), (0, pad), (0, 0)),
                        constant_values=NEG_INF)
        logf = jnp.pad(logf, ((0, 0), (0, pad), (0, 0)))
    nc = (l + pad) // chunk
    if state is None:
        state = (jnp.zeros((b, h, dh, dh), jnp.float32),
                 jnp.zeros((b, h, dh), jnp.float32),
                 jnp.full((b, h), -jnp.inf, jnp.float32))

    def to_chunks(x):
        return jnp.moveaxis(
            x.astype(jnp.float32).reshape(b, nc, chunk, *x.shape[2:]), 1, 0
        )

    xs = tuple(to_chunks(x) for x in (q, k, v, igate, logf))

    def body(carry, inp):
        c0, n0, m0 = carry
        qc, kc, vc, ic, fc = inp                    # (B, chunk, H, ...)
        fcum = jnp.cumsum(fc, axis=1)               # F_i inclusive (B,c,H)
        # intra log-decay D[i,j] = F_i - F_j + i_j  (j <= i)
        d = (fcum[:, :, None] - fcum[:, None, :] + ic[:, None, :])
        mask = jnp.tril(jnp.ones((chunk, chunk), bool))
        d = jnp.where(mask[None, :, :, None], d, NEG_INF)  # (B,c,c,H)
        m_intra = d.max(axis=2)                            # (B,c,H)
        m_inter = fcum + m0[:, None]                       # (B,c,H)
        m_i = jnp.maximum(m_intra, m_inter)

        s = jnp.einsum("bihd,bjhd->bijh", qc * scale, kc)  # (B,c,c,H)
        w = s * jnp.exp(d - m_i[:, :, None])
        num = jnp.einsum("bijh,bjhv->bihv", w, vc)
        den = w.sum(axis=2)                                # (B,c,H)

        inter_fac = jnp.exp(m_inter - m_i)                 # (B,c,H)
        num = num + inter_fac[..., None] * jnp.einsum(
            "bhkv,bihk->bihv", c0, qc * scale
        )
        den = den + inter_fac * jnp.einsum("bhk,bihk->bih", n0, qc * scale)
        hout = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_i))[..., None]

        # state to next chunk
        g = fcum[:, -1]                                    # (B,H) total decay
        dk_ = g[:, None] - fcum + ic                       # (B,c,H)
        m_new = jnp.maximum(g + m0, dk_.max(axis=1))
        kfac = jnp.exp(dk_ - m_new[:, None])               # (B,c,H)
        c_new = (jnp.exp(g + m0 - m_new)[..., None, None] * c0
                 + jnp.einsum("bjh,bjhk,bjhv->bhkv", kfac, kc, vc))
        n_new = (jnp.exp(g + m0 - m_new)[..., None] * n0
                 + jnp.einsum("bjh,bjhk->bhk", kfac, kc))
        return (c_new, n_new, m_new), hout

    (c, n, m), hs = jax.lax.scan(body, state, xs)
    hout = jnp.moveaxis(hs, 0, 1).reshape(b, nc * chunk, h, dh)[:, :l]
    return hout, (c, n, m)


# ---------------------------------------------------------------------------
# sLSTM cell (sequential; chunk-checkpointed scan)
# ---------------------------------------------------------------------------


def slstm_scan(zg, ig, fg, og, r_weights, *, state=None, chunk: int = 128):
    """Gate preactivations zg/ig/fg/og: (B, L, H, dh). Recurrent weights
    r_weights: (H, dh, 4*dh) block-diagonal per head. Returns (h, state)."""
    b, l, h, dh = zg.shape
    if state is None:
        state = tuple(jnp.zeros((b, h, dh), jnp.float32) for _ in range(3)) \
            + (jnp.full((b, h, dh), -jnp.inf, jnp.float32),)

    def step(carry, inp):
        c, n, hprev, m = carry
        z_x, i_x, f_x, o_x = inp
        rec = jnp.einsum("bhd,hde->bhe", hprev, r_weights)
        z_r, i_r, f_r, o_r = jnp.split(rec, 4, axis=-1)
        z = jnp.tanh(z_x + z_r)
        o = jax.nn.sigmoid(o_x + o_r)
        itil = i_x + i_r
        ftil = jax.nn.log_sigmoid(f_x + f_r)
        m_new = jnp.maximum(ftil + m, itil)
        i_p = jnp.exp(itil - m_new)
        f_p = jnp.exp(ftil + m - m_new)
        c = f_p * c + i_p * z
        n = f_p * n + i_p
        hnew = o * c / jnp.maximum(n, 1e-6)
        return (c, n, hnew, m_new), hnew

    tm = lambda x: jnp.moveaxis(x.astype(jnp.float32), 1, 0)
    xs = (tm(zg), tm(ig), tm(fg), tm(og))

    chunk = min(chunk, l)
    if l % chunk == 0 and l > chunk:
        nc = l // chunk
        xs_c = tuple(x.reshape(nc, chunk, *x.shape[1:]) for x in xs)

        @jax.checkpoint
        def chunk_step(carry, inp):
            return jax.lax.scan(step, carry, inp)

        state, hs = jax.lax.scan(chunk_step, state, xs_c)
        hs = hs.reshape(l, *hs.shape[2:])
    else:
        state, hs = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(hs, 0, 1), state


def slstm_step(zg, ig, fg, og, r_weights, state):
    """One decode step; gate preactivations (B, H, dh)."""
    h, state = slstm_scan(zg[:, None], ig[:, None], fg[:, None],
                          og[:, None], r_weights, state=state, chunk=1)
    return h[:, 0], state


# ---------------------------------------------------------------------------
# Blocks (params + forward). mLSTM: pre-up-projection; sLSTM: post-FFN.
# ---------------------------------------------------------------------------


def init_mlstm_block(key, d_model: int, n_heads: int, cfg: XLSTMConfig,
                     dtype=jnp.float32):
    ks = jax.random.split(key, 8)
    di = int(d_model * cfg.proj_factor)
    dh = di // n_heads
    return {
        "norm": {"scale": jnp.zeros((d_model,), jnp.float32)},
        "w_up": init_linear(ks[0], d_model, 2 * di, dtype=dtype),
        "conv": (jax.random.normal(ks[1], (cfg.conv_k, di))
                 * cfg.conv_k ** -0.5).astype(jnp.float32),
        "w_q": init_linear(ks[2], di, di, dtype=dtype),
        "w_k": init_linear(ks[3], di, di, dtype=dtype),
        "w_v": init_linear(ks[4], di, di, dtype=dtype),
        "w_gates": init_linear(ks[5], di, 2 * n_heads, bias=True, dtype=dtype),
        "out_norm": {"scale": jnp.zeros((di,), jnp.float32)},
        "w_down": init_linear(ks[6], di, d_model, dtype=dtype),
    }


def _mlstm_qkv_gates(p, xv, n_heads, policy):
    b, l, di = xv.shape
    dh = di // n_heads
    xc = depthwise1d_causal(xv, p["conv"].astype(xv.dtype), policy=policy)
    xc = jax.nn.silu(xc)
    q = linear(p["w_q"], xc, policy=policy).reshape(b, l, n_heads, dh)
    k = linear(p["w_k"], xc, policy=policy).reshape(b, l, n_heads, dh)
    v = linear(p["w_v"], xv, policy=policy).reshape(b, l, n_heads, dh)
    gates = linear(p["w_gates"], xc, policy=policy).astype(jnp.float32)
    igate, fraw = jnp.split(gates, 2, axis=-1)            # (B,L,H)
    logf = jax.nn.log_sigmoid(fraw)
    return q, k, v, igate, logf


def _conv_tail(x_pre, kc):
    tail = x_pre[:, -(kc - 1):, :].astype(jnp.float32)
    pad = (kc - 1) - tail.shape[1]
    if pad > 0:
        tail = jnp.pad(tail, ((0, 0), (pad, 0), (0, 0)))
    return tail


def mlstm_block(p, x, *, n_heads: int, cfg: XLSTMConfig, chunk: int = 128,
                policy: KernelPolicy = DEFAULT_POLICY,
                return_cache: bool = False):
    """x (B, L, d) -> (B, L, d) with residual."""
    xn = rms_norm(x, p["norm"]["scale"])
    up = linear(p["w_up"], xn, policy=policy)
    xv, xz = jnp.split(up, 2, axis=-1)                    # (B,L,di)
    q, k, v, igate, logf = _mlstm_qkv_gates(p, xv, n_heads, policy)
    h, (c, n, m) = mlstm_chunkwise(q, k, v, igate, logf, chunk=chunk)
    b, l, _, _ = q.shape
    h = h.reshape(b, l, -1)
    h = rms_norm(h.astype(x.dtype), p["out_norm"]["scale"])
    h = h * jax.nn.silu(xz)
    out = x + linear(p["w_down"], h, policy=policy)
    if return_cache:
        return out, {"c": c, "n": n, "m": m,
                     "conv": _conv_tail(xv, cfg.conv_k)}
    return out


def init_mlstm_cache(batch: int, d_model: int, n_heads: int,
                     cfg: XLSTMConfig):
    di = int(d_model * cfg.proj_factor)
    dh = di // n_heads
    return {
        "c": jnp.zeros((batch, n_heads, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, n_heads, dh), jnp.float32),
        "m": jnp.full((batch, n_heads), -jnp.inf, jnp.float32),
        "conv": jnp.zeros((batch, max(cfg.conv_k - 1, 1), di), jnp.float32),
    }


def mlstm_block_step(p, x_t, cache, *, n_heads: int, cfg: XLSTMConfig,
                     policy: KernelPolicy = DEFAULT_POLICY):
    """x_t (B, 1, d) -> (B, 1, d); cache from init_mlstm_cache."""
    b = x_t.shape[0]
    xn = rms_norm(x_t, p["norm"]["scale"])
    up = linear(p["w_up"], xn, policy=policy)
    xv, xz = jnp.split(up, 2, axis=-1)
    conv_state, xc = depthwise1d_step(
        cache["conv"].astype(xv.dtype), xv[:, 0], p["conv"].astype(xv.dtype)
    )
    xc = jax.nn.silu(xc)
    di = xv.shape[-1]
    dh = di // n_heads
    q = linear(p["w_q"], xc, policy=policy).reshape(b, n_heads, dh)
    k = linear(p["w_k"], xc, policy=policy).reshape(b, n_heads, dh)
    v = linear(p["w_v"], xv[:, 0], policy=policy).reshape(b, n_heads, dh)
    gates = linear(p["w_gates"], xc, policy=policy).astype(jnp.float32)
    igate, fraw = jnp.split(gates, 2, axis=-1)
    logf = jax.nn.log_sigmoid(fraw)
    h, (c, n, m) = mlstm_step(
        q, k, v, igate, logf, (cache["c"], cache["n"], cache["m"])
    )
    h = h.reshape(b, 1, di)
    h = rms_norm(h.astype(x_t.dtype), p["out_norm"]["scale"])
    h = h * jax.nn.silu(xz)
    out = x_t + linear(p["w_down"], h, policy=policy)
    return out, {"c": c, "n": n, "m": m, "conv": conv_state.astype(jnp.float32)}


def init_slstm_block(key, d_model: int, n_heads: int, cfg: XLSTMConfig,
                     dtype=jnp.float32):
    ks = jax.random.split(key, 6)
    dh = d_model // n_heads
    ff = int(d_model * 4 / 3 / 64) * 64 or d_model
    return {
        "norm": {"scale": jnp.zeros((d_model,), jnp.float32)},
        "conv": (jax.random.normal(ks[0], (cfg.conv_k, d_model))
                 * cfg.conv_k ** -0.5).astype(jnp.float32),
        "w_gates": init_linear(ks[1], d_model, 4 * d_model, bias=True,
                               dtype=dtype),
        "r": (jax.random.normal(ks[2], (n_heads, dh, 4 * dh))
              * dh ** -0.5).astype(jnp.float32),
        "out_norm": {"scale": jnp.zeros((d_model,), jnp.float32)},
        "ffn_norm": {"scale": jnp.zeros((d_model,), jnp.float32)},
        "w_ff_gate": init_linear(ks[3], d_model, ff, dtype=dtype),
        "w_ff_up": init_linear(ks[4], d_model, ff, dtype=dtype),
        "w_ff_down": init_linear(ks[5], ff, d_model, dtype=dtype),
    }


def _slstm_gates(p, xn, n_heads, policy):
    b, l, d = xn.shape
    dh = d // n_heads
    xc = depthwise1d_causal(xn, p["conv"].astype(xn.dtype), policy=policy)
    xc = jax.nn.silu(xc)
    gates = linear(p["w_gates"], xc, policy=policy).astype(jnp.float32)
    zg, ig, fg, og = jnp.split(gates, 4, axis=-1)
    reshape = lambda g: g.reshape(b, l, n_heads, dh)
    return reshape(zg), reshape(ig), reshape(fg), reshape(og)


def slstm_block(p, x, *, n_heads: int, cfg: XLSTMConfig, chunk: int = 128,
                policy: KernelPolicy = DEFAULT_POLICY,
                return_cache: bool = False):
    b, l, d = x.shape
    xn = rms_norm(x, p["norm"]["scale"])
    zg, ig, fg, og = _slstm_gates(p, xn, n_heads, policy)
    h, (c, n, hs, m) = slstm_scan(zg, ig, fg, og, p["r"], chunk=chunk)
    h = h.reshape(b, l, d).astype(x.dtype)
    x = x + rms_norm(h, p["out_norm"]["scale"])
    # post-up-projection GLU FFN (part of the sLSTM block, factor 4/3)
    xn2 = rms_norm(x, p["ffn_norm"]["scale"])
    g = linear(p["w_ff_gate"], xn2, activation="silu", policy=policy)
    u = linear(p["w_ff_up"], xn2, policy=policy)
    out = x + linear(p["w_ff_down"], g * u, policy=policy)
    if return_cache:
        return out, {"c": c, "n": n, "h": hs, "m": m,
                     "conv": _conv_tail(xn, cfg.conv_k)}
    return out


def init_slstm_cache(batch: int, d_model: int, n_heads: int,
                     cfg: XLSTMConfig):
    dh = d_model // n_heads
    return {
        "c": jnp.zeros((batch, n_heads, dh), jnp.float32),
        "n": jnp.zeros((batch, n_heads, dh), jnp.float32),
        "h": jnp.zeros((batch, n_heads, dh), jnp.float32),
        "m": jnp.full((batch, n_heads, dh), -jnp.inf, jnp.float32),
        "conv": jnp.zeros((batch, max(cfg.conv_k - 1, 1), d_model),
                          jnp.float32),
    }


def slstm_block_step(p, x_t, cache, *, n_heads: int, cfg: XLSTMConfig,
                     policy: KernelPolicy = DEFAULT_POLICY):
    b = x_t.shape[0]
    d = x_t.shape[-1]
    dh = d // n_heads
    xn = rms_norm(x_t, p["norm"]["scale"])
    conv_state, xc = depthwise1d_step(
        cache["conv"].astype(xn.dtype), xn[:, 0], p["conv"].astype(xn.dtype)
    )
    xc = jax.nn.silu(xc)
    gates = linear(p["w_gates"], xc, policy=policy).astype(jnp.float32)
    zg, ig, fg, og = (g.reshape(b, n_heads, dh)
                      for g in jnp.split(gates, 4, axis=-1))
    h, (c, n, hs, m) = slstm_step(
        zg, ig, fg, og, p["r"],
        (cache["c"], cache["n"], cache["h"], cache["m"]),
    )
    h = h.reshape(b, 1, d).astype(x_t.dtype)
    x = x_t + rms_norm(h, p["out_norm"]["scale"])
    xn = rms_norm(x, p["ffn_norm"]["scale"])
    g = linear(p["w_ff_gate"], xn, activation="silu", policy=policy)
    u = linear(p["w_ff_up"], xn, policy=policy)
    out = x + linear(p["w_ff_down"], g * u, policy=policy)
    return out, {"c": c, "n": n, "h": hs, "m": m,
                 "conv": conv_state.astype(jnp.float32)}
