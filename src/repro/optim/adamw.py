"""AdamW from scratch (no optax in this environment).

* fp32 master moments regardless of param dtype (bf16 params at scale).
* decoupled weight decay with a name-based mask (no decay on norms/bias).
* global-norm clipping, linear warmup + cosine decay schedule.
* ZeRO-1: the optimizer state tree reuses the param tree structure, so the
  launcher shards it with sharding.zero1_specs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    # moment storage dtype: float32, or bfloat16 to halve optimizer HBM at
    # the 100B+ scale (8-bit-Adam-style state compression, coarse variant)
    moments_dtype: str = "float32"


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def _decay_mask(path) -> bool:
    keys = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
    name = keys[-1]
    if name in ("scale", "bias", "b", "dt_bias", "d_skip", "m"):
        return False
    return True


def init_state(params, cfg: "AdamWConfig | None" = None) -> dict:
    dt = (jnp.bfloat16 if cfg is not None
          and cfg.moments_dtype == "bfloat16" else jnp.float32)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "mu": jax.tree_util.tree_map(zeros, params),
        "nu": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(g.astype(jnp.float32)))
        for g in jax.tree_util.tree_leaves(tree)
    ))


def apply_updates(params, grads, state, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    flat_p, treedef = jax.tree_util.tree_flatten_with_path(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_mu = jax.tree_util.tree_leaves(state["mu"])
    flat_nu = jax.tree_util.tree_leaves(state["nu"])

    new_p, new_mu, new_nu = [], [], []
    for (path, p), g, mu, nu in zip(flat_p, flat_g, flat_mu, flat_nu):
        mdt = mu.dtype
        g = g.astype(jnp.float32) * scale
        mu = b1 * mu.astype(jnp.float32) + (1 - b1) * g
        nu = b2 * nu.astype(jnp.float32) + (1 - b2) * jnp.square(g)
        upd = (mu / bc1) / (jnp.sqrt(nu / bc2) + cfg.eps)
        if cfg.weight_decay and _decay_mask(path):
            upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        new = p.astype(jnp.float32) - lr * upd
        new_p.append(new.astype(p.dtype))
        new_mu.append(mu.astype(mdt))
        new_nu.append(nu.astype(mdt))

    unflatten = jax.tree_util.tree_unflatten
    params = unflatten(treedef, new_p)
    new_state = {
        "mu": unflatten(treedef, new_mu),
        "nu": unflatten(treedef, new_nu),
        "step": step,
    }
    metrics = {"grad_norm": gnorm, "lr": lr,
               "param_norm": global_norm(params)}
    return params, new_state, metrics
