"""Gradient compression for cross-pod data parallelism.

At 1000+ nodes the pod-level gradient all-reduce crosses DCN (slow vs ICI).
Two standard compressors, both with *error feedback* (the residual of the
compression is added back into the next step's gradient) so convergence is
preserved (Karimireddy et al. 2019):

* top-k sparsification — keep the k largest-|g| entries per tensor.
* int8 stochastic-rounding quantization — per-tensor scale, unbiased.

On the compiled path the compressed gradient is what enters the all-reduce;
XLA then moves 1/compression of the bytes across the pod axis. The
compressor is exercised in tests for exactness of the error-feedback
invariant and for end-to-end convergence on a small model.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    kind: str = "none"            # none | topk | int8
    topk_frac: float = 0.01       # fraction of entries kept (topk)


def init_error(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params
    )


def _topk_tensor(g: jax.Array, frac: float):
    flat = g.reshape(-1)
    k = max(1, int(flat.size * frac))
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    mask = jnp.zeros_like(flat).at[idx].set(1.0)
    comp = flat * mask
    return comp.reshape(g.shape)


def _int8_tensor(g: jax.Array, key) -> jax.Array:
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    scaled = g / scale
    noise = jax.random.uniform(key, g.shape, minval=-0.5, maxval=0.5)
    q = jnp.clip(jnp.round(scaled + noise), -127, 127).astype(jnp.int8)
    return q.astype(jnp.float32) * scale


def compress(grads, error, cfg: CompressionConfig, key=None):
    """Returns (compressed_grads, new_error). g_comp + e_new == g + e_old
    exactly for topk (the error-feedback invariant); int8 is unbiased."""
    if cfg.kind == "none":
        return grads, error

    def one(g, e, k):
        g = g.astype(jnp.float32) + e
        if cfg.kind == "topk":
            c = _topk_tensor(g, cfg.topk_frac)
        elif cfg.kind == "int8":
            c = _int8_tensor(g, k)
        else:
            raise ValueError(cfg.kind)
        return c, g - c

    leaves, treedef = jax.tree_util.tree_flatten(grads)
    e_leaves = jax.tree_util.tree_leaves(error)
    keys = (jax.random.split(key, len(leaves)) if key is not None
            else [None] * len(leaves))
    out = [one(g, e, k) for g, e, k in zip(leaves, e_leaves, keys)]
    comp = jax.tree_util.tree_unflatten(treedef, [c for c, _ in out])
    new_err = jax.tree_util.tree_unflatten(treedef, [e for _, e in out])
    return comp, new_err
