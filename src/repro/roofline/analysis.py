"""Roofline analysis from a compiled dry-run artifact.

Three terms per (arch × shape × mesh), all in seconds (per step):

    compute    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
    memory     = HLO_bytes_per_device / HBM_bw_per_chip
    collective = collective_bytes_per_device / ICI_link_bw

``compiled.cost_analysis()`` is the per-device SPMD program cost, so the
"/ chips" in the spec formulas is already applied. collective bytes are
parsed from the post-SPMD HLO text: we sum the result sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute,
with a 2x(n-1)/n ring factor for all-reduce and (n-1)/n for the others
(n from the op's replica_groups when parseable).

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Optional

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (one link direction)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_TYPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _TYPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str) -> Optional[int]:
    m = _GROUPS_V2_RE.search(line)    # replica_groups=[ngroups,size]
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)       # replica_groups={{0,1,2,...},...}
    if m:
        return len(m.group(1).split(","))
    return None


def parse_collectives(hlo_text: str) -> dict:
    """Per-collective-kind byte totals (per device) from HLO text."""
    out = {k: 0.0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        # result type is between '=' and the op name
        for op in _COLLECTIVES:
            m = re.search(rf"=\s*(.+?)\s+{op}(-start|-done)?\(", stripped)
            if not m:
                continue
            if m.group(2) == "-done":     # avoid double count of async pair
                continue
            size = _shape_bytes(m.group(1))
            n = _group_size(stripped) or 2
            if op == "all-reduce":
                moved = 2.0 * size * (n - 1) / n
            else:
                moved = 1.0 * size * (n - 1) / n
            out[op] += moved
            counts[op] += 1
            break
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["counts"] = counts
    return out


def cost_dict(compiled) -> dict:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return dict(ca)


def memory_dict(compiled) -> dict:
    ma = compiled.memory_analysis()
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes")
    return {k: getattr(ma, k, None) for k in keys}


def analyze(compiled, *, n_devices: int, model_flops_global: float,
            label: str = "", group_compiled=None, trips: int = 1) -> dict:
    """Full roofline record for one dry-run cell.

    XLA cost_analysis counts a `while` (lax.scan) body ONCE, so a scanned
    layer stack under-reports per-step cost by the trip count. When
    ``group_compiled`` (the compiled single-layer-group program) is given,
    per-step totals are reconstructed as

        total = group_cost * trips + max(full_cost - group_cost, 0)

    where the residual term covers everything outside the layer loop
    (embedding, loss, optimizer, step-level collectives). Known remaining
    undercounts (documented in EXPERIMENTS.md): inner scans *within* one
    layer (blockwise-attention pair scan, CE chunk scan, whisper encoder
    stack) are still counted once inside their program.
    """
    cost = cost_dict(compiled)
    hlo = compiled.as_text()
    coll = parse_collectives(hlo)
    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))
    coll_total = coll["total"]
    raw = {"flops": flops_dev, "bytes": bytes_dev, "coll": coll_total}
    if group_compiled is not None and trips > 1:
        gcost = cost_dict(group_compiled)
        gcoll = parse_collectives(group_compiled.as_text())
        gf = float(gcost.get("flops", 0.0))
        gb = float(gcost.get("bytes accessed", 0.0))
        gc = gcoll["total"]
        flops_dev = gf * trips + max(flops_dev - gf, 0.0)
        bytes_dev = gb * trips + max(bytes_dev - gb, 0.0)
        coll_total = gc * trips + max(coll_total - gc, 0.0)
        for k in _COLLECTIVES:
            coll[k] = gcoll[k] * trips + max(coll[k] - gcoll[k], 0.0)
    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_total / ICI_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dominant = max(terms, key=terms.get)
    model_flops_dev = model_flops_global / n_devices
    mem = memory_dict(compiled)
    bound = max(t_compute, t_memory, t_coll)
    return {
        "label": label,
        "n_devices": n_devices,
        "trips": trips,
        "raw_while_once": raw,
        "hlo_flops_per_device": flops_dev,
        "hlo_bytes_per_device": bytes_dev,
        "collective_bytes_per_device": coll["total"],
        "collectives": {k: coll[k] for k in _COLLECTIVES},
        "collective_counts": coll["counts"],
        **terms,
        "dominant": dominant,
        "model_flops_global": model_flops_global,
        "model_flops_per_device": model_flops_dev,
        "useful_flop_ratio": (model_flops_dev / flops_dev
                              if flops_dev else 0.0),
        # fraction of the roofline achieved if the dominant term were the
        # only cost (upper bound on achievable MFU for this lowering)
        "roofline_mfu_bound": (model_flops_dev / PEAK_FLOPS) / bound
        if bound else 0.0,
        "memory_analysis": mem,
    }


def model_flops(cfg, shape_meta: dict) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE); D = tokens this step (global).

    Training counts fwd+bwd (the 6x); decode counts one token per sequence
    with the 2x inference factor (2*N*D) plus KV-attention read FLOPs are
    negligible and excluded by convention.
    """
    kind = shape_meta["kind"]
    b, s = shape_meta["global_batch"], shape_meta["seq_len"]
    n = cfg.n_active_params()
    if kind == "train":
        return 6.0 * n * b * s
    if kind == "prefill":
        return 2.0 * n * b * s
    return 2.0 * n * b  # decode: one token per sequence


def save_record(record: dict, path: str):
    with open(path, "w") as f:
        json.dump(record, f, indent=2, default=str)
