"""repro.runtime — fault-tolerant execution (DESIGN.md §9).

The runtime counterpart of the §8 static verifier: a structured failure
taxonomy (``failures``), a deterministic fault-injection harness
(``faultinject``), a runtime degradation ladder with persistent plan
quarantine (``ladder``, ``quarantine``, ``executor``) and fallback-event
telemetry (``telemetry``).  ``core/chain.execute`` and
``core/network.execute_network`` route here under the default
``KernelPolicy(on_failure="degrade")``.

Lazy attribute re-exports on purpose: ``kernels/lowering.py`` imports the
submodules ``failures``/``faultinject`` (which triggers this package
``__init__``), so nothing here may import the kernel or core layers at
module scope.
"""
from __future__ import annotations

_EXPORTS = {
    "KernelFailure": "failures",
    "LoweringFailure": "failures",
    "CompileFailure": "failures",
    "NumericalFailure": "failures",
    "InjectedFault": "failures",
    "classify": "failures",
    "INJECTION_POINTS": "faultinject",
    "RUNGS": "ladder",
    "Quarantine": "quarantine",
    "quarantine_path": "quarantine",
    "execute_chain": "executor",
    "run_network": "executor",
    "runtime_report": "telemetry",
    "reset_runtime_telemetry": "telemetry",
    "fallback_count": "telemetry",
}

__all__ = sorted(_EXPORTS) + ["executor", "failures", "faultinject",
                              "ladder", "quarantine", "telemetry"]


def __getattr__(name):
    mod = _EXPORTS.get(name)
    if mod is None:
        raise AttributeError(f"module 'repro.runtime' has no attribute "
                             f"{name!r}")
    import importlib
    return getattr(importlib.import_module(f"repro.runtime.{mod}"), name)
