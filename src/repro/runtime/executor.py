"""Fault-tolerant execution (DESIGN.md §9): the degradation ladder driver.

``core/chain.execute`` and ``core/network.execute_network`` route here
whenever ``policy.on_failure == "degrade"`` (the default) or
``policy.numeric_guard`` is on.  The steady-state path is the production
path — resolve the plan exactly as the raw executor would (explicit plan,
autotune winner, or analytic planner), run it, return — plus one
``try/except``; only a classified failure enters the ladder:

1. classify (``runtime/failures.py``) — unrecognized exceptions re-raise
   unwrapped, ``on_failure="raise"`` propagates the taxonomy error;
2. quarantine the rung the failure maps to (``runtime/ladder.py``) in the
   persistent store (``runtime/quarantine.py``) — future processes skip it
   with zero retries;
3. re-plan one rung down and retry, bounded by the ladder length, each
   fallback recorded in telemetry and warned about;
4. the last rung runs the analytic plan on the XLA reference backend
   (``kernels/ref`` numerics) with fault injection suppressed — the rung
   of last resort cannot itself be injected away.

The whole-network guard keeps the ONE-jitted-call fast path: on a
classified failure of the composed program it falls back to per-block
guarded chains — each block then quarantines its own problem, so the next
``plan_network`` (this process or a fresh one) plans around the bad blocks
and re-jits cleanly.
"""
from __future__ import annotations

import contextlib
import dataclasses
import warnings

import jax.numpy as jnp

from repro.runtime import failures, faultinject, ladder, quarantine, telemetry

#: One attempt per ladder rung:
#: fused3 -> fusedmb -> fused2 -> dw_se -> unfused -> ref.
MAX_ATTEMPTS = len(ladder.RUNGS)


def _require_finite(y, *, scope: str) -> None:
    """The ``policy.numeric_guard`` check: host-side all-finite test of the
    output (forces a sync — that is the price of the guard)."""
    if not bool(jnp.isfinite(y.astype(jnp.float32)).all()):
        raise failures.NumericalFailure(
            f"non-finite values in {scope} output (numeric_guard)")


def execute_chain(spec, params, x, *, policy, chain_plan=None):
    """Guarded ``chain.execute``: the ladder loop described above."""
    from repro.core import chain  # lazy: core sits above the runtime layer
    from repro.kernels import autotune, lowering

    degrade = policy.on_failure == "degrade"
    key = autotune.problem_key(spec, x.shape, x.dtype, policy)
    qpath = quarantine.quarantine_path(policy)
    q = quarantine.load(qpath)
    banned = set(q.banned(key)) if degrade else set()
    supplied = chain_plan
    if (supplied is not None and banned
            and ("unfused" in banned
                 or any(s.kind in banned for s in supplied.segments))):
        warnings.warn(
            f"ignoring supplied chain_plan for {key}: it uses quarantined "
            f"rungs ({sorted(banned)} banned in {qpath})",
            RuntimeWarning, stacklevel=3)
        supplied = None
    if banned:
        telemetry.record_quarantine_hit(scope="chain", key=key,
                                        banned=banned)
    cp = None
    failure = None
    for attempt in range(MAX_ATTEMPTS):
        ref_mode = degrade and "unfused" in banned
        run_policy = (dataclasses.replace(policy, impl="xla")
                      if ref_mode else policy)
        try:
            if ref_mode:
                # the reference rung executes the ANALYTIC plan on the XLA
                # backend (= kernels/ref numerics): plan quarantine-blind
                # (on_failure="raise" skips the consult) so the output is
                # bitwise the reference oracle's, not a degraded layout
                cp = chain.plan(spec, x.shape, dtype=x.dtype,
                                policy=dataclasses.replace(
                                    run_policy, autotune=False,
                                    on_failure="raise"))
            elif attempt == 0 and not banned:
                # the production path: explicit plan / autotune / analytic
                cp = chain.resolve_plan(spec, params, x, policy=policy,
                                        chain_plan=supplied)
            else:
                # post-failure or quarantined: analytic re-plan; plan()
                # consults the quarantine itself and skips banned rungs
                cp = chain.plan(spec, x.shape, dtype=x.dtype,
                                policy=dataclasses.replace(policy,
                                                           autotune=False))
            runner = lowering.lower(spec, cp, run_policy)
            ctx = (faultinject.suppressed() if ref_mode
                   else contextlib.nullcontext())
            with ctx:
                faultinject.check("compile:chain")
                y = runner(params, x)
                if policy.numeric_guard:
                    y = faultinject.poison("numeric:chain", y)
                    _require_finite(y, scope="chain")
            if attempt:
                telemetry.record_recovery(
                    scope="chain", key=key,
                    rung="ref" if ref_mode else ladder.plan_rung(cp))
            return y
        except Exception as e:
            failure = failures.classify(e)
            if failure is None:
                raise  # not a recognized backend failure: never masked
            if not degrade or ref_mode or attempt + 1 >= MAX_ATTEMPTS:
                if failure is e:
                    raise
                raise failure from e
            ban = ladder.ban_for_failure(failure, cp)
            from_rung = ("ref" if ref_mode
                         else ladder.plan_rung(cp) if cp is not None
                         else "unknown")
            banned.add(ban)
            to_rung = ladder.next_rung(ban, banned)
            q.add_failure(
                key,
                signature=autotune.problem_signature(spec, x.shape, x.dtype,
                                                     policy),
                ban=ban,
                failure={**failure.describe(), "from_rung": from_rung})
            q.save()
            telemetry.record_fallback(
                scope="chain", key=key, from_rung=from_rung,
                to_rung=to_rung, failure_kind=failure.kind,
                segment_kind=failure.segment_kind,
                injected=failure.injected, error=str(failure))
            warnings.warn(
                f"runtime ladder: {failure.kind} failure at rung "
                f"{from_rung} (segment {failure.segment_kind}) for chain "
                f"{key}: {failure}; quarantined {ban!r} in {qpath}, "
                f"retrying at {to_rung}", RuntimeWarning, stacklevel=3)
    raise failure  # bounded attempts exhausted (unreachable: ref re-raises)


def run_network(net, params, x, *, policy, network_plan=None,
                block_dtype_policies=None):
    """Guarded ``execute_network``: ONE jitted call on the happy path; on a
    classified failure, recover with per-block guarded chains (each block
    quarantining its own problem) so the next call re-plans and re-jits
    around the bad blocks."""
    from repro.core import network

    degrade = policy.on_failure == "degrade"
    try:
        faultinject.check("compile:network")
        y = network._execute_network_raw(
            net, params, x, policy=policy, network_plan=network_plan,
            block_dtype_policies=block_dtype_policies)
        if policy.numeric_guard:
            y = faultinject.poison("numeric:network", y)
            _require_finite(y, scope="network")
        return y
    except Exception as e:
        failure = failures.classify(e)
        if failure is None:
            raise
        if not degrade:
            if failure is e:
                raise
            raise failure from e
        nkey = network.network_key(net, x.shape, x.dtype, policy,
                                   block_dtype_policies)
        telemetry.record_fallback(
            scope="network", key=nkey, from_rung="network-jit",
            to_rung="per-block", failure_kind=failure.kind,
            segment_kind=failure.segment_kind, injected=failure.injected,
            error=str(failure))
        warnings.warn(
            f"runtime ladder: {failure.kind} failure in the whole-network "
            f"jitted call for {nkey}: {failure}; recovering per-block "
            "(failing blocks will be quarantined and the next call "
            "re-plans around them)", RuntimeWarning, stacklevel=3)
        policies = network.resolve_block_policies(net, policy,
                                                  block_dtype_policies)
        y = x
        for spec, p, pol in zip(net.blocks, params, policies):
            y = execute_chain(spec, p, y, policy=pol)
        telemetry.record_recovery(scope="network", key=nkey,
                                  rung="per-block")
        return y
