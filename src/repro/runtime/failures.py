"""Structured failure taxonomy for the runtime layer (DESIGN.md §9).

The §8 static verifier proves what it can before execution; everything it
cannot reach — whether Mosaic actually accepts a lowering, whether XLA can
compile the working set into real VMEM, whether the arithmetic stays finite
— surfaces only at run time, as a zoo of backend exceptions.  This module
names them:

* :class:`LoweringFailure` — Pallas/Mosaic rejected the kernel (unsupported
  op, layout, or reshape in the kernel body);
* :class:`CompileFailure`  — XLA compilation or allocation failed
  (RESOURCE_EXHAUSTED / OOM / VMEM pressure) or the backend died at run
  time;
* :class:`NumericalFailure` — the ``numeric_guard`` found non-finite values
  in a kernel/chain output.

Each failure is tagged with the :class:`~repro.kernels.blocking.ChainSegment`
that produced it (kind + index + stage indices) so the degradation ladder
(``runtime/ladder.py``) knows exactly which rung to quarantine.

:func:`classify` is deliberately WHITELIST-based: only exception types the
backend plausibly raises (``RuntimeError`` and subclasses — which includes
jaxlib's ``XlaRuntimeError`` — ``NotImplementedError``, ``MemoryError``) are
wrapped; everything else (``ValueError``, ``TypeError``, ``AssertionError``,
``analysis.PlanVerificationError``, ...) answers ``None`` and propagates
unwrapped, so the ladder can never mask a genuine bug in this codebase as a
degradable backend fault.

Stdlib-only on purpose: ``kernels/lowering.py`` imports this module, so it
must sit below the whole kernel layer.
"""
from __future__ import annotations

from typing import Optional, Sequence


class KernelFailure(RuntimeError):
    """Base of the taxonomy; ``kind`` names the class in telemetry,
    quarantine records and ``runtime_report()``."""

    kind = "kernel"

    def __init__(self, message: str, *,
                 segment_kind: Optional[str] = None,
                 segment_index: Optional[int] = None,
                 stage_indices: Optional[Sequence[int]] = None,
                 original: Optional[BaseException] = None,
                 injected: bool = False):
        super().__init__(message)
        self.segment_kind = segment_kind
        self.segment_index = segment_index
        self.stage_indices = (tuple(int(i) for i in stage_indices)
                              if stage_indices is not None else None)
        self.original = original
        self.injected = bool(injected)

    def describe(self) -> dict:
        """JSON-serializable record for quarantine entries / telemetry."""
        return {
            "kind": self.kind,
            "message": str(self)[:300],
            "segment_kind": self.segment_kind,
            "segment_index": self.segment_index,
            "stage_indices": (list(self.stage_indices)
                              if self.stage_indices is not None else None),
            "original": (type(self.original).__name__
                         if self.original is not None else None),
            "injected": self.injected,
        }


class LoweringFailure(KernelFailure):
    kind = "lowering"


class CompileFailure(KernelFailure):
    kind = "compile"


class NumericalFailure(KernelFailure):
    kind = "numeric"


class InjectedFault(RuntimeError):
    """Raised by ``runtime/faultinject.check`` at an armed injection point;
    classified like the real failure it imitates (the message carries the
    backend markers)."""

    def __init__(self, message: str, *, point: str):
        super().__init__(message)
        self.point = point


#: Message substrings identifying a Mosaic/Pallas lowering rejection.
_LOWERING_MARKERS = ("mosaic", "pallas", "lowering", "unsupported",
                     "not implemented", "unimplemented")


def classify(exc: BaseException, *,
             segment_kind: Optional[str] = None,
             segment_index: Optional[int] = None,
             stage_indices: Optional[Sequence[int]] = None,
             ) -> Optional[KernelFailure]:
    """Map a raised exception onto the taxonomy, or ``None`` when it is not
    a recognized backend failure (the caller must then re-raise it as-is).

    An already-classified :class:`KernelFailure` passes through, gaining
    segment tags it lacks (the lowering tags at segment scope; outer layers
    only add context, never overwrite it).
    """
    if isinstance(exc, KernelFailure):
        if exc.segment_kind is None and segment_kind is not None:
            exc.segment_kind = segment_kind
            exc.segment_index = segment_index
            exc.stage_indices = (tuple(int(i) for i in stage_indices)
                                 if stage_indices is not None else None)
        return exc
    catchable = isinstance(exc, (RuntimeError, NotImplementedError,
                                 MemoryError))
    if not catchable:
        return None
    ctx = dict(segment_kind=segment_kind, segment_index=segment_index,
               stage_indices=stage_indices, original=exc,
               injected=isinstance(exc, InjectedFault))
    msg = str(exc).lower()
    if (isinstance(exc, NotImplementedError)
            or any(m in msg for m in _LOWERING_MARKERS)):
        return LoweringFailure(str(exc), **ctx)
    # XlaRuntimeError (a RuntimeError subclass), RESOURCE_EXHAUSTED/OOM and
    # any other backend runtime death: the compile/execute class
    return CompileFailure(str(exc), **ctx)
