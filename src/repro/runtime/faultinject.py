"""Deterministic fault-injection harness (DESIGN.md §9).

The degradation ladder exists for failures only real TPU hardware produces
— which CPU CI never sees.  This module closes that testability gap with
NAMED injection points compiled into the dispatch path: tests and the
``--fault-inject`` benchmark flag arm a point, the next time execution
passes it a :class:`~repro.runtime.failures.InjectedFault` is raised (or,
for the ``numeric:*`` points, the output is NaN-poisoned so the numeric
guard genuinely detects non-finite values, not a simulation of detecting
them).  Disarmed points cost one dict lookup — nothing is patched or
monkeyed, so the injected control flow IS the production control flow.

Determinism: a point fires exactly ``times`` times (``PERSISTENT`` = every
pass), counted per arm; :func:`fired_counts` lets CI assert the telemetry
records *exactly* the injected fallbacks.  :func:`suppressed` marks the
reference rung: the ladder's last rung must not be injectable, or a
persistent fault could make the fallback of last resort fail too.

Stdlib-only (``kernels/lowering.py`` imports this; the array op in
:func:`poison` uses only methods of the array passed in).
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Dict, Optional, Tuple

from repro.runtime.failures import InjectedFault

#: The injection-point catalog (DESIGN.md §9).  Arming any other name is a
#: ValueError — a typo must fail the test arming it, not silently no-op.
INJECTION_POINTS = {
    "lowering:separable_fused":
        "fused2/fused3 segment dispatch (kernels/lowering._run_fused; the "
        "two rungs share the kernel, so they share the point)",
    "lowering:fused_mbconv":
        "fusedmb/mb segment dispatch (kernels/lowering._run_fused_mb and "
        "the standalone conv; the two rungs share the point)",
    "lowering:se_epilogue":
        "dw_se/se segment dispatch (kernels/lowering._run_dw_se and "
        "_run_se; the two rungs share the point)",
    "lowering:pwconv":
        "standalone pw segment dispatch (kernels/lowering.lower)",
    "lowering:dwconv2d":
        "standalone dw segment dispatch (kernels/lowering.lower)",
    "compile:chain":
        "chain runner invocation (runtime/executor.execute_chain)",
    "compile:network":
        "whole-network jitted invocation (runtime/executor.run_network)",
    "numeric:chain":
        "NaN-poisons the chain output before the numeric guard",
    "numeric:network":
        "NaN-poisons the network output before the numeric guard",
}

#: ``times`` value meaning "fire on every pass until disarmed".
PERSISTENT = -1


@dataclasses.dataclass
class _Fault:
    point: str
    times: int
    fired: int = 0
    message: Optional[str] = None

    @property
    def live(self) -> bool:
        return self.times < 0 or self.fired < self.times


_faults: Dict[str, _Fault] = {}
_local = threading.local()


def arm(point: str, times: int = 1, message: Optional[str] = None) -> None:
    """Arm ``point`` to fire ``times`` times (:data:`PERSISTENT` forever)."""
    if point not in INJECTION_POINTS:
        raise ValueError(
            f"unknown injection point {point!r}; catalog: "
            f"{sorted(INJECTION_POINTS)}")
    _faults[point] = _Fault(point, times=int(times), message=message)


def disarm(point: str) -> None:
    _faults.pop(point, None)


def disarm_all() -> None:
    _faults.clear()


def armed_points() -> Tuple[str, ...]:
    return tuple(sorted(p for p, f in _faults.items() if f.live))


def fired_counts() -> Dict[str, int]:
    """{point: times fired} for every point armed since the last disarm."""
    return {p: f.fired for p, f in _faults.items()}


def _suppressed() -> bool:
    return getattr(_local, "depth", 0) > 0


@contextlib.contextmanager
def suppressed():
    """No point fires inside — the executor wraps the reference rung in
    this, so a persistent fault cannot take down the rung of last resort."""
    _local.depth = getattr(_local, "depth", 0) + 1
    try:
        yield
    finally:
        _local.depth -= 1


@contextlib.contextmanager
def injected(point: str, times: int = 1, message: Optional[str] = None):
    """Scoped arm: arms on enter, disarms on exit (test convenience)."""
    arm(point, times=times, message=message)
    try:
        yield
    finally:
        disarm(point)


def _default_message(point: str) -> str:
    # imitate the real failure class the point stands in for: the markers
    # steer failures.classify the same way the genuine backend error would
    if point.startswith("lowering:"):
        return ("Mosaic lowering failed: unsupported operation in kernel "
                f"body (fault-injected at {point})")
    return ("RESOURCE_EXHAUSTED: out of memory while compiling "
            f"(fault-injected at {point})")


def check(point: str) -> None:
    """Raise :class:`InjectedFault` when ``point`` is armed and live; a
    no-op (one dict lookup) otherwise.  Suppressed inside
    :func:`suppressed`."""
    f = _faults.get(point)
    if f is None or _suppressed() or not f.live:
        return
    f.fired += 1
    raise InjectedFault(f.message or _default_message(point), point=point)


def poison(point: str, y):
    """NaN-poison one element of ``y`` when ``point`` is armed — the
    ``numeric:*`` points: the guard then detects a REAL non-finite output."""
    f = _faults.get(point)
    if f is None or _suppressed() or not f.live:
        return y
    f.fired += 1
    return y.at[tuple(0 for _ in y.shape)].set(float("nan"))


def arm_from_spec(spec: str) -> Tuple[str, ...]:
    """Arm from a CLI string: comma-separated ``point[:times]`` items,
    persistent when ``times`` is omitted.  Returns the armed point names."""
    points = []
    for item in str(spec).split(","):
        item = item.strip()
        if not item:
            continue
        name, times = item, PERSISTENT
        # point names contain one ':' (category:site); a second one is the
        # fire count
        if item.count(":") == 2:
            name, _, t = item.rpartition(":")
            times = int(t)
        arm(name, times=times)
        points.append(name)
    return tuple(points)
