"""The runtime degradation ladder (DESIGN.md §9).

Mirrors the planner's analytic feasibility ladder (3-fused -> 2-fused ->
unfused, ``core/chain.plan``) with one extra rung the planner cannot
express: the XLA reference path (``kernels/ref``), which trades all of the
paper's data-movement wins for the guarantee of running anywhere.

    RUNGS = fused3 -> fused2 -> unfused -> ref

A failure maps to a BAN — the rung the quarantine removes — from the
segment tag the taxonomy carries:

* a ``fused3`` / ``fusedmb`` / ``fused2`` / ``dw_se`` segment failure bans
  exactly that fusion kind (the planner's next walk degrades the window
  one step — fusedmb to mb+pw, dw_se to dw+se);
* a standalone ``pw`` / ``dw`` / ``se`` / ``mb`` segment failure bans
  ``unfused`` — the Pallas kernels themselves are unusable for this
  problem, so the executor escalates straight to the reference rung (an
  ``se`` failure is two pwconv passes failing; ``mb`` is already XLA but
  shares the segment taxonomy);
* an untagged failure (chain-scope compile error, numeric-guard trip on
  the final output) bans the highest rung the failing plan actually used.
"""
from __future__ import annotations

from typing import Optional

RUNGS = ("fused3", "fusedmb", "fused2", "dw_se", "unfused", "ref")


def plan_rung(cp) -> str:
    """The ladder rung a ChainPlan executes at: its highest fusion kind."""
    kinds = {seg.kind for seg in cp.segments}
    for r in ("fused3", "fusedmb", "fused2", "dw_se"):
        if r in kinds:
            return r
    return "unfused"


def ban_for_failure(failure, cp=None) -> str:
    """Which rung to quarantine for this classified failure (see module
    docstring); ``cp`` is the plan that was executing, for untagged
    failures."""
    if failure.segment_kind in ("fused3", "fusedmb", "fused2", "dw_se"):
        return failure.segment_kind
    if failure.segment_kind in ("pw", "dw", "se", "mb"):
        return "unfused"
    return plan_rung(cp) if cp is not None else "unfused"


def next_rung(ban: str, banned) -> str:
    """The rung the retry lands on after banning ``ban``, given the full
    banned set (for telemetry/warning messages).  Advisory: RUNGS
    interleaves both stage-algebra families (separable and SE/fused-MB),
    so the retry's ACTUAL rung is whatever the re-plan produces for the
    spec — a fused3 ban on a chain with no FusedMB stage lands on fused2,
    skipping the inapplicable fusedmb rung this names."""
    start = RUNGS.index(ban) + 1 if ban in RUNGS else len(RUNGS) - 1
    for r in RUNGS[start:]:
        if r == "ref" or r not in banned:
            return r
    return "ref"
