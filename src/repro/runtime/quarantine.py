"""Persistent plan quarantine (DESIGN.md §9): failed rungs stay failed.

When the degradation ladder quarantines a rung for a problem, the decision
must outlive the process — the whole point is that the NEXT run (a fresh
server, a re-launched benchmark) skips the known-bad plan with zero retry
attempts instead of re-failing it.  The store therefore follows the tune
cache's exact discipline:

* same key: ``kernels/autotune.problem_key`` — spec stages + input
  shape/dtype + dtype policy + VMEM budget + **backend fingerprint**, so a
  rung that failed on one backend is never banned on another;
* same persistence: ``kernels/diskstore.VersionedJsonStore`` — versioned,
  merge-on-write atomic saves, warn-and-recover loads;
* same placement: a ``quarantine.json`` sibling of the policy's tune cache
  (or ``$REPRO_QUARANTINE`` / ``~/.cache/repro/quarantine.json``).

Entry format (one per problem key)::

    {"signature": {...problem_signature...},
     "banned": ["fused3", ...],            # subset of BANNABLE
     "failures": [{...KernelFailure.describe() + from_rung...}, ...]}

``banned`` names the LADDER RUNGS the planner must skip: ``fused3`` /
``fused2`` remove those fusion windows from ``core/chain.plan``'s walk;
``unfused`` means even the standalone kernels failed and the executor goes
straight to the XLA reference rung.

A small mtime/size-keyed memo makes the steady-state consult (every
``plan()`` call in degrade mode) one ``os.stat``.
"""
from __future__ import annotations

import os
import threading
from typing import FrozenSet, Optional, Sequence

from repro.kernels import autotune as _autotune
from repro.kernels.diskstore import VersionedJsonStore

QUARANTINE_VERSION = 1

#: Rungs an entry may ban (the "ref" rung is never bannable — it is the
#: fallback of last resort and fault injection is suppressed around it).
#: ``fusedmb`` and ``dw_se`` are the DESIGN §10 fusion windows: banning
#: one removes that window from ``core/chain.plan``'s walk, degrading to
#: the standalone composition (mb+pw / dw+se) exactly like fused3->fused2.
BANNABLE = ("fused3", "fusedmb", "fused2", "dw_se", "unfused")


def default_quarantine_path() -> str:
    """$REPRO_QUARANTINE, else ~/.cache/repro/quarantine.json."""
    env = os.environ.get("REPRO_QUARANTINE")
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache", "repro",
                        "quarantine.json")


def quarantine_path(policy) -> str:
    """The store lives alongside the policy's tune cache when one is
    pinned (same directory, same lifecycle); else the default path."""
    if policy.tune_cache:
        d = os.path.dirname(policy.tune_cache)
        return os.path.join(d or ".", "quarantine.json")
    return default_quarantine_path()


class Quarantine(VersionedJsonStore):
    version = QUARANTINE_VERSION

    def banned(self, key: str) -> FrozenSet[str]:
        entry = self.entries.get(key)
        if not isinstance(entry, dict):
            return frozenset()
        banned = entry.get("banned")
        if not isinstance(banned, list):
            return frozenset()
        return frozenset(b for b in banned if b in BANNABLE)

    def add_failure(self, key: str, *, signature: dict, ban: str,
                    failure: dict) -> None:
        assert ban in BANNABLE, ban
        entry = self.entries.get(key)
        if not isinstance(entry, dict):
            entry = {"signature": signature, "banned": [], "failures": []}
        entry["banned"] = sorted(set(entry.get("banned", [])) | {ban})
        entry.setdefault("failures", []).append(dict(failure))
        entry["failures"] = entry["failures"][-16:]
        self.entries[key] = entry

    def save(self) -> None:
        super().save()
        _memo_store(self.path, self)


# -- steady-state load memo (mtime/size keyed, one os.stat per consult) -----

_MEMO_LOCK = threading.Lock()
_MEMO: dict = {}


def _stat_sig(path: str):
    try:
        st = os.stat(path)
        return (st.st_mtime_ns, st.st_size)
    except OSError:
        return None


def load(path: str) -> Quarantine:
    sig = _stat_sig(path)
    with _MEMO_LOCK:
        hit = _MEMO.get(path)
        if hit is not None and hit[0] == sig:
            return hit[1]
    q = Quarantine.load(path)
    with _MEMO_LOCK:
        _MEMO[path] = (sig, q)
    return q


def _memo_store(path: str, q: Quarantine) -> None:
    with _MEMO_LOCK:
        _MEMO[path] = (_stat_sig(path), q)


def clear_memo() -> None:
    with _MEMO_LOCK:
        _MEMO.clear()


def banned_kinds(spec, x_shape: Sequence[int], dtype,
                 policy) -> FrozenSet[str]:
    """The rungs quarantined for this exact problem on this backend —
    what ``core/chain.plan`` skips and the executor starts below.  Records
    a quarantine-hit telemetry event when non-empty (the visible trace of
    a plan being steered around a known-bad rung)."""
    q = load(quarantine_path(policy))
    if not q.entries:
        return frozenset()
    key = _autotune.problem_key(spec, x_shape, dtype, policy)
    banned = q.banned(key)
    if banned:
        from repro.runtime import telemetry
        telemetry.record_quarantine_hit(scope="plan", key=key, banned=banned)
    return banned
