"""Fallback-event telemetry (DESIGN.md §9): per-process counters + report.

Every degradation the runtime executor performs is recorded here — which
rung fell to which, for which problem key, classified how, and whether the
underlying failure was injected — so benchmarks and CI can assert on the
aggregate: a faulted run's report must record *exactly* the injected
fallbacks, and a clean steady-state run must report **zero**.

In-memory and per-process on purpose (the persistent artifact is the
quarantine store): ``runtime_report()`` snapshots to a JSON-serializable
dict, ``reset_runtime_telemetry()`` zeroes between benchmark phases.
Stdlib-only.
"""
from __future__ import annotations

import collections
import threading
from typing import Optional

#: Bounded event log — counters never saturate, the event detail does.
MAX_EVENTS = 256

_LOCK = threading.Lock()
_COUNTERS: collections.Counter = collections.Counter()
_EVENTS: list = []


def _append_event(event: dict) -> None:
    _EVENTS.append(event)
    if len(_EVENTS) > MAX_EVENTS:
        del _EVENTS[: len(_EVENTS) - MAX_EVENTS]


def record_fallback(*, scope: str, key: str, from_rung: str, to_rung: str,
                    failure_kind: str, segment_kind: Optional[str],
                    injected: bool, error: str) -> None:
    """One rung-down retry (or network-jit -> per-block recovery)."""
    with _LOCK:
        _COUNTERS["fallbacks"] += 1
        _COUNTERS[f"fallbacks.{failure_kind}"] += 1
        _COUNTERS[f"fallbacks.{scope}"] += 1
        if injected:
            _COUNTERS["injected_fallbacks"] += 1
        _append_event({
            "event": "fallback", "scope": scope, "key": key,
            "from_rung": from_rung, "to_rung": to_rung,
            "failure_kind": failure_kind, "segment_kind": segment_kind,
            "injected": bool(injected), "error": str(error)[:300],
        })


def record_recovery(*, scope: str, key: str, rung: str) -> None:
    """A degraded attempt succeeded — the ladder landed somewhere."""
    with _LOCK:
        _COUNTERS["recoveries"] += 1
        _append_event({"event": "recovery", "scope": scope, "key": key,
                       "rung": rung})


def record_quarantine_hit(*, scope: str, key: str, banned) -> None:
    """A plan consult honored a persisted quarantine entry (skipped the
    banned rungs with ZERO retry attempts — the steady state after a
    failure)."""
    with _LOCK:
        _COUNTERS["quarantine_hits"] += 1
        _append_event({"event": "quarantine_hit", "scope": scope,
                       "key": key, "banned": sorted(banned)})


def fallback_count() -> int:
    with _LOCK:
        return int(_COUNTERS.get("fallbacks", 0))


def runtime_report() -> dict:
    """JSON-serializable snapshot; steady state = ``fallbacks == 0``."""
    with _LOCK:
        return {
            "fallbacks": int(_COUNTERS.get("fallbacks", 0)),
            "injected_fallbacks": int(_COUNTERS.get("injected_fallbacks", 0)),
            "numeric_trips": int(_COUNTERS.get("fallbacks.numeric", 0)),
            "recoveries": int(_COUNTERS.get("recoveries", 0)),
            "quarantine_hits": int(_COUNTERS.get("quarantine_hits", 0)),
            "counters": {k: int(v) for k, v in sorted(_COUNTERS.items())},
            "events": [dict(e) for e in _EVENTS],
        }


def reset_runtime_telemetry() -> None:
    with _LOCK:
        _COUNTERS.clear()
        _EVENTS.clear()
