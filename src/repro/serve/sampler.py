"""Token samplers for the serving loop."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def greedy(logits: jax.Array) -> jax.Array:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def sample(logits: jax.Array, key, *, temperature: float = 1.0,
           top_k: int = 0) -> jax.Array:
    """logits (B, V) -> tokens (B,)."""
    if temperature <= 0.0:
        return greedy(logits)
    logits = logits / temperature
    if top_k > 0:
        vals, _ = jax.lax.top_k(logits, top_k)
        kth = vals[..., -1:]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)


def generate(decode_step_fn, cache, first_tokens, n_steps: int, key,
             *, temperature: float = 0.0, top_k: int = 0):
    """Batched autoregressive generation loop (jit-compatible).

    decode_step_fn(cache, tokens (B,1)) -> (logits (B,V), cache).
    Returns (tokens (B, n_steps), cache).
    """
    def body(carry, k):
        cache, tok = carry
        logits, cache = decode_step_fn(cache, tok)
        nxt = sample(logits, k, temperature=temperature, top_k=top_k)
        return (cache, nxt[:, None]), nxt

    keys = jax.random.split(key, n_steps)
    (cache, _), toks = jax.lax.scan(body, (cache, first_tokens), keys)
    return toks.T, cache
