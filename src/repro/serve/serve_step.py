"""Serving: cache construction, prefill, and the one-token decode step.

``decode_step`` is what the decode_32k / long_500k dry-run cells lower: one
new token against a seq_len-deep cache. The cache is a stacked-per-layer
pytree scanned with the layer stack (HLO stays O(pattern period)).

Prefill:
* attention / enc-dec archs: one full forward with per-layer KV capture,
  then scatter into the cache buffers (ring-aware for SWA layers).
* ssm / hybrid archs: prefill-by-stepping (scan of decode steps over the
  prompt) — state capture through the chunked scan is a listed perf TODO.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.pwconv import DEFAULT_POLICY, KernelPolicy
from repro.models import transformer as T
from repro.models.layers import embed, norm, unembed_logits
from repro.sharding.rules import shard_act


def _pattern(cfg: ModelConfig):
    if cfg.encdec is not None:
        return [T.LayerVariant(kind="dec")]
    return T.layer_pattern(cfg)


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    """Zeroed cache pytree. max_len includes any meta/fusion prefix."""
    pattern = _pattern(cfg)
    groups = cfg.n_layers // len(pattern)
    cache: dict[str, Any] = {
        "pos": jnp.zeros((batch,), jnp.int32),
    }
    for vi, variant in enumerate(pattern):
        one = lambda key=None, v=variant: T.init_layer_cache(
            cfg, v, batch, max_len
        )
        cache[f"v{vi}"] = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *[one() for _ in range(groups)]
        )
    if cfg.encdec is not None:
        dh, hkv = cfg.head_dim, cfg.n_kv_heads
        cache["enc_k"] = jnp.zeros(
            (groups, batch, cfg.encdec.enc_seq, hkv, dh), cfg.jax_dtype)
        cache["enc_v"] = jnp.zeros_like(cache["enc_k"])
    return cache


def cache_specs(cfg: ModelConfig, batch: int, max_len: int):
    """ShapeDtypeStruct pytree of the cache (for the dry run)."""
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_len))


# ---------------------------------------------------------------------------
# Decode step
# ---------------------------------------------------------------------------


def decode_step(cfg: ModelConfig, params, cache, tokens, *,
                policy: KernelPolicy = DEFAULT_POLICY):
    """tokens (B, 1) -> (logits (B, V) f32, new cache). pos from cache."""
    pattern = _pattern(cfg)
    b = tokens.shape[0]
    pos = cache["pos"]
    x = embed(params["embedding"], tokens)                  # (B,1,d)

    stacked_p = {f"blocks_v{vi}": params[f"blocks_v{vi}"]
                 for vi in range(len(pattern))}
    stacked_c = {f"v{vi}": cache[f"v{vi}"] for vi in range(len(pattern))}
    xs = (stacked_p, stacked_c)
    if cfg.encdec is not None:
        xs = (stacked_p, stacked_c,
              {"enc_k": cache["enc_k"], "enc_v": cache["enc_v"]})

    def body(x, inp):
        if cfg.encdec is not None:
            p_group, c_group, enc = inp
            enc_kv = (enc["enc_k"], enc["enc_v"])
        else:
            p_group, c_group = inp
            enc_kv = None
        new_c = {}
        for vi, variant in enumerate(pattern):
            x, new_c[f"v{vi}"] = T.layer_decode(
                p_group[f"blocks_v{vi}"], x, c_group[f"v{vi}"], pos, cfg,
                variant, enc_kv=enc_kv, policy=policy,
            )
        return x, new_c

    if cfg.scan_layers:
        x, new_stacked = jax.lax.scan(body, x, xs)
    else:
        groups = cfg.n_layers // len(pattern)
        outs = []
        for g in range(groups):
            inp = jax.tree_util.tree_map(lambda a: a[g], xs)
            x, nc = body(x, inp)
            outs.append(nc)
        new_stacked = jax.tree_util.tree_map(
            lambda *cs: jnp.stack(cs), *outs)

    x = norm(x, params["ln_final"], cfg.norm_type)
    table = params["embedding" if cfg.tie_embeddings else "unembed"]["table"]
    logits = unembed_logits(x[:, 0], table)                  # (B, V) f32
    new_cache = dict(cache)
    new_cache.update(new_stacked)
    new_cache["pos"] = pos + 1
    return logits, new_cache


# ---------------------------------------------------------------------------
# Prefill
# ---------------------------------------------------------------------------


def _ring_fill(kv_full: jax.Array, s_c: int, sink: int, total: int):
    """Scatter full-seq KV (B, S, H, dh) into a ring cache (B, s_c, H, dh)
    matching attention_decode's slot function."""
    s = kv_full.shape[1]
    if s <= s_c:
        pad = s_c - s
        return jnp.pad(kv_full, ((0, 0), (0, pad), (0, 0), (0, 0)))
    ring_len = s_c - sink
    r = jnp.arange(s_c)
    # latest position p < s with slot(p) == r
    off = (jnp.maximum(r, sink) - sink)
    base = s - 1 - ((s - 1 - sink - off) % ring_len)
    p = jnp.where(r < sink, r, base)
    return jnp.take(kv_full, p, axis=1)


def prefill(cfg: ModelConfig, params, tokens, *, max_len: int,
            frontend=None, policy: KernelPolicy = DEFAULT_POLICY):
    """Returns (last_logits (B, V), cache primed to pos = prefix + S).

    One full forward with per-layer state capture: attention KV scattered
    into (ring-aware) cache buffers; SSM/xLSTM recurrent states carried out
    of the chunked scans directly.
    """
    pattern = _pattern(cfg)
    b, s = tokens.shape
    x, prefix, aux = T.hidden_states(cfg, params, tokens, frontend=frontend,
                                     policy=policy, capture_kv=True)
    total = prefix + s
    cache = init_cache(cfg, b, max_len)
    kv_stacks = aux["kv_stacks"]
    for vi, variant in enumerate(pattern):
        stack = kv_stacks[f"v{vi}"]
        buf = cache[f"v{vi}"]
        new_buf = dict(buf)
        if "kv" in stack:
            k_full, v_full = stack["kv"]                     # (G,B,S',Hkv,dh)
            s_c = buf["k"].shape[2]
            sink = variant.sink
            fill = jax.vmap(lambda kv: _ring_fill(kv, s_c, sink, total))
            new_buf["k"] = fill(k_full).astype(buf["k"].dtype)
            new_buf["v"] = fill(v_full).astype(buf["v"].dtype)
        if "state" in stack:
            if variant.kind == "hymba":
                new_buf["mamba"] = stack["state"]
            else:                                            # mlstm / slstm
                new_buf = stack["state"]
        cache[f"v{vi}"] = new_buf
        if cfg.encdec is not None and "cross_kv" in stack:
            ck, cv = stack["cross_kv"]
            cache["enc_k"] = ck.astype(cfg.jax_dtype)
            cache["enc_v"] = cv.astype(cfg.jax_dtype)
    cache["pos"] = jnp.full((b,), total, jnp.int32)
    table = params["embedding" if cfg.tie_embeddings else "unembed"]["table"]
    last_logits = unembed_logits(x[:, -1], table)
    return last_logits, cache


def prefill_by_stepping(cfg: ModelConfig, params, tokens, *, max_len: int,
                        policy: KernelPolicy = DEFAULT_POLICY):
    """Reference prefill: scan of decode steps. Oracle for prefill()."""
    b, s = tokens.shape
    cache = init_cache(cfg, b, max_len)
    if cfg.meta_tokens:
        meta = jnp.broadcast_to(
            params["meta"][None], (b, cfg.meta_tokens, cfg.d_model)
        ).astype(cfg.jax_dtype)
        # run meta tokens through decode steps as a learned prefix
        for i in range(cfg.meta_tokens):
            _, cache = _embedded_decode_step(cfg, params, cache,
                                             meta[:, i:i + 1], policy)

    def body(carry, tok):
        cache, _ = carry
        logits, cache = decode_step(cfg, params, cache, tok[:, None],
                                    policy=policy)
        return (cache, logits), None

    zl = jnp.zeros((b, cfg.vocab_size), jnp.float32)
    (cache, logits), _ = jax.lax.scan(body, (cache, zl), tokens.T)
    return logits, cache


def _embedded_decode_step(cfg, params, cache, x_embed, policy):
    """decode_step but starting from an embedding (meta-token priming)."""
    pattern = _pattern(cfg)
    pos = cache["pos"]
    x = x_embed
    stacked_p = {f"blocks_v{vi}": params[f"blocks_v{vi}"]
                 for vi in range(len(pattern))}
    stacked_c = {f"v{vi}": cache[f"v{vi}"] for vi in range(len(pattern))}

    def body(x, inp):
        p_group, c_group = inp
        new_c = {}
        for vi, variant in enumerate(pattern):
            x, new_c[f"v{vi}"] = T.layer_decode(
                p_group[f"blocks_v{vi}"], x, c_group[f"v{vi}"], pos, cfg,
                variant, policy=policy,
            )
        return x, new_c

    x, new_stacked = jax.lax.scan(body, x, (stacked_p, stacked_c))
    new_cache = dict(cache)
    new_cache.update(new_stacked)
    new_cache["pos"] = pos + 1
    return None, new_cache
