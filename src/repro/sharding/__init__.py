from repro.sharding.rules import (
    ShardingRules,
    current_rules,
    param_specs,
    shard_act,
    use_rules,
    zero1_specs,
)

__all__ = [
    "ShardingRules",
    "current_rules",
    "param_specs",
    "shard_act",
    "use_rules",
    "zero1_specs",
]
