"""Sharding rules: logical parameter/activation axes -> mesh axes.

Train mode (FSDP + TP + optional pod-DP):
* 2-D weights are column-parallel by default: (in, out) -> P(fsdp, tp); the
  "down"/output projections are row-parallel: (in, out) -> P(tp, fsdp).
* Expert weights (E, ., .) -> P(tp, None, None) (expert parallelism; must
  match the shard_map in_specs in models/moe.py). ZeRO-1 shards the matching
  optimizer state further over the fsdp axis.
* Embedding/unembedding table (V, d) -> P(tp, fsdp): vocab-sharded so the
  (B, chunk, V) loss logits are sharded over tp.
* Activations: batch over (pod, data); attention heads over tp when the head
  count divides; KV caches: batch over data, sequence over tp
  (flash-decoding style).

Serve mode: TP only (no fsdp) — per-token weight all-gathers would dominate
decode latency.

A sharding "context" (plain module global, set by the launcher around
lower/compile and around real execution) lets model code call
``shard_act(x, kind)`` without threading mesh details everywhere. With no
context, every helper is a no-op (CPU tests).
"""
from __future__ import annotations

import dataclasses
from contextlib import contextmanager
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    mesh: Optional[Mesh]
    batch_axes: tuple = ("data",)          # + "pod" on the multi-pod mesh
    model_axis: Optional[str] = "model"
    fsdp_axis: Optional[str] = "data"      # None in serve mode
    seq_axis: Optional[str] = None         # sequence-parallel activations
    # experts may need the extra (data) axis even at serve time — a 400B
    # expert tree does not fit TP-16 on v5e
    expert_fsdp_axis: Optional[str] = None

    @property
    def expert_fsdp(self) -> Optional[str]:
        return self.expert_fsdp_axis or self.fsdp_axis

    @property
    def model_size(self) -> int:
        if self.mesh is None or self.model_axis is None:
            return 1
        return self.mesh.shape[self.model_axis]

    @property
    def fsdp_size(self) -> int:
        if self.mesh is None or self.fsdp_axis is None:
            return 1
        return self.mesh.shape[self.fsdp_axis]


_CURRENT: list[Optional[ShardingRules]] = [None]


def current_rules() -> Optional[ShardingRules]:
    return _CURRENT[0]


@contextmanager
def use_rules(rules: Optional[ShardingRules]):
    prev = _CURRENT[0]
    _CURRENT[0] = rules
    try:
        yield rules
    finally:
        _CURRENT[0] = prev


# ---------------------------------------------------------------------------
# Activation constraints
# ---------------------------------------------------------------------------


def _div(n: int, size: int) -> bool:
    return size > 1 and n % size == 0


def shard_act(x: jax.Array, kind: str) -> jax.Array:
    """Annotate an activation with its sharding. No-op without a context.

    kinds: btd (B,S,d) · heads4 (B,S,H,dh) · cache (B,Smax,Hkv,dh) ·
    logits (B,S,V) · tokens (B,S).
    """
    r = current_rules()
    if r is None or r.mesh is None:
        return x
    tp = r.model_axis
    spec: P
    if kind == "btd":
        seq = r.seq_axis if (r.seq_axis and _div(x.shape[1], r.mesh.shape[r.seq_axis])) else None
        spec = P(r.batch_axes, seq, None)
    elif kind == "heads4":
        h_ok = tp is not None and _div(x.shape[2], r.model_size)
        spec = P(r.batch_axes, None, tp if h_ok else None, None)
    elif kind == "cache":
        s_ok = tp is not None and _div(x.shape[1], r.model_size)
        spec = P(r.batch_axes, tp if s_ok else None, None, None)
    elif kind == "q_decode":
        # decode queries: heads replicated so the score contraction shards
        # over the cache's sequence axis (flash-decoding); a heads-sharded q
        # would force GSPMD to all-gather the whole KV cache per layer
        spec = P(r.batch_axes, None, None, None)
    elif kind == "scores_decode":
        # (B, Hq, 1, S): pin S to the model axis so the partitioner computes
        # scores where the cache lives instead of gathering f32 K/V
        s_ok = tp is not None and _div(x.shape[-1], r.model_size)
        spec = P(r.batch_axes, None, None, tp if s_ok else None)
    elif kind == "logits":
        v_ok = tp is not None and _div(x.shape[-1], r.model_size)
        spec = P(r.batch_axes, None, tp if v_ok else None)
    elif kind == "tokens":
        spec = P(r.batch_axes, None)
    else:
        raise ValueError(kind)
    return jax.lax.with_sharding_constraint(x, NamedSharding(r.mesh, spec))


# ---------------------------------------------------------------------------
# Parameter specs (path-based)
# ---------------------------------------------------------------------------

_ROW_PARALLEL_KEYS = {"w_o", "w_down", "w_ff_down", "w_out", "w_dt"}
_EXPERT_KEYS = {"w_gate_e", "w_up_e", "w_down_e"}
_REPLICATED_PARENTS = {"router"}


def _leaf_spec(path: tuple, leaf, rules: ShardingRules) -> P:
    keys = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
    name = keys[-1]
    parent = keys[-2] if len(keys) >= 2 else ""
    tp, fsdp = rules.model_axis, rules.fsdp_axis
    ndim = leaf.ndim
    shape = leaf.shape

    def tp_if(n):
        return tp if (tp and _div(n, rules.model_size)) else None

    def fsdp_if(n):
        return fsdp if (fsdp and _div(n, rules.fsdp_size)) else None

    # stacked-layer leading dim(s): strip and re-prepend None
    lead = 0
    core_spec = None

    if name in _EXPERT_KEYS or parent in _EXPERT_KEYS:
        # (., E, a, b) possibly layer-stacked: E -> tp (EP), dim1 -> fsdp.
        # The shard_map in_specs (E only) re-gather dim1 per layer — that IS
        # the FSDP all-gather.
        lead = ndim - 3
        ef = rules.expert_fsdp
        ef_ok = ef and rules.mesh is not None and _div(
            shape[lead + 1], rules.mesh.shape[ef])
        core_spec = (tp_if(shape[lead]), ef if ef_ok else None, None)
    elif parent in _REPLICATED_PARENTS or name in _REPLICATED_PARENTS:
        return P(*([None] * ndim))
    elif name == "table":  # embedding (V, d)
        return P(tp_if(shape[0]), fsdp_if(shape[1]))
    elif name == "w" or name == "b":
        pname = parent
        if ndim - (1 if name == "b" else 2) > 0:
            lead = ndim - (1 if name == "b" else 2)
        if name == "b":
            if pname in _ROW_PARALLEL_KEYS:
                core_spec = (None,)
            else:
                core_spec = (tp_if(shape[lead]),)
        elif pname in _ROW_PARALLEL_KEYS:
            core_spec = (tp_if(shape[lead]), fsdp_if(shape[lead + 1]))
        else:
            core_spec = (fsdp_if(shape[lead]), tp_if(shape[lead + 1]))
    elif name == "conv":  # (K, D) depthwise filter: channel = tp (paper!)
        lead = ndim - 2
        core_spec = (None, tp_if(shape[lead + 1]))
    elif name == "a_log":  # (di, N)
        lead = ndim - 2
        core_spec = (tp_if(shape[lead]), None)
    elif name in ("d_skip", "dt_bias"):
        lead = ndim - 1
        core_spec = (tp_if(shape[lead]),)
    elif name == "r":  # slstm recurrent (H, dh, 4dh)
        lead = ndim - 3
        core_spec = (tp_if(shape[lead]), None, None)
    elif name == "meta":  # learnable meta tokens (n, d)
        return P(*([None] * ndim))
    else:  # norms, scalars
        return P(*([None] * ndim))
    return P(*([None] * lead), *core_spec)


def param_specs(params, rules: ShardingRules):
    """Pytree of PartitionSpec matching `params`."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _leaf_spec(path, leaf, rules), params
    )


def zero1_specs(params, specs, rules: ShardingRules):
    """Optimizer-state specs: param spec + fsdp sharding of the largest
    currently-unsharded dim (ZeRO-1). Falls back to the param spec."""
    fsdp = rules.fsdp_axis
    if fsdp is None or rules.fsdp_size <= 1:
        return specs

    def upgrade(leaf, spec: P):
        if leaf.ndim == 0:
            return spec
        parts = list(spec) + [None] * (leaf.ndim - len(spec))
        if fsdp in parts:
            return spec
        # largest unsharded, fsdp-divisible dim
        cands = [(leaf.shape[i], i) for i in range(leaf.ndim)
                 if parts[i] is None and leaf.shape[i] % rules.fsdp_size == 0]
        if not cands:
            return spec
        _, i = max(cands)
        parts[i] = fsdp
        return P(*parts)

    return jax.tree_util.tree_map(upgrade, params, specs)


def named(mesh: Mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda s: isinstance(s, P),
    )


# ---------------------------------------------------------------------------
# Batch / cache input specs
# ---------------------------------------------------------------------------


def _batch_axes_if(rules: ShardingRules, n: int):
    total = 1
    for a in rules.batch_axes:
        total *= rules.mesh.shape[a]
    return rules.batch_axes if (total > 1 and n % total == 0) else None


def batch_pspecs(batch_tree, rules: ShardingRules):
    """Specs for {tokens, labels, frontend, pos}: batch dim over data axes."""
    def one(leaf):
        bspec = _batch_axes_if(rules, leaf.shape[0])
        return P(bspec, *([None] * (leaf.ndim - 1)))
    return jax.tree_util.tree_map(one, batch_tree)


def cache_pspecs(cache_tree, rules: ShardingRules, stacked: bool = True):
    """Decode-cache specs: batch over data axes; KV sequence over the model
    axis (flash-decoding layout). stacked=True: leaves carry a leading
    (n_layer_groups,) dim (the scan stack); False: per-group caches."""
    tp = rules.model_axis
    lead = 1 if stacked else 0

    def one(path, leaf):
        keys = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
        name = keys[-1]
        if name == "pos":
            return P(_batch_axes_if(rules, leaf.shape[0]))
        if leaf.ndim < 1 + lead:
            return P(*([None] * leaf.ndim))
        bspec = _batch_axes_if(rules, leaf.shape[lead])
        pre = (None,) * lead
        if name in ("k", "v", "enc_k", "enc_v") and leaf.ndim == 4 + lead:
            seq = tp if (tp and _div(leaf.shape[lead + 1],
                                     rules.model_size)) else None
            return P(*pre, bspec, seq, None, None)
        if (name in ("k_scale", "v_scale")) and leaf.ndim == 3 + lead:
            seq = tp if (tp and _div(leaf.shape[lead + 1],
                                     rules.model_size)) else None
            return P(*pre, bspec, seq, None)
        return P(*pre, bspec, *([None] * (leaf.ndim - 1 - lead)))

    return jax.tree_util.tree_map_with_path(one, cache_tree)
