"""Checkpointing: sharded-agnostic, atomic, checksummed, async, elastic.

Layout (one directory per step):

    ckpt_dir/
      step_000123/
        arrays.npz            # flat {path -> np.ndarray}, full (unsharded)
        manifest.json         # step, keys, per-key sha256-prefix, data state
      step_000123.COMMITTED   # atomic marker written last
      latest                  # text file: last committed step

Design points for the 1000+-node posture:
* full (replicated-view) arrays — a reload under a *different* mesh/topology
  reshapes transparently (elastic scaling); device_put with the new sharding
  does the scatter.
* atomic commit marker -> a job killed mid-save never corrupts `latest`
  (restore scans for the newest COMMITTED step and verifies checksums).
* async: `save_async` snapshots to host (jax.device_get) synchronously —
  cheap — and writes in a background thread.
* multi-host: only process 0 writes (jax.process_index() == 0); all arrays
  are gathered via device_get on the addressable replica (single-host here).
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

SEP = "/"


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(
            str(getattr(k, "key", getattr(k, "name", getattr(k, "idx", k))))
            for k in path
        )
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten_into(template, flat: dict[str, np.ndarray]):
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths:
        key = SEP.join(
            str(getattr(k, "key", getattr(k, "name", getattr(k, "idx", k))))
            for k in path
        )
        arr = flat[key]
        assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def _checksum(a: np.ndarray) -> str:
    return hashlib.sha256(a.tobytes()).hexdigest()[:16]


class Checkpointer:
    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.dir = ckpt_dir
        self.keep = keep
        os.makedirs(ckpt_dir, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ----------------------------------------------------------------- save
    def save(self, step: int, state, extra: Optional[dict] = None,
             blocking: bool = True):
        host_state = jax.device_get(state)  # snapshot now; write later
        if blocking:
            self._write(step, host_state, extra or {})
        else:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, host_state, extra or {}),
                daemon=True,
            )
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, state, extra: dict):
        if jax.process_index() != 0:
            return
        name = f"step_{step:09d}"
        tmp = os.path.join(self.dir, name + ".tmp")
        final = os.path.join(self.dir, name)
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        flat = _flatten(state)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        manifest = {
            "step": step,
            "extra": extra,
            "checksums": {k: _checksum(v) for k, v in flat.items()},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)
        with open(os.path.join(self.dir, name + ".COMMITTED"), "w") as f:
            f.write(str(step))
        with open(os.path.join(self.dir, "latest.tmp"), "w") as f:
            f.write(name)
        os.replace(os.path.join(self.dir, "latest.tmp"),
                   os.path.join(self.dir, "latest"))
        self._gc()

    def _gc(self):
        steps = sorted(self.committed_steps())
        for s in steps[: -self.keep]:
            name = f"step_{s:09d}"
            shutil.rmtree(os.path.join(self.dir, name), ignore_errors=True)
            try:
                os.remove(os.path.join(self.dir, name + ".COMMITTED"))
            except FileNotFoundError:
                pass

    # -------------------------------------------------------------- restore
    def committed_steps(self) -> list[int]:
        out = []
        for fn in os.listdir(self.dir):
            if fn.endswith(".COMMITTED"):
                out.append(int(fn[len("step_"):-len(".COMMITTED")]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.committed_steps()
        return steps[-1] if steps else None

    def restore(self, template, step: Optional[int] = None,
                shardings=None) -> tuple[Any, int, dict]:
        """Returns (state, step, extra). Verifies checksums; falls back to
        the previous committed step on corruption."""
        steps = self.committed_steps()
        if step is not None:
            steps = [s for s in steps if s <= step]
        while steps:
            s = steps.pop()
            name = f"step_{s:09d}"
            try:
                with open(os.path.join(self.dir, name, "manifest.json")) as f:
                    manifest = json.load(f)
                with np.load(os.path.join(self.dir, name, "arrays.npz")) as z:
                    flat = {k: z[k] for k in z.files}
                for k, v in flat.items():
                    if _checksum(v) != manifest["checksums"][k]:
                        raise IOError(f"checksum mismatch at {k}")
                state = _unflatten_into(template, flat)
                if shardings is not None:
                    state = jax.device_put(state, shardings)
                return state, manifest["step"], manifest.get("extra", {})
            except Exception as e:  # corrupted -> try previous
                print(f"[ckpt] step {s} unusable ({e}); trying previous")
        raise FileNotFoundError(f"no usable checkpoint in {self.dir}")
