"""The jitted training step: loss -> grads -> (compress) -> AdamW update.

Microbatch gradient accumulation (sequential lax.scan over microbatches —
the standard memory/throughput knob) and donation of params/opt-state
buffers. Sharding comes from the in/out shardings the launcher attaches.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.pwconv import DEFAULT_POLICY, KernelPolicy
from repro.models import transformer as T
from repro.optim import adamw
from repro.optim.compress import CompressionConfig, compress


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: adamw.AdamWConfig = adamw.AdamWConfig()
    microbatches: int = 1
    compression: CompressionConfig = CompressionConfig()


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig,
                    policy: KernelPolicy = DEFAULT_POLICY):
    """Returns train_step(state, batch) -> (state, metrics).

    state = {params, opt, [err]}; batch = {tokens, labels [, frontend]}.
    """

    def loss_of(params, batch):
        return T.loss_fn(cfg, params, batch, policy=policy)

    grad_fn = jax.value_and_grad(loss_of, has_aux=True)

    def accumulate(params, batch):
        if tcfg.microbatches <= 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return loss, metrics, grads
        mb = tcfg.microbatches

        def split(x):
            b = x.shape[0]
            assert b % mb == 0, (b, mb)
            return x.reshape(mb, b // mb, *x.shape[1:])

        mbatches = jax.tree_util.tree_map(split, batch)
        zero_g = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )

        def body(carry, mbatch):
            loss_sum, grads = carry
            (loss, metrics), g = grad_fn(params, mbatch)
            grads = jax.tree_util.tree_map(
                lambda a, b: a + b.astype(jnp.float32), grads, g
            )
            return (loss_sum + loss, grads), metrics

        (loss_sum, grads), ms = jax.lax.scan(
            body, (jnp.float32(0.0), zero_g), mbatches
        )
        grads = jax.tree_util.tree_map(lambda g: g / mb, grads)
        metrics = jax.tree_util.tree_map(lambda m: m[-1], ms)
        return loss_sum / mb, metrics, grads

    def train_step(state, batch, rng=None):
        params, opt = state["params"], state["opt"]
        loss, metrics, grads = accumulate(params, batch)
        if tcfg.compression.kind != "none":
            grads, err = compress(grads, state["err"], tcfg.compression,
                                  key=rng)
        params, opt, opt_metrics = adamw.apply_updates(
            params, grads, opt, tcfg.optimizer
        )
        metrics = dict(metrics, **opt_metrics, loss=loss)
        new_state = {"params": params, "opt": opt}
        if tcfg.compression.kind != "none":
            new_state["err"] = err
        return new_state, metrics

    return train_step


def init_train_state(cfg: ModelConfig, tcfg: TrainConfig, key):
    params = T.init_params(cfg, key)
    state = {"params": params,
             "opt": adamw.init_state(params, tcfg.optimizer)}
    if tcfg.compression.kind != "none":
        from repro.optim.compress import init_error
        state["err"] = init_error(params)
    return state
