"""Fault-tolerant training loop.

Features exercised by tests (CPU) and designed for the 1000+-node posture:

* periodic async checkpoints; on ANY step failure (device error, injected
  fault, NaN loss) the trainer restores the latest committed checkpoint,
  rewinds the data iterator (bit-exact: the pipeline is a pure function of
  the step index) and continues — the final model is identical to an
  uninterrupted run (tested).
* straggler monitor: EMA of step wall-time; steps slower than
  `straggler_factor` x EMA are logged and counted (at scale this hooks
  the preemption/replacement controller; here it is a metric).
* NaN guard: a non-finite loss is treated as a failure (restore + skip the
  offending data step after `max_nan_retries` attempts on the same batch).
* multi-host entry: `jax.distributed.initialize` is called by the launcher
  (launch/train.py) when COORDINATOR_ADDRESS is set.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import numpy as np

from repro.data.pipeline import DataConfig, DataIterator
from repro.train.checkpoint import Checkpointer


@dataclasses.dataclass
class LoopConfig:
    total_steps: int
    ckpt_every: int = 50
    log_every: int = 10
    keep_ckpts: int = 3
    straggler_factor: float = 3.0
    max_nan_retries: int = 1


class FaultInjector:
    """Test hook: raise at given steps (once each)."""

    def __init__(self, fail_at: Optional[dict[int, str]] = None):
        self.fail_at = dict(fail_at or {})

    def check(self, step: int):
        if step in self.fail_at:
            kind = self.fail_at.pop(step)
            raise RuntimeError(f"injected fault ({kind}) at step {step}")


def train_loop(
    train_step: Callable,
    state,
    data_cfg: DataConfig,
    loop_cfg: LoopConfig,
    ckpt_dir: str,
    *,
    fault_injector: Optional[FaultInjector] = None,
    shardings=None,
    log: Callable[[str], None] = print,
):
    """Runs to loop_cfg.total_steps; returns (state, history)."""
    ckpt = Checkpointer(ckpt_dir, keep=loop_cfg.keep_ckpts)
    start = 0
    if ckpt.latest_step() is not None:
        state, start, extra = ckpt.restore(state, shardings=shardings)
        log(f"[trainer] resumed from step {start}")
    it = DataIterator(data_cfg, start_step=start, prefetch=2)

    history = []
    ema = None
    stragglers = 0
    nan_retries = 0
    step = start
    while step < loop_cfg.total_steps:
        batch = next(it)
        t0 = time.monotonic()
        try:
            if fault_injector is not None:
                fault_injector.check(step)
            new_state, metrics = train_step(state, batch)
            loss = float(jax.device_get(metrics["loss"]))
            if not np.isfinite(loss):
                raise FloatingPointError(f"non-finite loss at step {step}")
        except Exception as e:
            log(f"[trainer] step {step} failed: {e}; recovering")
            ckpt.wait()
            if ckpt.latest_step() is not None:
                state, rstep, _ = ckpt.restore(state, shardings=shardings)
            else:
                rstep = 0  # restart from initial state
            if isinstance(e, FloatingPointError):
                nan_retries += 1
                if nan_retries > loop_cfg.max_nan_retries:
                    rstep = max(rstep, step + 1)  # skip poisoned batch
                    nan_retries = 0
            it.close()
            it = DataIterator(data_cfg, start_step=rstep, prefetch=2)
            step = rstep
            continue

        dt = time.monotonic() - t0
        ema = dt if ema is None else 0.9 * ema + 0.1 * dt
        if dt > loop_cfg.straggler_factor * ema and step > start + 3:
            stragglers += 1
            log(f"[trainer] straggler: step {step} took {dt:.3f}s "
                f"(ema {ema:.3f}s)")
        state = new_state
        step += 1
        nan_retries = 0
        if step % loop_cfg.log_every == 0 or step == loop_cfg.total_steps:
            log(f"[trainer] step {step} loss {loss:.4f} "
                f"({dt*1e3:.0f} ms)")
        history.append({"step": step, "loss": loss, "time_s": dt})
        if step % loop_cfg.ckpt_every == 0 or step == loop_cfg.total_steps:
            ckpt.save(step, state, extra={"data": it.state()},
                      blocking=False)
    ckpt.wait()
    it.close()
    return state, {"history": history, "stragglers": stragglers}
