import os
import sys

# Tests run on the single host device (the 512-device override is ONLY for
# launch/dryrun.py). Make repo sources importable without install.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
