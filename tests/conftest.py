import os
import sys
import tempfile

# Tests run on the single host device (the 512-device override is ONLY for
# launch/dryrun.py). Make repo sources importable without install.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# The default KernelPolicy runs in degrade mode (DESIGN.md §9), which
# consults/writes the persistent plan quarantine — shield the developer's
# real ~/.cache store from the test run (tests that care pin their own
# path via KernelPolicy.tune_cache anyway).
os.environ.setdefault(
    "REPRO_QUARANTINE",
    os.path.join(tempfile.mkdtemp(prefix="repro-test-quarantine-"),
                 "quarantine.json"))

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)


# ---------------------------------------------------------------------------
# Optional-hypothesis shim: the tier-1 suite must collect and run without the
# `hypothesis` package. When it is absent we install a minimal stand-in that
# replays a small FIXED, deterministic example set per property test (seeded
# RNG, capped example count) instead of true property-based search. With real
# hypothesis installed this block is a no-op and full search applies.
# ---------------------------------------------------------------------------
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    import random as _random
    import types as _types

    _MAX_EXAMPLES = 5  # fixed-set fallback: keep deterministic and fast

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng):
            return self._draw(rng)

    def _integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def _sampled_from(elements):
        seq = list(elements)
        return _Strategy(lambda rng: rng.choice(seq))

    def _booleans():
        return _Strategy(lambda rng: rng.choice([False, True]))

    def _floats(min_value=0.0, max_value=1.0, **_kw):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    def _given(*_args, **strategies):
        if _args:
            raise TypeError(
                "hypothesis shim supports keyword strategies only")

        def decorate(fn):
            # *args/**kw signature on purpose: pytest must not see the
            # strategy names as fixture parameters (no functools.wraps —
            # __wrapped__ would expose the original signature).
            def wrapper(*args, **kw):
                n = getattr(wrapper, "_max_examples", _MAX_EXAMPLES)
                rng = _random.Random(0xC0FFEE)
                for _ in range(n):
                    ex = {k: s.example(rng) for k, s in strategies.items()}
                    fn(*args, **kw, **ex)

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper

        return decorate

    def _settings(max_examples=_MAX_EXAMPLES, deadline=None, **_kw):
        def decorate(fn):
            fn._max_examples = min(max_examples, _MAX_EXAMPLES)
            return fn

        return decorate

    _st = _types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.sampled_from = _sampled_from
    _st.booleans = _booleans
    _st.floats = _floats

    _hyp = _types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    _hyp.__is_repro_shim__ = True

    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
