"""Seeded-violation suite for the static plan/kernel verifier
(repro.analysis, DESIGN.md §8).

Every rule in the catalog gets at least one POSITIVE test (a deliberately
corrupted plan / model / jaxpr that must fire exactly that rule) and at
least one NEGATIVE test (the clean equivalent must not fire it) — the
analyzer is only trustworthy if it both catches seeded bugs and stays
silent on the real plans the planner emits.  Also covers the integration
hooks: the ``KernelPolicy(verify=True)`` knob, tune-cache drop-and-warn,
and network-cache entry validation.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import pallas as pl

from repro import analysis
from repro.analysis import jaxpr_audit, mosaic_check, planlint
from repro.analysis.diagnostics import ERROR, INFO, WARNING, Report
from repro.core import chain, network
from repro.kernels import autotune, blocking
from repro.kernels.gridspec import BlockRef, KernelModel
from repro.kernels.policy import KernelPolicy

PAL = KernelPolicy(impl="pallas", interpret=True)

#: Small geometries keep interpret-mode planning/tracing fast.
SEP_SHAPE = (1, 16, 16, 32)      # fused2: DW(32) -> PW(64)
IR_SHAPE = (1, 14, 14, 16)       # fused3: PW(64) -> DW -> PW(16) + residual
PW_SHAPE = (1, 8, 8, 256)        # standalone pointwise GEMM


def _sep():
    return chain.separable_block_spec(64, stride=1)


def _ir():
    return chain.inverted_residual_spec(16, 16, expand=4, stride=1)


def _pw_only():
    return chain.SeparableSpec(stages=(chain.PW(128, bias=True),))


def _with_plan(cp, si, **kw):
    """A copy of ``cp`` with segment ``si``'s BlockPlan fields replaced."""
    seg = cp.segments[si]
    new = dataclasses.replace(seg, plan=dataclasses.replace(seg.plan, **kw))
    return dataclasses.replace(
        cp, segments=cp.segments[:si] + (new,) + cp.segments[si + 1:])


def _rules(diags, severity=ERROR):
    return sorted({d.rule for d in diags if d.severity == severity})


# ---------------------------------------------------------------------------
# planlint PL101-PL113: plan-field checks
# ---------------------------------------------------------------------------

def test_clean_plans_have_no_errors():
    """Negative for every PL rule at once: the analytic planner's own
    answers must lint clean (fused2, fused3-with-residual, pw)."""
    for spec, shape in ((_sep(), SEP_SHAPE), (_ir(), IR_SHAPE),
                        (_pw_only(), PW_SHAPE)):
        cp = chain.plan(spec, shape)
        diags = planlint.lint_chain(spec, cp, shape)
        assert _rules(diags) == [], [d.format() for d in diags]


def test_pl101_claimed_vmem_over_budget():
    spec, shape = _sep(), SEP_SHAPE
    cp = chain.plan(spec, shape)
    assert cp.segments[0].plan.vmem_bytes > 1024
    bad = dataclasses.replace(cp, vmem_budget=1024)
    assert "PL101" in _rules(planlint.lint_chain(spec, bad, shape))
    assert "PL101" not in _rules(planlint.lint_chain(spec, cp, shape))


def test_pl102_vmem_claim_drift():
    spec, shape = _sep(), SEP_SHAPE
    cp = chain.plan(spec, shape)
    bad = _with_plan(cp, 0, vmem_bytes=123)
    rules = _rules(planlint.lint_chain(spec, bad, shape))
    assert rules == ["PL102"]  # coherent fields -> exactly the drift rule


def test_pl110_unsnapped_channel_block():
    spec, shape = _sep(), SEP_SHAPE
    cp = chain.plan(spec, shape)
    bad = _with_plan(cp, 0, block_c=100)  # snap_channels(100, 32) == 32
    assert "PL110" in _rules(planlint.lint_chain(spec, bad, shape))
    zero = _with_plan(cp, 0, block_c=0)
    assert "PL110" in _rules(planlint.lint_chain(spec, zero, shape))


def test_pl111_invalid_co_panel():
    spec, shape = _sep(), SEP_SHAPE
    cp = chain.plan(spec, shape)
    assert 100 not in blocking.co_candidates(64)
    bad = _with_plan(cp, 0, block_co=100)
    assert "PL111" in _rules(planlint.lint_chain(spec, bad, shape))


def test_pl112_inconsistent_slab_fields():
    spec, shape = _sep(), SEP_SHAPE
    cp = chain.plan(spec, shape)
    plan = cp.segments[0].plan
    bad = _with_plan(cp, 0, n_slabs=plan.n_slabs + 1)
    assert "PL112" in _rules(planlint.lint_chain(spec, bad, shape))
    overslab = _with_plan(cp, 0, slab_h=10_000)
    assert "PL112" in _rules(planlint.lint_chain(spec, overslab, shape))
    wrong_halo = _with_plan(cp, 0, slab_h=4, n_slabs=4, halo_rows=7)
    assert "PL112" in _rules(planlint.lint_chain(spec, wrong_halo, shape))


def test_pl113_misaligned_gemm_split():
    spec, shape = _pw_only(), PW_SHAPE
    cp = chain.plan(spec, shape)
    assert cp.segments[0].kind == "pw"
    # bci=100 splits the ci=256 reduction off the 128-lane tile
    bad = _with_plan(cp, 0, block_c=100)
    assert "PL113" in _rules(planlint.lint_chain(spec, bad, shape))
    degenerate = _with_plan(cp, 0, block_g=-8)
    assert "PL113" in _rules(planlint.lint_chain(spec, degenerate, shape))


# ---------------------------------------------------------------------------
# planlint PL103: derived VMEM vs ceiling/budget
# ---------------------------------------------------------------------------

def _dw_model(c=32, block_c=32, ho=8):
    from repro.kernels.dwconv2d import dw_kernel_model
    return dw_kernel_model(b=1, hiu=ho + 2, wiu=ho + 2, ho=ho, wo=ho, c=c,
                           block_c=block_c, hf=3, wf=3, itemsize=4,
                           out_itemsize=4)


def test_pl103_derived_vmem():
    small = _dw_model()
    assert planlint.check_vmem_derived(small,
                                       blocking.DEFAULT_VMEM_BUDGET) == []
    # 258x258x1024 fp32 double-buffered blows the 16 MiB physical ceiling
    huge = _dw_model(c=1024, block_c=1024, ho=256)
    diags = planlint.check_vmem_derived(huge, blocking.DEFAULT_VMEM_BUDGET)
    assert _rules(diags) == ["PL103"]
    # between soft budget and ceiling -> warning only
    mid = _dw_model(c=256, block_c=256, ho=50)
    assert blocking.DEFAULT_VMEM_BUDGET < mid.vmem_bytes() <= 16 * 2 ** 20
    diags = planlint.check_vmem_derived(mid, blocking.DEFAULT_VMEM_BUDGET)
    assert _rules(diags) == [] and _rules(diags, WARNING) == ["PL103"]


# ---------------------------------------------------------------------------
# planlint PL120-PL123: grid enumeration on a toy model
# ---------------------------------------------------------------------------

def _toy(out_map=lambda i, k: (i, 0), in_map=lambda i, k: (i, k),
         out_shape=((32, 8), (8, 8)), grid=(4, 2),
         sem=("parallel", "arbitrary")):
    x = BlockRef("x", (32, 16), (8, 8), in_map, 4)
    out = BlockRef("out", out_shape[0], out_shape[1], out_map, 4)
    return KernelModel("toy", grid, sem, (x,), out)


def test_grid_clean_toy_model():
    assert _rules(planlint.check_grid(_toy())) == []


def test_pl120_input_window_oob():
    bad = _toy(in_map=lambda i, k: (i + 1, k))  # last row block over-reads
    assert _rules(planlint.check_grid(bad)) == ["PL120"]


def test_pl120_unblocked_offset_oob():
    x = BlockRef("x", (33, 16), (9, 8), lambda i: (i * 8, 0), 4,
                 unblocked=True)
    out = BlockRef("out", (32, 16), (8, 16), lambda i: (i, 0), 4)
    clean = KernelModel("halo", (4,), ("parallel",), (x,), out)
    assert _rules(planlint.check_grid(clean)) == []
    # shift every halo window 2 rows down: the last reads [26, 35) of 33
    shifted = dataclasses.replace(
        clean, inputs=(dataclasses.replace(x, index_map=lambda i:
                                           (i * 8 + 2, 0)),))
    assert _rules(planlint.check_grid(shifted)) == ["PL120"]


def test_pl121_coverage_gap():
    bad = _toy(out_map=lambda i, k: (0, 0))  # every slab writes block 0
    rules = _rules(planlint.check_grid(bad))
    assert "PL121" in rules      # blocks (1..3, 0) never written
    assert "PL122" in rules      # and all parallel coords race on (0, 0)


def test_pl122_write_race_without_gap():
    # two parallel coords per output block, but full coverage
    bad = _toy(out_map=lambda i, k: (i // 2, 0), out_shape=((16, 8), (8, 8)))
    assert _rules(planlint.check_grid(bad)) == ["PL122"]


def test_pl123_output_depends_on_reduction_dim():
    bad = _toy(out_map=lambda i, k: (i, k), out_shape=((32, 16), (8, 8)))
    assert "PL123" in _rules(planlint.check_grid(bad))


def test_grid_sampling_on_huge_grids():
    """Above MAX_GRID_POINTS the check degrades to boundary samples and
    says so (INFO PL121) instead of silently passing."""
    big = _toy(out_map=lambda i, k: (i, 0),
               out_shape=((8 * 600, 8), (8, 8)), grid=(600, 600),
               sem=("parallel", "arbitrary"))
    big = dataclasses.replace(
        big, inputs=(BlockRef("x", (8 * 600, 8 * 600), (8, 8),
                              lambda i, k: (i, k), 4),))
    diags = planlint.check_grid(big)
    assert _rules(diags) == []
    assert [d.rule for d in diags if d.severity == INFO] == ["PL121"]


def test_real_fused_model_grid_proofs():
    """The derived fused3 model (overlapping halo windows, RTRD reduction)
    passes the full grid proof — the negative for PL120-123 on the real
    index maps, not the toy."""
    spec, shape = _ir(), IR_SHAPE
    cp = chain.plan(spec, shape)
    (label, geom, model), = planlint.chain_models(spec, cp, shape)
    assert model is not None and geom.kind == "fused3"
    assert _rules(planlint.check_grid(model)) == []


# ---------------------------------------------------------------------------
# mosaic_check MC201-MC205
# ---------------------------------------------------------------------------

def _ref(array, block, itemsize=4, name="x"):
    return BlockRef(name, array, block, lambda *i: tuple(0 for _ in array),
                    itemsize)


def test_mc201_lane_misaligned_block():
    warn = mosaic_check._check_block_alignment(
        _ref((64, 256), (8, 64)), "s")
    assert [d.rule for d in warn if d.severity == WARNING] == ["MC201"]
    # taking ALL of a small minor dim is the planner's documented fallback
    info = mosaic_check._check_block_alignment(_ref((64, 64), (8, 64)), "s")
    assert [d.rule for d in info if d.severity == INFO] == ["MC201"]
    assert mosaic_check._check_block_alignment(
        _ref((64, 256), (8, 128)), "s") == []


def test_mc202_sublane_misaligned_block():
    diags = mosaic_check._check_block_alignment(_ref((64, 128), (7, 128)),
                                                "s")
    assert [d.rule for d in diags] == ["MC202"]
    assert mosaic_check._check_block_alignment(
        _ref((64, 128), (8, 128)), "s") == []
    # bf16 needs 16 sublanes: 8 is now misaligned
    diags = mosaic_check._check_block_alignment(
        _ref((64, 128), (8, 128), itemsize=2), "s")
    assert [d.rule for d in diags] == ["MC202"]


def test_mc203_collapsing_reshape():
    # (14, 14, 512) -> (196, 512): second-minor 14 off the 8-sublane tile
    diags = mosaic_check.check_reshapes([((14, 14, 512), (196, 512))], 4)
    assert [d.rule for d in diags] == ["MC203"]
    # minor-dim change is a relayout regardless of alignment
    diags = mosaic_check.check_reshapes([((8, 16, 32), (8, 512))], 4)
    assert [d.rule for d in diags] == ["MC203"]
    # aligned collapse is clean
    assert mosaic_check.check_reshapes([((16, 128, 512),
                                         (2048, 512))], 4) == []


def _unblocked_model(index_map):
    x = BlockRef("x", (64, 128), (8, 128), index_map, 4, unblocked=True)
    out = BlockRef("o", (64, 128), (8, 128), lambda i: (i, 0), 4)
    return KernelModel("toy", (8,), ("parallel",), (x,), out)


def test_mc204_unblocked_offsets():
    aligned = mosaic_check.check_unblocked(
        _unblocked_model(lambda i: (i * 8, 0)))
    assert [d.severity for d in aligned] == [INFO]  # surfaced, not flagged
    skewed = mosaic_check.check_unblocked(
        _unblocked_model(lambda i: (i * 8 + 1, 0)))
    assert [d.severity for d in skewed] == [INFO, WARNING]
    assert all(d.rule == "MC204" for d in skewed)


def test_mc205_reduction_dim_not_innermost():
    m = _toy(sem=("arbitrary", "parallel"))
    assert _rules(mosaic_check.check_semantics(m)) == ["MC205"]
    assert mosaic_check.check_semantics(
        _toy(sem=("parallel", "arbitrary"))) == []


def test_real_models_mosaic_clean():
    """Negative at the model level: no MC errors on real derived models."""
    for spec, shape in ((_sep(), SEP_SHAPE), (_ir(), IR_SHAPE)):
        cp = chain.plan(spec, shape)
        for label, _geom, model in planlint.chain_models(spec, cp, shape):
            assert _rules(mosaic_check.lint_model(model, label)) == []


# ---------------------------------------------------------------------------
# jaxpr_audit JX301/JX302/JX310/JX311
# ---------------------------------------------------------------------------

def test_jx301_pass_count():
    spec, shape = _ir(), IR_SHAPE
    cp = chain.plan(spec, shape, policy=PAL)
    jaxpr = jaxpr_audit.trace_chain(spec, cp, shape, jnp.float32, PAL)
    ok = jaxpr_audit.audit_passes(jaxpr, len(cp.segments), cp.fully_fused)
    assert ok == []
    bad = jaxpr_audit.audit_passes(jaxpr, len(cp.segments) + 1,
                                   cp.fully_fused)
    assert _rules(bad) == ["JX301"]


def test_jx302_hbm_intermediate_on_fused_chain():
    spec, shape = _ir(), IR_SHAPE
    cp = chain.plan(spec, shape, policy=PAL)
    assert cp.fully_fused
    run = chain.lower(spec, cp, PAL)
    params = jaxpr_audit.param_structs(spec, shape[-1], jnp.float32)
    x = jax.ShapeDtypeStruct(shape, jnp.float32)
    # a compute op outside the kernel materializes an HBM intermediate
    leaky = jax.make_jaxpr(lambda p, a: jnp.tanh(run(p, a)))(params, x)
    diags = jaxpr_audit.audit_passes(leaky, len(cp.segments), True)
    assert _rules(diags) == ["JX302"]
    # the same trace is fine when the plan never claimed full fusion
    assert jaxpr_audit.audit_passes(leaky, len(cp.segments), False) == []


def test_jx310_rogue_cast():
    jaxpr = jax.make_jaxpr(
        lambda a: a.astype(jnp.float16).astype(jnp.float32))(
            jax.ShapeDtypeStruct((4, 4), jnp.float32))
    diags = jaxpr_audit.audit_casts(jaxpr, {"float32"})
    assert _rules(diags) == ["JX310"]
    assert "float16" in diags[0].message
    assert jaxpr_audit.audit_casts(jaxpr, {"float16", "float32"}) == []


def _matmul_jaxpr(pref):
    def kernel(x_ref, y_ref, o_ref):
        o_ref[...] = jax.lax.dot_general(
            x_ref[...], y_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=pref).astype(jnp.float32)
    fn = pl.pallas_call(
        kernel, out_shape=jax.ShapeDtypeStruct((8, 8), jnp.float32),
        interpret=True)
    s = jax.ShapeDtypeStruct((8, 8), jnp.float32)
    return jax.make_jaxpr(fn)(s, s)


def test_jx311_accumulation_width():
    bad = jaxpr_audit.audit_accumulation(_matmul_jaxpr(jnp.bfloat16))
    assert _rules(bad) == ["JX311"]
    assert jaxpr_audit.audit_accumulation(_matmul_jaxpr(jnp.float32)) == []


def test_real_chain_jaxpr_audit_clean():
    for spec, shape in ((_sep(), SEP_SHAPE), (_ir(), IR_SHAPE)):
        cp = chain.plan(spec, shape, policy=PAL)
        diags = jaxpr_audit.lint_chain_jaxpr(spec, cp, shape,
                                             dtype=jnp.float32, policy=PAL)
        assert _rules(diags) == [], [d.format() for d in diags]


# ---------------------------------------------------------------------------
# report plumbing + top-level entry points
# ---------------------------------------------------------------------------

def test_report_serialization():
    spec, shape = _sep(), SEP_SHAPE
    cp = chain.plan(spec, shape)
    r = analysis.analyze_chain(spec, cp, shape, policy=PAL, jaxpr=True)
    assert r.ok
    d = r.to_json()
    assert d["ok"] and set(d) == {"ok", "summary", "diagnostics"}
    assert all(set(x) == {"rule", "severity", "message", "segment",
                          "geometry", "hint"} for x in d["diagnostics"])
    assert "0 error(s)" in r.summary()


def test_verify_or_raise():
    spec, shape = _sep(), SEP_SHAPE
    cp = chain.plan(spec, shape)
    analysis.verify_or_raise(
        analysis.analyze_chain(spec, cp, shape, jaxpr=False))
    bad = _with_plan(cp, 0, vmem_bytes=123)
    with pytest.raises(analysis.PlanVerificationError, match="PL102"):
        analysis.verify_or_raise(
            analysis.analyze_chain(spec, bad, shape, jaxpr=False))


def test_lint_cached_plan():
    spec, shape = _sep(), SEP_SHAPE
    cp = chain.plan(spec, shape)
    assert analysis.lint_cached_plan(spec, cp, shape) is None
    assert analysis.lint_cached_plan(
        spec, _with_plan(cp, 0, vmem_bytes=123), shape) == "PL102"


# ---------------------------------------------------------------------------
# integration: policy.verify knob, tune-cache drop, network-cache validation
# ---------------------------------------------------------------------------

def test_policy_verify_knob():
    spec = _sep()
    params = chain.init_chain(jax.random.PRNGKey(0), spec, SEP_SHAPE[-1])
    x = jnp.asarray(np.random.default_rng(0).normal(
        size=SEP_SHAPE).astype(np.float32))
    verified = chain.execute(spec, params, x,
                             policy=dataclasses.replace(PAL, verify=True))
    plain = chain.execute(spec, params, x, policy=PAL)
    np.testing.assert_allclose(np.asarray(verified), np.asarray(plain))

    bad = _with_plan(chain.plan(spec, x.shape, policy=PAL), 0,
                     vmem_bytes=123)
    with pytest.raises(analysis.PlanVerificationError, match="PL102"):
        chain.execute(spec, params, x,
                      policy=dataclasses.replace(PAL, verify=True),
                      chain_plan=bad)
    # without the knob the corrupted claim executes (values stay right:
    # vmem_bytes is a claim, not an input to the lowering)
    out = chain.execute(spec, params, x, policy=PAL, chain_plan=bad)
    np.testing.assert_allclose(np.asarray(out), np.asarray(plain))


def test_tune_cache_entry_dropped_with_warning(tmp_path):
    spec, x_shape = _sep(), SEP_SHAPE
    pol = dataclasses.replace(PAL, autotune=True,
                              tune_cache=str(tmp_path / "tune.json"))
    good = chain.plan(spec, x_shape,
                      policy=dataclasses.replace(pol, autotune=False))
    key = autotune.problem_key(spec, x_shape, jnp.float32, pol)
    cache = autotune.TuneCache(pol.tune_cache)
    cache.put(key, {"plan": autotune.serialize_chain_plan(
        _with_plan(good, 0, vmem_bytes=123))})
    cache.save()
    with pytest.warns(UserWarning, match=r"planlint \(PL102\)"):
        got = autotune.lookup_cached_plan(spec, x_shape, jnp.float32, pol)
    assert got is None  # caller falls back to the analytic planner

    cache.put(key, {"plan": autotune.serialize_chain_plan(good)})
    cache.save()
    got = autotune.lookup_cached_plan(spec, x_shape, jnp.float32, pol)
    assert got == good  # clean entries replay untouched, no warning


def _tiny_net():
    return network.NetworkSpec(name="tiny", c_in=8, blocks=(
        chain.separable_block_spec(16, stride=1),
        chain.inverted_residual_spec(16, 16, expand=2, stride=1),
    ))


def test_network_cache_entry_validation():
    net = _tiny_net()
    nplan = network.plan_network(net, (1, 8, 8, 8), policy=PAL)
    assert network._validate_network_entry(net, nplan, PAL)
    bad = dataclasses.replace(
        nplan, plans=(_with_plan(nplan.plans[0], 0, vmem_bytes=123),)
        + nplan.plans[1:])
    with pytest.warns(UserWarning, match=r"block 0 failed planlint"):
        assert not network._validate_network_entry(net, bad, PAL)


def test_network_verify_knob():
    net = _tiny_net()
    nplan = network.plan_network(
        net, (1, 8, 8, 8), policy=dataclasses.replace(PAL, verify=True))
    assert analysis.analyze_network(net, nplan, policy=PAL,
                                    jaxpr=False).ok
