"""Blockwise (flash) attention vs dense oracle: forward, VJP, decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models.attention import (
    attention,
    attention_decode,
    blockwise_attention,
    dense_attention,
    init_attention,
)

RNG = np.random.default_rng(0)


def _qkv(b=2, s=96, hq=6, hkv=2, dh=16, sk=None):
    sk = sk or s
    q = jnp.asarray(RNG.normal(size=(b, s, hq, dh)).astype(np.float32))
    k = jnp.asarray(RNG.normal(size=(b, sk, hkv, dh)).astype(np.float32))
    v = jnp.asarray(RNG.normal(size=(b, sk, hkv, dh)).astype(np.float32))
    return q, k, v


CASES = [
    dict(causal=True),
    dict(causal=True, window=17),
    dict(causal=True, window=17, sink=5),
    dict(causal=False),
]


@pytest.mark.parametrize("kwargs", CASES)
@pytest.mark.parametrize("chunk", [32, 96])
def test_blockwise_matches_dense_fwd(kwargs, chunk):
    q, k, v = _qkv()
    a = dense_attention(q, k, v, **kwargs)
    b_ = blockwise_attention(q, k, v, chunk=chunk, **kwargs)
    np.testing.assert_allclose(a, b_, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("kwargs", CASES)
def test_blockwise_matches_dense_grad(kwargs):
    q, k, v = _qkv(s=80)

    def fd(q, k, v):
        return jnp.sum(jnp.sin(dense_attention(q, k, v, **kwargs)))

    def fb(q, k, v):
        return jnp.sum(jnp.sin(
            blockwise_attention(q, k, v, chunk=32, **kwargs)))

    gd = jax.grad(fd, argnums=(0, 1, 2))(q, k, v)
    gb = jax.grad(fb, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gd, gb):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


def test_cross_attention_rectangular():
    q, _, _ = _qkv(s=70)
    _, k, v = _qkv(s=70, sk=45)
    a = dense_attention(q, k, v, causal=False)
    b_ = blockwise_attention(q, k, v, causal=False, chunk=32)
    np.testing.assert_allclose(a, b_, rtol=2e-5, atol=2e-5)


def test_decode_matches_full_forward():
    b, s, hq, hkv, dh, d = 2, 10, 4, 2, 8, 32
    p = init_attention(jax.random.PRNGKey(0), d, hq, hkv, dh)
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, d)) * 0.3
    full = attention(p, x, n_heads=hq, n_kv_heads=hkv, head_dim=dh)
    ck = jnp.zeros((b, 16, hkv, dh))
    cv = jnp.zeros((b, 16, hkv, dh))
    outs = []
    for t in range(s):
        pos = jnp.full((b,), t, jnp.int32)
        o, ck, cv = attention_decode(p, x[:, t:t + 1], ck, cv, pos,
                                     n_heads=hq, n_kv_heads=hkv, head_dim=dh)
        outs.append(o)
    np.testing.assert_allclose(jnp.concatenate(outs, 1), full, rtol=1e-4,
                               atol=1e-4)


def test_decode_ring_cache_swa():
    """Ring-buffer (sink+window) decode == dense SWA attention."""
    b, s, hq, hkv, dh, d = 1, 30, 2, 1, 8, 16
    window, sink = 8, 4
    p = init_attention(jax.random.PRNGKey(0), d, hq, hkv, dh)
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, d)) * 0.3
    full = attention(p, x, n_heads=hq, n_kv_heads=hkv, head_dim=dh,
                     window=window, sink=sink, chunk=1024)
    s_c = window + sink
    ck = jnp.zeros((b, s_c, hkv, dh))
    cv = jnp.zeros((b, s_c, hkv, dh))
    outs = []
    for t in range(s):
        pos = jnp.full((b,), t, jnp.int32)
        o, ck, cv = attention_decode(
            p, x[:, t:t + 1], ck, cv, pos, n_heads=hq, n_kv_heads=hkv,
            head_dim=dh, window=window, sink=sink, ring=True)
        outs.append(o)
    np.testing.assert_allclose(jnp.concatenate(outs, 1), full, rtol=1e-4,
                               atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(
    s=st.integers(3, 60),
    hkv=st.sampled_from([1, 2, 3]),
    g=st.sampled_from([1, 2, 4]),
    chunk=st.sampled_from([16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_blockwise_property(s, hkv, g, chunk, seed):
    r = np.random.default_rng(seed)
    q = jnp.asarray(r.normal(size=(1, s, hkv * g, 8)).astype(np.float32))
    k = jnp.asarray(r.normal(size=(1, s, hkv, 8)).astype(np.float32))
    v = jnp.asarray(r.normal(size=(1, s, hkv, 8)).astype(np.float32))
    a = dense_attention(q, k, v, causal=True)
    b_ = blockwise_attention(q, k, v, causal=True, chunk=chunk)
    np.testing.assert_allclose(a, b_, rtol=3e-5, atol=3e-5)


def test_softmax_rows_sum_to_one_property():
    """Online-softmax invariant: attention output of v=1s is 1s."""
    q, k, _ = _qkv(s=64)
    v = jnp.ones((2, 64, 2, 16), jnp.float32)
    out = blockwise_attention(q, k, v, causal=True, chunk=16)
    np.testing.assert_allclose(out, jnp.ones_like(out), rtol=1e-5,
                               atol=1e-5)
