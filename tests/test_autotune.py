"""The measured ChainPlan autotuner (kernels/autotune.py): cache
round-trip (tune -> write -> reload -> hit with zero re-measurement,
bitwise-identical replay), measured-winner parity with the analytic plan
(fp32 + bf16), cache-key sensitivity (shape / dtype / budget / backend),
corrupted-cache-file recovery, and the plan-fidelity guarantees the tuner
relies on (the lowering executes plans verbatim)."""
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import chain
from repro.kernels import autotune, blocking
from repro.kernels.policy import DtypePolicy, KernelPolicy

RNG = np.random.default_rng(7)

#: Tiny geometry keeps interpret-mode Pallas measurement in seconds.
CI_, CO_, EXPAND, RES = 8, 8, 4, 8


def _problem(dtype=np.float32, res=RES, ci=CI_, co=CO_):
    spec = chain.inverted_residual_spec(ci, co, expand=EXPAND, stride=1)
    params = chain.init_chain(jax.random.PRNGKey(3), spec, ci)
    if dtype != np.float32:
        params = jax.tree_util.tree_map(lambda a: a.astype(dtype), params)
    x = jnp.asarray(RNG.normal(size=(1, res, res, ci)).astype(np.float32))
    return spec, params, x.astype(dtype)


def _policy(tmp_path, **kw):
    kw.setdefault("impl", "pallas")
    kw.setdefault("interpret", True)
    kw.setdefault("autotune", True)
    kw.setdefault("tune_cache", str(tmp_path / "tune.json"))
    return KernelPolicy(**kw)


# ---------------------------------------------------------------------------
# cache round-trip
# ---------------------------------------------------------------------------

def test_tune_write_reload_hit_no_remeasure(tmp_path, monkeypatch):
    """First execute measures and persists; a fresh cache load replays the
    winner with ZERO measurement and bitwise-identical output."""
    spec, params, x = _problem()
    pol = _policy(tmp_path)
    y1 = chain.execute(spec, params, x, policy=pol)
    assert os.path.exists(pol.tune_cache)
    raw = json.load(open(pol.tune_cache))
    assert raw["version"] == autotune.CACHE_VERSION
    (entry,) = raw["entries"].values()
    assert entry["n_measured"] >= 1
    assert entry["measured_us"] > 0

    # simulate the second process: any measurement now is a bug
    def _boom(*a, **k):
        raise AssertionError("cache hit must not re-measure")
    monkeypatch.setattr(autotune, "measure_run", _boom)
    base = chain.plan(spec, x.shape, dtype=x.dtype,
                      policy=dataclasses.replace(pol, autotune=False))
    r = autotune.autotune_chain(spec, params, x, policy=pol, base_plan=base)
    assert r.cache_hit and r.n_measured == 0
    y2 = chain.execute(spec, params, x, policy=pol)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))


def test_plan_consults_cache(tmp_path):
    """core/chain.plan with autotune returns the cached measured plan; on a
    miss (or with tuning disabled) it answers analytically."""
    spec, params, x = _problem()
    pol = _policy(tmp_path)
    analytic = chain.plan(spec, x.shape, dtype=x.dtype,
                          policy=dataclasses.replace(pol, autotune=False))
    # miss: plan() must still answer (analytically)
    assert chain.plan(spec, x.shape, dtype=x.dtype, policy=pol) == analytic
    r = autotune.autotune_chain(spec, params, x, policy=pol,
                                base_plan=analytic)
    assert not r.cache_hit
    got = chain.plan(spec, x.shape, dtype=x.dtype, policy=pol)
    assert got == r.plan


def test_chain_plan_serialization_round_trip():
    spec = chain.inverted_residual_spec(16, 16, expand=6, stride=1)
    cp = chain.plan(spec, (1, 14, 14, 16))
    d = autotune.serialize_chain_plan(cp)
    json.dumps(d)  # must be pure-JSON serializable
    assert autotune.deserialize_chain_plan(d) == cp
    cp_u = chain.plan(spec, (1, 14, 14, 16), policy=KernelPolicy(fused=False))
    assert autotune.deserialize_chain_plan(
        autotune.serialize_chain_plan(cp_u)) == cp_u


# ---------------------------------------------------------------------------
# measured winner parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_measured_plan_parity_with_analytic(tmp_path, dtype):
    """Whatever candidate wins the measurement, its output matches the
    analytic plan's (every candidate is a feasibility-checked blocking of
    the SAME computation)."""
    spec, params, x = _problem(dtype=dtype)
    pol = _policy(tmp_path)
    y_tuned = chain.execute(spec, params, x, policy=pol)
    y_analytic = chain.execute(
        spec, params, x, policy=dataclasses.replace(pol, autotune=False))
    tol = 1e-5 if dtype == np.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(y_tuned, np.float32),
                               np.asarray(y_analytic, np.float32),
                               rtol=tol, atol=tol)


def test_multi_segment_chain_tunes_and_matches(tmp_path):
    """Coordinate descent over a pw+dw+pw chain (fused=False): every
    segment contributes candidates, output parity holds."""
    spec, params, x = _problem()
    pol = _policy(tmp_path, fused=False)
    y = chain.execute(spec, params, x, policy=pol)
    y_ref = chain.execute(
        spec, params, x, policy=dataclasses.replace(pol, autotune=False))
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)
    (entry,) = json.load(open(pol.tune_cache))["entries"].values()
    assert [s["kind"] for s in entry["plan"]["segments"]] == [
        "pw", "dw", "pw"]
    assert entry["n_measured"] > autotune.MAX_SEGMENT_CANDIDATES


# ---------------------------------------------------------------------------
# cache-key sensitivity
# ---------------------------------------------------------------------------

def test_problem_key_changes_with_shape_dtype_budget():
    spec, _, _ = _problem()
    pol = KernelPolicy(impl="pallas", interpret=True, autotune=True)
    base = autotune.problem_key(spec, (1, 8, 8, 8), jnp.float32, pol)
    assert autotune.problem_key(spec, (1, 8, 8, 8), jnp.float32, pol) == base
    assert autotune.problem_key(spec, (1, 16, 16, 8), jnp.float32,
                                pol) != base
    assert autotune.problem_key(spec, (2, 8, 8, 8), jnp.float32, pol) != base
    assert autotune.problem_key(spec, (1, 8, 8, 8), jnp.bfloat16,
                                pol) != base
    small = dataclasses.replace(pol, vmem_budget=1 << 20)
    assert autotune.problem_key(spec, (1, 8, 8, 8), jnp.float32,
                                small) != base
    xla = dataclasses.replace(pol, impl="xla")
    assert autotune.problem_key(spec, (1, 8, 8, 8), jnp.float32,
                                xla) != base
    other_spec = chain.inverted_residual_spec(CI_, CO_, expand=EXPAND,
                                              stride=2)
    assert autotune.problem_key(other_spec, (1, 8, 8, 8), jnp.float32,
                                pol) != base


def test_problem_key_changes_with_dtype_policy():
    """The dtype POLICY is part of the precision identity, not just the
    input dtype: a bf16-streamed measured plan (budgeted at 2 B/elt) must
    never replay onto a native fp32 run of the same problem (DESIGN.md §7)."""
    spec, _, _ = _problem()
    pol = KernelPolicy(impl="pallas", interpret=True, autotune=True)
    base = autotune.problem_key(spec, (1, 8, 8, 8), jnp.float32, pol)
    bf = dataclasses.replace(
        pol, dtype_policy=DtypePolicy(stream="bfloat16"))
    key_bf = autotune.problem_key(spec, (1, 8, 8, 8), jnp.float32, bf)
    assert key_bf != base
    # the out pin is a distinct problem too (different final kernel store)
    bf_out32 = dataclasses.replace(
        pol, dtype_policy=DtypePolicy(stream="bfloat16", out="float32"))
    key_out = autotune.problem_key(spec, (1, 8, 8, 8), jnp.float32, bf_out32)
    assert key_out not in (base, key_bf)
    # explicitly-native policy == default policy (both stream at input dtype)
    native = dataclasses.replace(pol, dtype_policy=DtypePolicy())
    assert autotune.problem_key(spec, (1, 8, 8, 8), jnp.float32,
                                native) == base


def test_bf16_streamed_entry_does_not_replay_on_native(tmp_path):
    """End-to-end key isolation: tune under the bf16 streaming policy, then
    a NATIVE-policy lookup of the same problem must miss."""
    spec, params, x = _problem()
    pol_bf = _policy(tmp_path,
                     dtype_policy=DtypePolicy(stream="bfloat16"))
    chain.execute(spec, params, x, policy=pol_bf)
    raw = json.load(open(pol_bf.tune_cache))
    (entry,) = raw["entries"].values()
    assert entry["signature"]["dtype_policy"] == {"stream": "bfloat16",
                                                  "out": None}
    # budgeted at the stream width: the persisted plan says 2 bytes/elt
    assert entry["plan"]["dtype_bytes"] == 2
    pol_native = _policy(tmp_path)
    assert autotune.lookup_cached_plan(spec, x.shape, x.dtype,
                                       pol_native) is None


def test_distinct_problems_get_distinct_entries(tmp_path):
    """Two shapes tune into the same file without clobbering each other."""
    spec, params, x8 = _problem()
    _, _, x12 = _problem(res=12)
    pol = _policy(tmp_path)
    chain.execute(spec, params, x8, policy=pol)
    chain.execute(spec, params, x12, policy=pol)
    raw = json.load(open(pol.tune_cache))
    assert len(raw["entries"]) == 2


# ---------------------------------------------------------------------------
# corrupted-cache recovery
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("garbage", [
    "not json at all {{{",
    '{"version": 999, "entries": "nope"}',
    '[]',
    '',
])
def test_corrupted_cache_file_recovers(tmp_path, garbage):
    """A trashed cache file must neither crash nor poison the result: the
    tuner falls back to measuring from the analytic plan and REWRITES a
    valid cache."""
    spec, params, x = _problem()
    pol = _policy(tmp_path)
    with open(pol.tune_cache, "w") as f:
        f.write(garbage)
    y = chain.execute(spec, params, x, policy=pol)
    y_ref = chain.execute(
        spec, params, x, policy=dataclasses.replace(pol, autotune=False))
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)
    raw = json.load(open(pol.tune_cache))  # rewritten, valid again
    assert raw["version"] == autotune.CACHE_VERSION and raw["entries"]


def test_corrupted_entry_retunes(tmp_path):
    """A structurally-valid file with an undecodable entry re-tunes that
    key instead of crashing."""
    spec, params, x = _problem()
    pol = _policy(tmp_path)
    key = autotune.problem_key(spec, x.shape, x.dtype, pol)
    cache = autotune.TuneCache(pol.tune_cache)
    cache.put(key, {"plan": {"segments": "garbage"}})
    cache.save()
    y = chain.execute(spec, params, x, policy=pol)
    assert y.shape == (1, RES, RES, CO_)
    (entry,) = json.load(open(pol.tune_cache))["entries"].values()
    assert entry["n_measured"] >= 1  # re-measured and overwrote


def test_lookup_cached_plan_miss_returns_none(tmp_path):
    spec, _, x = _problem()
    pol = _policy(tmp_path)
    assert autotune.lookup_cached_plan(spec, x.shape, x.dtype, pol) is None


# ---------------------------------------------------------------------------
# candidate ladder
# ---------------------------------------------------------------------------

def test_segment_candidates_feasible_and_capped():
    spec = chain.inverted_residual_spec(16, 24, expand=6, stride=2)
    cp = chain.plan(spec, (1, 28, 28, 16))
    (geom,) = autotune._segment_geoms(spec.stages, cp, (1, 28, 28, 16))
    cands = autotune.segment_candidates(
        geom, cp.segments[0].plan, jnp.float32, blocking.DEFAULT_VMEM_BUDGET)
    assert 1 < len(cands) <= autotune.MAX_SEGMENT_CANDIDATES
    assert cands[0] == cp.segments[0].plan           # analytic plan first
    assert len(set(cands)) == len(cands)             # deduplicated
    for p in cands:
        assert p.vmem_bytes <= blocking.DEFAULT_VMEM_BUDGET


def test_plan_separable_at_matches_ladder_corner():
    """The explicit-point probe agrees with the analytic walk at the point
    the walk selects."""
    p = blocking.plan_separable(56, 56, 144, 32, stride=2)
    q = blocking.plan_separable_at(56, 56, 144, 32, block_co=p.block_co,
                                   slab_h=p.slab_h, stride=2)
    assert q == p
    p3 = blocking.plan_separable3(28, 28, 32, 192, 64, stride=1)
    q3 = blocking.plan_separable3_at(28, 28, 32, 192, 64,
                                     block_co=p3.block_co,
                                     slab_h=p3.slab_h, stride=1)
    assert q3 == p3
    # infeasible explicit point answers None, never a bogus plan
    assert blocking.plan_separable_at(56, 56, 144, 32, block_co=32,
                                      slab_h=56, stride=2,
                                      vmem_budget=1024) is None
