"""Unit tests for the unified dtype-aware block planner
(src/repro/kernels/blocking.py) — the single owner of VMEM budgeting,
channel/Co-panel enumeration and row-slab blocking that replaced the
per-kernel choosers (``dwconv2d._block_c``, ``separable_fused._snap`` /
``_co_candidates`` / ``_block_sizes``, ``pwconv``'s fixed grid)."""
import jax.numpy as jnp
import pytest

from repro.kernels import blocking


# ---------------------------------------------------------------------------
# candidate enumerators
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("co", [1, 7, 33, 64, 127, 128, 129, 192, 256, 320,
                                1000, 1024, 3000])
def test_co_candidates_strictly_descending_deduplicated(co):
    """The migration fix: the old ``_co_candidates`` could interleave
    128-multiples with powers of two; the planner's enumerator must be
    strictly descending with no duplicates, start at Co (the single-panel,
    traffic-optimal case) and end at a feasible (<= Co) block."""
    cands = blocking.co_candidates(co)
    assert cands[0] == co
    assert all(a > b for a, b in zip(cands, cands[1:])), cands
    assert len(set(cands)) == len(cands)
    assert all(1 <= x <= co for x in cands)
    assert cands[-1] == 1 or co == 1


@pytest.mark.parametrize("ho", [1, 2, 7, 8, 56, 112, 1504])
def test_slab_candidates_strictly_descending(ho):
    cands = blocking.slab_candidates(ho)
    assert cands[0] == ho          # whole image first: no halo
    assert all(a > b for a, b in zip(cands, cands[1:])), cands
    assert cands[-1] == 1


def test_snap_channels_preference_order():
    """All of C, else multiple of 128 lanes, else power of two."""
    assert blocking.snap_channels(600, 512) == 512        # all of C
    assert blocking.snap_channels(300, 512) == 256        # 128-multiple
    assert blocking.snap_channels(100, 512) == 64         # pow2 fallback
    assert blocking.snap_channels(1, 512) == 1            # floor


# ---------------------------------------------------------------------------
# dwconv2d plan (replaces dwconv2d._block_c)
# ---------------------------------------------------------------------------

def test_plan_dwconv2d_full_c_when_it_fits():
    assert blocking.plan_dwconv2d(14, 14, 12, 12, 512).block_c == 512


def test_plan_dwconv2d_tiny_vmem_fallback():
    cb = blocking.plan_dwconv2d(14, 14, 12, 12, 512,
                                vmem_budget=16 * 1024).block_c
    assert 1 <= cb < 128 and (cb & (cb - 1)) == 0
    assert blocking.plan_dwconv2d(64, 64, 62, 62, 512,
                                  vmem_budget=1).block_c == 1


def test_plan_dwconv2d_128_multiple_snapping():
    cb = blocking.plan_dwconv2d(28, 28, 26, 26, 1024,
                                vmem_budget=2 * 1024 * 1024).block_c
    assert cb % 128 == 0 and 128 <= cb < 1024


def test_plan_dwconv2d_bf16_affords_larger_blocks():
    """ROADMAP item 4: bf16 working sets claim ~2x less, so the same budget
    affords a larger channel block (the old fp32-only math under-claimed)."""
    budget = 2 * 1024 * 1024
    p32 = blocking.plan_dwconv2d(28, 28, 26, 26, 4096, vmem_budget=budget)
    p16 = blocking.plan_dwconv2d(28, 28, 26, 26, 4096, vmem_budget=budget,
                                 dtype=jnp.bfloat16)
    assert p16.block_c > p32.block_c
    assert p16.dtype_bytes == 2 and p32.dtype_bytes == 4
    # and at EQUAL blocks the bf16 claim is strictly smaller
    b32 = blocking.dwconv2d_vmem_bytes(28, 28, 26, 26, 256, itemsize=4)
    b16 = blocking.dwconv2d_vmem_bytes(28, 28, 26, 26, 256, itemsize=2)
    assert b16 < b32


# ---------------------------------------------------------------------------
# fused separable plan (replaces separable_fused._block_sizes)
# ---------------------------------------------------------------------------

def test_plan_separable_prefers_single_co_panel():
    """The planner targets n_co == 1 (the traffic-optimal case) whenever the
    accumulator fits; that is what makes fused bytes strictly lower."""
    p = blocking.plan_separable(112, 112, 32, 64)
    assert p is not None and p.block_co == 64
    p = blocking.plan_separable(7, 7, 1024, 1024)
    assert p is not None and p.block_co == 1024


def test_plan_separable_prefers_whole_image_slab_when_it_fits():
    """No-slabbing (slab_h == Ho) must win at MobileNet resolutions — the
    seed behavior — since it has zero halo cost."""
    for ho, c, co in ((112, 32, 64), (56, 128, 128), (14, 512, 512)):
        p = blocking.plan_separable(ho, ho, c, co)
        assert p is not None
        assert p.slab_h == ho and p.n_slabs == 1 and p.halo_rows == 0


def test_plan_separable_hires_returns_slab_plan():
    """Above the old ~1.5M-pixel accumulator ceiling the planner must return
    a real row-slab plan instead of None (the old unfused fallback)."""
    p = blocking.plan_separable(1504, 1504, 32, 32)
    assert p is not None
    assert p.n_slabs > 1 and p.slab_h * p.n_slabs >= 1504
    assert p.halo_rows == 2                      # Hf - stride = 3 - 1
    assert p.vmem_bytes <= blocking.DEFAULT_VMEM_BUDGET
    # stride-2 halo is 1 row
    p2 = blocking.plan_separable(752, 752, 32, 64, stride=2)
    assert p2 is not None and (p2.n_slabs == 1 or p2.halo_rows == 1)


def test_plan_separable_bf16_claims_less_and_slabs_less():
    """bf16 budget accounting (ROADMAP item 4): the same geometry needs
    fewer/larger slabs and claims fewer bytes per element."""
    p32 = blocking.plan_separable(1504, 1504, 32, 32)
    p16 = blocking.plan_separable(1504, 1504, 32, 32, dtype=jnp.bfloat16)
    assert p16.slab_h >= p32.slab_h
    assert p16.n_slabs <= p32.n_slabs
    b32 = blocking.fused_vmem_bytes(1504, 8, 32, 32, itemsize=4)
    b16 = blocking.fused_vmem_bytes(1504, 8, 32, 32, itemsize=2)
    assert b16 < b32


def test_plan_separable_none_only_below_minimal_plan():
    """None is reserved for budgets below even (cb=1, cob=1, slab_h=1);
    row slabs removed the resolution-driven ceiling."""
    assert blocking.plan_separable(9, 9, 10, 12, vmem_budget=64) is None
    # a budget that used to be infeasible pre-slabs now yields a plan
    p = blocking.plan_separable(112, 112, 3000, 3000,
                                vmem_budget=64 * 1024)
    assert p is not None and p.n_slabs > 1


def test_plan_separable_residual_costs_budget():
    """The residual tile is part of the claim: at equal blocks it strictly
    raises the working set, and the plan accounts for it."""
    pr = blocking.plan_separable(112, 112, 32, 64, residual=True)
    p = blocking.plan_separable(112, 112, 32, 64, residual=False)
    assert pr is not None and p is not None
    assert pr.vmem_bytes > p.vmem_bytes or pr.slab_h < p.slab_h \
        or pr.block_c < p.block_c
    assert (blocking.fused_vmem_bytes(112, 112, 32, 64, residual=True)
            > blocking.fused_vmem_bytes(112, 112, 32, 64, residual=False))


# ---------------------------------------------------------------------------
# pwconv plan
# ---------------------------------------------------------------------------

def test_plan_pwconv_mxu_aligned_and_within_budget():
    p = blocking.plan_pwconv(12544, 64, 128)
    assert p.block_co % 128 == 0 and p.block_c % 128 == 0
    assert p.block_g >= 8
    assert p.vmem_bytes <= blocking.DEFAULT_VMEM_BUDGET


def test_plan_pwconv_bf16_affords_taller_g_panel():
    budget = 3 * 1024 * 1024
    p32 = blocking.plan_pwconv(1 << 20, 1024, 1024, vmem_budget=budget)
    p16 = blocking.plan_pwconv(1 << 20, 1024, 1024, vmem_budget=budget,
                               dtype=jnp.bfloat16)
    assert p16.block_g >= p32.block_g
    assert p16.vmem_bytes <= budget and p32.vmem_bytes <= budget
    # at equal blocks, the bf16-budgeted claim is strictly smaller
    assert (blocking.pwconv_vmem_bytes(256, 256, 256, itemsize=2)
            < blocking.pwconv_vmem_bytes(256, 256, 256, itemsize=4))


# ---------------------------------------------------------------------------
# claimed-bytes tables (benchmarks/kernel_vmem.py) — bf16 rows shrink
# ---------------------------------------------------------------------------

def test_kernel_vmem_tables_shrink_for_bf16():
    """Satellite acceptance: with dtype-aware budgeting the claimed-bytes
    tables must be strictly smaller for bf16 than fp32 on every row (same
    blocks => half the streamed bytes; bigger blocks still fit the same
    budget)."""
    import os
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks.kernel_vmem import separable_fused_rows
    from benchmarks.layers import SEP_SUITES

    from benchmarks.layers import sep_geometry

    for suite in ("mobilenet_v1", "hires"):
        r32 = separable_fused_rows(SEP_SUITES[suite], dtype=jnp.float32)
        r16 = separable_fused_rows(SEP_SUITES[suite], dtype=jnp.bfloat16)
        for a, b in zip(r32, r16):
            assert a["fusible"] and b["fusible"]
            # bf16 may buy LARGER blocks at the same budget, so compare
            # like-for-like: every claim stays within the shared budget...
            assert b["vmem_bytes"] <= blocking.DEFAULT_VMEM_BUDGET
            # ...and at the fp32 plan's own block shapes the bf16-budgeted
            # claim strictly shrinks (the fp32-only math under-claimed ~2x).
            blk = next(x for x in SEP_SUITES[suite] if x.name == a["name"])
            hi, wi, ho, wo = sep_geometry(blk)
            b32 = blocking.fused_vmem_bytes(
                wo, a["slab_h"], a["block_c"], a["block_co"],
                blk.hf, blk.hf, blk.stride, itemsize=4)
            b16 = blocking.fused_vmem_bytes(
                wo, a["slab_h"], a["block_c"], a["block_co"],
                blk.hf, blk.hf, blk.stride, itemsize=2)
            assert b16 < b32, a["name"]


# ---------------------------------------------------------------------------
# degenerate geometries (DESIGN.md §8: the ladders must stay strictly
# descending, deduplicated and feasible even where the benchmarked suites
# never go — tiny/prime channel counts, narrow rows, width-mult channels)
# ---------------------------------------------------------------------------

PRIMES = (2, 3, 5, 7, 13, 97, 113, 251)


@pytest.mark.parametrize("c", list(range(1, 8)) + list(PRIMES))
def test_snap_channels_degenerate_c(c):
    """C < 8 and prime C: every snapped block is feasible (1 <= cb <= C)
    and idempotent — snapping a snapped value is a no-op (the PL110
    planlint rule relies on exactly this fixed-point property)."""
    for budget in (1, 2, 3, 7, 8, 100, 128, 129, 1 << 20):
        cb = blocking.snap_channels(budget, c)
        assert 1 <= cb <= c
        assert blocking.snap_channels(cb, c) == cb


@pytest.mark.parametrize("n", PRIMES)
def test_candidate_ladders_prime_counts(n):
    """Prime Co/Ho: the ladders still lead with the whole extent, stay
    strictly descending and deduplicated, and every rung is feasible."""
    for cands in (blocking.co_candidates(n), blocking.slab_candidates(n)):
        assert cands[0] == n
        assert all(a > b for a, b in zip(cands, cands[1:])), cands
        assert len(cands) == len(set(cands))
        assert all(1 <= x <= n for x in cands)


@pytest.mark.parametrize("ho,wo,c,co", [
    (7, 7, 3, 5),        # C < 8, Wo < 128, everything tiny
    (13, 13, 7, 13),     # prime Ho and Co, C < 8
    (113, 113, 8, 8),    # prime rows at a real V2-stem-like resolution
    (5, 3, 2, 2),        # near-scalar
])
def test_plan_separable_degenerate_feasible(ho, wo, c, co):
    """The fused planner's answer at degenerate geometry is internally
    consistent: snapped channel block, ladder-member Co panel, exact slab
    arithmetic — i.e. it passes the same field checks planlint enforces."""
    plan = blocking.plan_separable(ho, wo, c, co)
    assert plan is not None
    assert plan.block_c == blocking.snap_channels(plan.block_c, c)
    assert plan.block_co in blocking.co_candidates(co)
    assert 1 <= plan.slab_h <= ho
    assert plan.n_slabs == -(-ho // plan.slab_h)
    assert plan.halo_rows == (2 if plan.n_slabs > 1 else 0)
    assert plan.vmem_bytes <= blocking.DEFAULT_VMEM_BUDGET


@pytest.mark.parametrize("wm", [0.25, 0.35, 0.75, 1.4])
def test_width_mult_channel_counts_plan_cleanly(wm):
    """make_divisible width-mult channel ladders (the counts real slimmed
    MobileNets use) plan feasibly end to end: 2- and 3-stage fused plans
    exist and carry ladder-member blocks."""
    from repro.core.network import make_divisible
    for c_base, co_base in ((32, 64), (64, 128), (512, 512)):
        ci = make_divisible(c_base * wm)
        co = make_divisible(co_base * wm)
        assert ci % 8 == 0 and co % 8 == 0  # the make_divisible contract
        p2 = blocking.plan_separable(14, 14, ci, co)
        assert p2 is not None and p2.block_co in blocking.co_candidates(co)
        p3 = blocking.plan_separable3(14, 14, ci, 6 * ci, co)
        assert p3 is not None
        assert p3.block_c == blocking.snap_channels(p3.block_c, 6 * ci)
        assert p3.block_co in blocking.co_candidates(co)
