"""The declarative chain API (core/chain.py): plan() golden tests (which
stages fuse at which shapes/dtypes/budgets), 3-stage fused vs unfused-
composition parity (fp32 + bf16, stride 1/2, with/without residual), and
shim-equivalence of the legacy entry points, plus the ChainPlan traffic
invariant (3-stage < 2-stage < unfused HBM bytes)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import chain
from repro.core import intensity as it
from repro.core.separable import (
    init_inverted_residual,
    init_separable,
    inverted_residual,
    separable_block,
)
from repro.kernels import blocking, ref
from repro.kernels.policy import KernelPolicy

RNG = np.random.default_rng(11)


def _arr(shape, dtype=np.float32, scale=1.0):
    return jnp.asarray((RNG.normal(size=shape) * scale).astype(dtype))


def _kinds(cp):
    return [s.kind for s in cp.segments]


# ---------------------------------------------------------------------------
# plan() golden tests
# ---------------------------------------------------------------------------

# Every MobileNetV2 inverted-residual geometry must lower to ONE 3-stage
# fused pass at the default budget (the ROADMAP capability), fp32 AND bf16.
V2_GOLDEN = [
    # (h, c_in, expand, c_out, stride)
    (112, 16, 6, 24, 2),
    (56, 24, 6, 32, 2),
    (28, 32, 6, 64, 2),
    (14, 64, 6, 96, 1),
    (7, 160, 6, 320, 1),
]


@pytest.mark.parametrize("h,ci,ex,co,stride", V2_GOLDEN)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_plan_golden_v2_single_fused3_pass(h, ci, ex, co, stride, dtype):
    spec = chain.inverted_residual_spec(ci, co, expand=ex, stride=stride)
    cp = chain.plan(spec, (1, h, h, ci), dtype=dtype)
    assert _kinds(cp) == ["fused3"], cp
    assert cp.fully_fused
    seg = cp.segments[0]
    assert seg.stages == (0, 1, 2)
    assert seg.plan.vmem_bytes <= blocking.DEFAULT_VMEM_BUDGET
    # residual exactly when the V2 rule allows it, always folded in-kernel
    expect_res = stride == 1 and ci == co
    assert cp.residual == expect_res
    assert cp.residual_fused == expect_res


def test_plan_golden_v1_single_fused2_pass():
    spec = chain.separable_block_spec(64, stride=1)
    cp = chain.plan(spec, (1, 112, 112, 32))
    assert _kinds(cp) == ["fused2"]
    assert cp.fully_fused and not cp.residual


def test_plan_golden_budget_degradation_ladder():
    """The acceptance fallback: 3-fused -> (expand + 2-fused) -> unfused as
    the budget shrinks; the residual stays kernel-folded until the last
    segment is no longer fused."""
    spec = chain.inverted_residual_spec(16, 16, expand=6, stride=1)
    shape = (1, 12, 12, 16)

    cp = chain.plan(spec, shape)
    assert _kinds(cp) == ["fused3"] and cp.residual_fused

    cp2 = chain.plan(spec, shape,
                     policy=KernelPolicy(vmem_budget=3 * 1024))
    assert _kinds(cp2) == ["pw", "fused2"] and cp2.residual_fused

    cp1 = chain.plan(spec, shape, policy=KernelPolicy(vmem_budget=64))
    assert _kinds(cp1) == ["pw", "dw", "pw"]
    assert cp1.residual and not cp1.residual_fused
    assert cp1.n_kernel_passes == 4  # 3 stages + separate residual add


def test_plan_biased_expansion_blocks_3stage_fusion():
    """A biased expansion cannot commute with zero SAME padding, so the
    planner must degrade it to expand + 2-stage (kernels/separable_fused.py
    restriction)."""
    spec = chain.SeparableSpec(stages=(
        chain.PW(96, activation="relu6", bias=True),
        chain.DW(stride=1, activation="relu6"),
        chain.PW(24),
    ))
    cp = chain.plan(spec, (1, 14, 14, 16))
    assert _kinds(cp) == ["pw", "fused2"]


def test_plan_legacy_fused_false_forces_unfused():
    spec = chain.inverted_residual_spec(16, 16, expand=6)
    cp = chain.plan(spec, (1, 12, 12, 16), policy=KernelPolicy(fused=False))
    assert _kinds(cp) == ["pw", "dw", "pw"]


def test_plan_bf16_budgets_differ_from_fp32():
    """dtype reaches the chain budget: bf16 streams cost half, so the
    planned blocks can grow (and never shrink) vs fp32 at equal budget."""
    spec = chain.inverted_residual_spec(32, 32, expand=6)
    budget = 96 * 1024
    p32 = chain.plan(spec, (1, 56, 56, 32),
                     policy=KernelPolicy(vmem_budget=budget))
    p16 = chain.plan(spec, (1, 56, 56, 32), dtype=jnp.bfloat16,
                     policy=KernelPolicy(vmem_budget=budget))
    assert p32.dtype_bytes == 4 and p16.dtype_bytes == 2
    assert len(p16.segments) <= len(p32.segments)
    if _kinds(p16) == _kinds(p32) == ["fused3"]:
        assert p16.segments[0].plan.slab_h >= p32.segments[0].plan.slab_h


def test_chain_plan_is_hashable_and_comparable():
    """The autotuning requirement: a ChainPlan is a frozen, hashable,
    comparable unit — same spec+shape+dtype plans equal, others differ."""
    spec = chain.inverted_residual_spec(16, 24, expand=4, stride=2)
    a = chain.plan(spec, (1, 28, 28, 16))
    b = chain.plan(spec, (1, 28, 28, 16))
    c = chain.plan(spec, (1, 112, 112, 16))
    assert a == b and hash(a) == hash(b)
    assert a != c
    assert {a, b, c} == {a, c}


# ---------------------------------------------------------------------------
# 3-stage fused vs unfused-composition parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("stride", [1, 2])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
@pytest.mark.parametrize("residual", [False, True])
def test_fused3_matches_unfused_composition(stride, dtype, residual):
    """Acceptance gate: the single-pass expand->DW->project kernel matches
    the fully unfused XLA oracle chain (fp32 tight, bf16 within rounding —
    the unfused chain rounds BOTH intermediates to bf16, the fused pass
    keeps them fp32)."""
    ci = 16
    co = ci if residual else 40
    stride = 1 if residual else stride  # residual requires stride 1
    spec = chain.inverted_residual_spec(ci, co, expand=4, stride=stride)
    params = chain.init_chain(jax.random.PRNGKey(42), spec, ci)
    if dtype != np.float32:
        params = jax.tree_util.tree_map(lambda a: a.astype(dtype), params)
    x = _arr((2, 13, 13, ci)).astype(dtype)

    cp = chain.plan(spec, x.shape, dtype=x.dtype)
    assert _kinds(cp) == ["fused3"]
    got = chain.execute(spec, params, x,
                        policy=KernelPolicy(impl="pallas", interpret=True),
                        chain_plan=cp)

    # unfused oracle composition (per-stage XLA refs, natural rounding)
    y = ref.pwconv_ref(x, params[0]["w"], activation="relu6")
    y = ref.dwconv2d_ref(y, params[1]["f"], stride=stride, padding="same")
    y = jnp.clip(y, 0.0, 6.0)
    y = ref.pwconv_ref(y, params[2]["w"])
    if cp.residual:
        y = y + x
    tol = 1e-4 if dtype == np.float32 else 8e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(y, np.float32),
                               rtol=tol, atol=tol)


def test_fused3_parity_across_degradation_ladder():
    """Every rung of the fallback ladder computes the same block (fp32)."""
    spec = chain.inverted_residual_spec(16, 16, expand=6, stride=1)
    params = chain.init_chain(jax.random.PRNGKey(5), spec, 16)
    x = _arr((1, 12, 12, 16))
    outs = []
    for budget in (blocking.DEFAULT_VMEM_BUDGET, 3 * 1024, 64):
        pol = KernelPolicy(impl="pallas", interpret=True,
                           vmem_budget=budget)
        outs.append(np.asarray(chain.execute(spec, params, x, policy=pol)))
    np.testing.assert_allclose(outs[0], outs[1], rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(outs[0], outs[2], rtol=1e-4, atol=1e-4)


def test_ops_separable_fused_expand_entry():
    """The kernel-level wrapper (ops.separable_fused(expand_w=...)) matches
    its oracle, including the plan3-infeasible degrade path."""
    from repro.kernels import ops

    x = _arr((1, 10, 10, 12))
    ew = _arr((12, 48), scale=12 ** -0.5)
    f = _arr((3, 3, 48), scale=1 / 3)
    w = _arr((48, 20), scale=48 ** -0.5)
    want = ref.separable_fused_ref(
        x, f, w, expand_w=ew, stride=1, padding="same",
        dw_activation="relu6", activation=None)
    got = ops.separable_fused(
        x, f, w, expand_w=ew, stride=1, padding="same",
        dw_activation="relu6", activation=None,
        impl="pallas", interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    # budget that kills plan3 but allows the 2-stage tail
    got_deg = ops.separable_fused(
        x, f, w, expand_w=ew, stride=1, padding="same",
        dw_activation="relu6", activation=None,
        impl="pallas", interpret=True, vmem_budget=6 * 1024)
    np.testing.assert_allclose(np.asarray(got_deg), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# shim equivalence: legacy entry points == the chain API
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("stride", [1, 2])
def test_separable_block_shim_equivalence(stride):
    """Old separable_block call == explicit spec->plan->execute, bitwise
    (same code path), on both backends."""
    params = init_separable(jax.random.PRNGKey(0), 16, 24)
    x = _arr((1, 14, 14, 16))
    spec = chain.separable_block_spec(24, stride=stride)
    stage_params = (
        {"f": params["dw_filter"], "b": params["dw_bias"]},
        {"w": params["pw_weight"], "b": params["pw_bias"]},
    )
    for pol in (KernelPolicy(impl="xla"),
                KernelPolicy(impl="pallas", interpret=True)):
        old = separable_block(params, x, stride=stride, policy=pol)
        new = chain.execute(spec, stage_params, x, policy=pol)
        np.testing.assert_array_equal(np.asarray(old), np.asarray(new))


@pytest.mark.parametrize("stride,c_in,c_out", [(1, 8, 8), (2, 8, 16)])
def test_inverted_residual_shim_equivalence(stride, c_in, c_out):
    params = init_inverted_residual(jax.random.PRNGKey(1), c_in, c_out,
                                    expand=4)
    x = _arr((1, 10, 10, c_in))
    spec = chain.inverted_residual_spec(c_in, c_out, expand=4, stride=stride)
    stage_params = ({"w": params["expand_w"]}, {"f": params["dw_filter"]},
                    {"w": params["project_w"]})
    for pol in (KernelPolicy(impl="xla"),
                KernelPolicy(impl="pallas", interpret=True)):
        old = inverted_residual(params, x, stride=stride, policy=pol)
        new = chain.execute(spec, stage_params, x, policy=pol)
        np.testing.assert_array_equal(np.asarray(old), np.asarray(new))


def test_inverted_residual_now_single_pass():
    """The ROADMAP capability through the legacy shim: a V2 block's plan is
    ONE fused3 kernel pass with the residual folded in."""
    spec = chain.inverted_residual_spec(32, 32, expand=6, stride=1)
    cp = chain.plan(spec, (1, 14, 14, 32))
    assert cp.fully_fused and cp.n_kernel_passes == 1


# ---------------------------------------------------------------------------
# ChainPlan traffic model (core/intensity.py)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("h,ci,ex,co,stride", V2_GOLDEN)
def test_fused3_traffic_strictly_below_2stage_and_unfused(h, ci, ex, co,
                                                          stride):
    """Acceptance gate: the 3-stage fused chain's modeled HBM bytes are
    STRICTLY below the PR-2 two-stage lowering (standalone expand + fused
    DW->PW), which is strictly below fully unfused — at every MobileNetV2
    block geometry, fp32 and bf16."""
    c = ci * ex
    ho = -(-h // stride)
    hi = (ho - 1) * stride + 3
    for nb in (4, 2):
        p3 = blocking.plan_separable3(ho, ho, ci, c, co, stride=stride)
        p2 = blocking.plan_separable(ho, ho, c, co, stride=stride)
        assert p3 is not None and p2 is not None
        t3 = it.separable_traffic_fused3(
            1, hi, hi, ci, c, co, 3, 3, stride,
            block_co=p3.block_co, slab_h=p3.slab_h, dtype_bytes=nb)
        t2 = it.separable_traffic_2stage(
            1, h, h, ci, c, co, 3, 3, stride,
            block_co=p2.block_co, slab_h=p2.slab_h, dtype_bytes=nb)
        tu = it.separable_traffic_unfused3(1, h, h, ci, c, co, 3, 3, stride,
                                           dtype_bytes=nb)
        assert t3.bytes_hbm < t2.bytes_hbm < tu.bytes_hbm, (h, ci, co, nb)
        assert t3.intensity > t2.intensity


def test_chain_traffic_matches_segment_model():
    """chain_traffic over a planned V2 block equals the fused3 model term
    plus one streamed read of the folded residual operand."""
    spec = chain.inverted_residual_spec(32, 32, expand=6)
    shape = (1, 14, 14, 32)
    cp = chain.plan(spec, shape)
    assert _kinds(cp) == ["fused3"] and cp.residual_fused
    t = chain.chain_traffic(spec, cp, shape)
    seg = cp.segments[0]
    want = it.separable_traffic_fused3(
        1, 16, 16, 32, 192, 32, 3, 3, 1,
        block_co=seg.plan.block_co, slab_h=seg.plan.slab_h)
    res_read = 4 * 1 * 14 * 14 * 32
    assert t.flops == want.flops + 1 * 14 * 14 * 32
    assert t.bytes_hbm == want.bytes_hbm + res_read


def test_plan_residual_requires_spatial_preservation():
    """A valid-padded DW shrinks the spatial dims even at stride 1: the
    auto residual must deactivate, and an explicit residual=True must be
    rejected at plan time."""
    auto = chain.SeparableSpec(stages=(
        chain.DW(stride=1, padding="valid"), chain.PW(16)),
        residual="auto")
    cp = chain.plan(auto, (1, 12, 12, 16))
    assert not cp.residual
    forced = chain.SeparableSpec(stages=(
        chain.DW(stride=1, padding="valid"), chain.PW(16)),
        residual=True)
    with pytest.raises(ValueError):
        chain.plan(forced, (1, 12, 12, 16))


def test_chain_traffic_unfused_residual_counts_separate_add():
    spec = chain.inverted_residual_spec(16, 16, expand=6)
    shape = (1, 12, 12, 16)
    pol = KernelPolicy(fused=False)
    cp = chain.plan(spec, shape, policy=pol)
    assert cp.residual and not cp.residual_fused
    t = chain.chain_traffic(spec, cp, shape)
    cp_f = chain.plan(spec, shape)
    t_f = chain.chain_traffic(spec, cp_f, shape)
    assert t.bytes_hbm > t_f.bytes_hbm  # unfused + residual add cost more


def test_dw_epilogue_traffic_counted():
    """A standalone DW with bias/activation pays a separate elementwise
    epilogue (read + re-write of the whole output tensor, plus the bias
    vector); a bare DW pays nothing extra."""
    shape = (1, 12, 12, 16)
    b, ho, wo, c = 1, 12, 12, 16

    def _traffic(bias, activation):
        spec = chain.SeparableSpec(
            stages=(chain.DW(stride=1, bias=bias, activation=activation),),
            residual=False)
        cp = chain.plan(spec, shape)
        assert _kinds(cp) == ["dw"]
        return chain.chain_traffic(spec, cp, shape)

    bare = _traffic(False, None)
    act = _traffic(False, "relu6")
    full = _traffic(True, "relu6")
    # activation only: 2 * tensor bytes, one flop per element
    assert act.bytes_hbm - bare.bytes_hbm == 4 * 2 * b * ho * wo * c
    assert act.flops - bare.flops == b * ho * wo * c
    # bias adds one streamed read of the C-vector on top
    assert full.bytes_hbm - act.bytes_hbm == 4 * c
    # bias-only (no activation) still pays the epilogue
    bias_only = _traffic(True, None)
    assert bias_only.bytes_hbm == full.bytes_hbm


@pytest.mark.parametrize("h,ci,ex,co,stride", V2_GOLDEN)
@pytest.mark.parametrize("nb", [4, 2])
def test_unfused_chain_traffic_exceeds_fused_every_v2_shape(h, ci, ex, co,
                                                            stride, nb):
    """End-to-end chain_traffic gate: the unfused lowering's modeled HBM
    bytes — INCLUDING the standalone-DW epilogue pass — strictly exceed
    the fused plan's at every MobileNetV2 geometry, fp32 and bf16."""
    spec = chain.inverted_residual_spec(ci, co, expand=ex, stride=stride)
    shape = (1, h, h, ci)
    cp_f = chain.plan(spec, shape)
    cp_u = chain.plan(spec, shape, policy=KernelPolicy(fused=False))
    assert cp_f.fully_fused and _kinds(cp_u) == ["pw", "dw", "pw"]
    t_f = chain.chain_traffic(spec, cp_f, shape, dtype_bytes=nb)
    t_u = chain.chain_traffic(spec, cp_u, shape, dtype_bytes=nb)
    assert t_u.bytes_hbm > t_f.bytes_hbm, (h, ci, co, nb)
    # the V2 DW stage is activated (relu6, no bias): the unfused total
    # must carry exactly its epilogue term — diff against the same chain
    # with the DW activation stripped
    import dataclasses as dc
    stages = list(spec.stages)
    stages[1] = dc.replace(stages[1], activation=None)
    bare = dc.replace(spec, stages=tuple(stages))
    t_bare = chain.chain_traffic(bare, cp_u, shape, dtype_bytes=nb)
    ho = -(-h // stride)
    epi = nb * 2 * 1 * ho * ho * ci * ex
    assert t_u.bytes_hbm - t_bare.bytes_hbm == epi
    assert t_u.flops - t_bare.flops == 1 * ho * ho * ci * ex


# ---------------------------------------------------------------------------
# plan_separable3 planner unit behavior
# ---------------------------------------------------------------------------

def test_plan_separable3_budget_and_none():
    p = blocking.plan_separable3(112, 112, 16, 96, 24, stride=1)
    assert p is not None
    assert p.vmem_bytes <= blocking.DEFAULT_VMEM_BUDGET
    assert p.block_co == 24  # single Co panel preferred
    # nothing fits an absurd budget
    assert blocking.plan_separable3(12, 12, 16, 96, 24,
                                    vmem_budget=64) is None


def test_plan_separable3_slabs_at_hires():
    """The expanded fp32 intermediate dominates: high resolutions must slab
    (and still fit the budget) rather than return None."""
    p = blocking.plan_separable3(1504, 1504, 16, 96, 32)
    assert p is not None and p.n_slabs > 1
    assert p.vmem_bytes <= blocking.DEFAULT_VMEM_BUDGET


def test_fused3_vmem_bytes_exceeds_fused2_at_equal_blocks():
    """The 3-stage working set adds the raw-input window, expand-weight
    tile and expanded value on top of the 2-stage claim."""
    b3 = blocking.fused3_vmem_bytes(112, 8, 16, 32, 64)
    b2 = blocking.fused_vmem_bytes(112, 8, 32, 64)
    assert b3 > b2
