"""Generalized chain algebra (DESIGN.md §10): SE and fused-MBConv stages
as first-class chain citizens.

Covers the new fusability windows (``dw_se`` epilogue fusion, ``fusedmb``
conv+project fusion) as plan goldens incl. the VMEM-degradation ladders,
fused-vs-unfused-composition parity (fp32 tight, bf16 tolerance) on the
Pallas interpret path, the traffic-model ordering, the MnasNet-A1 /
EfficientNet-Lite0 network specs end to end, and the per-rule seeded
positives/negatives for the new static-analysis surface (PL114, the
XLA-composed model-None contract, grid proofs on the new kernel models).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import jaxpr_audit, planlint
from repro.analysis.diagnostics import ERROR
from repro.core import chain, network
from repro.kernels import blocking, ref
from repro.kernels.policy import KernelPolicy

RNG = np.random.default_rng(23)
PAL = KernelPolicy(impl="pallas", interpret=True)

#: Small enough for interpret mode, big enough for a real dw_se/fusedmb
#: plan: the SE pool needs FULL channel+spatial residency (DESIGN.md §10).
SE_SHAPE = (1, 14, 14, 16)       # pw -> dw_se -> pw (+ residual)
FMB_SHAPE = (1, 16, 16, 24)      # one fusedmb pass (+ residual)


def _arr(shape, dtype=np.float32, scale=1.0):
    return jnp.asarray((RNG.normal(size=shape) * scale).astype(dtype))


def _kinds(cp):
    return [s.kind for s in cp.segments]


def _rules(diags, severity=ERROR):
    return sorted({d.rule for d in diags if d.severity == severity})


def _se():
    return chain.mbconv_se_spec(16, 16, expand=4, stride=1)


def _fmb(stride=1, c_in=24, c_out=24):
    return chain.fused_mbconv_spec(c_in, c_out, expand=4, stride=stride)


def _with_plan(cp, si, **kw):
    seg = cp.segments[si]
    new = dataclasses.replace(seg, plan=dataclasses.replace(seg.plan, **kw))
    return dataclasses.replace(
        cp, segments=cp.segments[:si] + (new,) + cp.segments[si + 1:])


# ---------------------------------------------------------------------------
# plan() goldens: the new fusability windows
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_plan_golden_mbconv_se_fuses_dw_se(dtype):
    """The MnasNet MBConv+SE block plans its SE gate as the DW epilogue
    (ONE dw_se pass), never as a standalone stage, whenever the pooled
    tensor is fully VMEM-resident — fp32 and bf16."""
    cp = chain.plan(_se(), SE_SHAPE, dtype=dtype)
    assert _kinds(cp) == ["pw", "dw_se", "pw"], cp
    seg = cp.segments[1]
    # the residency contract the SE pool requires (and PL114 enforces):
    # every channel, every output row, no slabbing
    assert seg.plan.block_c == 16 * 4
    assert seg.plan.n_slabs == 1 and seg.plan.slab_h == 14
    assert seg.plan.block_g == 4  # se_ratio * block INPUT width
    assert cp.residual and not cp.residual_fused
    assert cp.n_kernel_passes == 4  # pw + dw_se + pw + residual add


def test_plan_golden_dw_se_residency_degradation():
    """When the dw_se working set cannot be fully resident the planner must
    fall back to DW + standalone two-GEMM SE — a partial-residency dw_se
    pool would compute the WRONG answer, so there is no slabbed middle
    ground."""
    spec = chain.mbconv_se_spec(16, 16, expand=6)
    cp = chain.plan(spec, (1, 112, 112, 16))
    assert _kinds(cp) == ["pw", "dw", "se", "pw"]
    # the standalone SE is two GEMM passes (pool+reduce, expand+scale)
    assert cp.n_kernel_passes == 6  # pw + dw + 2*se + pw + residual add


def test_plan_golden_fused_mbconv_single_pass():
    """The EfficientNet-Lite edge block (full conv -> PW-project) plans to
    ONE fusedmb pass, with the residual folded in when shapes allow."""
    cp = chain.plan(_fmb(stride=2, c_out=40), (1, 32, 32, 24))
    assert _kinds(cp) == ["fusedmb"]
    assert cp.n_kernel_passes == 1 and not cp.residual

    cp_r = chain.plan(_fmb(), FMB_SHAPE)
    assert _kinds(cp_r) == ["fusedmb"]
    assert cp_r.residual and cp_r.residual_fused
    assert cp_r.n_kernel_passes == 1


def test_plan_golden_fused_mbconv_degrades_to_mb_pw():
    """When even the minimal fusedmb tile blows the budget (the raw-input
    row window alone exceeds it at this geometry) the planner degrades to
    a standalone XLA conv (mb) + pointwise projection."""
    spec = chain.fused_mbconv_spec(256, 256, expand=2)
    cp = chain.plan(spec, (1, 8, 2048, 256))
    assert _kinds(cp) == ["mb", "pw"]
    assert cp.residual and not cp.residual_fused
    # mb executes as one XLA conv pass; vmem claims must stay honest
    assert cp.segments[0].plan.vmem_bytes == 0


def test_plan_legacy_fused_false_unfuses_new_kinds():
    cp = chain.plan(_se(), SE_SHAPE, policy=KernelPolicy(fused=False))
    assert _kinds(cp) == ["pw", "dw", "se", "pw"]
    cp2 = chain.plan(_fmb(), FMB_SHAPE, policy=KernelPolicy(fused=False))
    assert _kinds(cp2) == ["mb", "pw"]


# ---------------------------------------------------------------------------
# parity: fused kernels vs the unfused XLA oracle composition
# ---------------------------------------------------------------------------

def _se_oracle(spec, params, x, cp):
    """Per-stage XLA refs with natural rounding between stages."""
    y = ref.pwconv_ref(x, params[0]["w"], activation="relu")
    y = ref.dwconv2d_ref(y, params[1]["f"], stride=1, padding="same")
    y = jnp.maximum(y, 0.0)
    y = ref.se_ref(y, params[2]["w1"], params[2]["b1"],
                   params[2]["w2"], params[2]["b2"])
    y = ref.pwconv_ref(y, params[3]["w"])
    if cp.residual:
        y = y + x
    return y


@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_mbconv_se_parity(dtype):
    """Acceptance gate: the dw_se epilogue pass matches the fully unfused
    oracle chain (fp32 tight; bf16 within rounding — the fused pass keeps
    the DW output fp32 into the pool/gate, the unfused chain rounds it)."""
    spec = _se()
    params = chain.init_chain(jax.random.PRNGKey(3), spec, SE_SHAPE[-1])
    if dtype != np.float32:
        params = jax.tree_util.tree_map(lambda a: a.astype(dtype), params)
    x = _arr((2,) + SE_SHAPE[1:]).astype(dtype)

    cp = chain.plan(spec, x.shape, dtype=x.dtype)
    assert _kinds(cp) == ["pw", "dw_se", "pw"]
    got = chain.execute(spec, params, x, policy=PAL, chain_plan=cp)
    want = _se_oracle(spec, params, x, cp)
    tol = 1e-4 if dtype == np.float32 else 8e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_mbconv_se_parity_across_degradation():
    """The unfused rung (pw+dw+se+pw) computes the same block as the fused
    dw_se plan (fp32)."""
    spec = _se()
    params = chain.init_chain(jax.random.PRNGKey(4), spec, SE_SHAPE[-1])
    x = _arr(SE_SHAPE)
    fused = chain.execute(spec, params, x, policy=PAL)
    unfused = chain.execute(
        spec, params, x,
        policy=KernelPolicy(impl="pallas", interpret=True, fused=False))
    np.testing.assert_allclose(np.asarray(fused), np.asarray(unfused),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("stride,residual", [(1, True), (2, False)])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_fused_mbconv_parity(stride, residual, dtype):
    """The single-pass conv+project kernel matches the unfused composition
    (XLA conv -> rounded activation -> XLA GEMM), stride 1 with residual
    and stride 2 without, fp32 and bf16."""
    c_in = 24
    c_out = c_in if residual else 40
    spec = _fmb(stride=stride, c_in=c_in, c_out=c_out)
    params = chain.init_chain(jax.random.PRNGKey(7), spec, c_in)
    if dtype != np.float32:
        params = jax.tree_util.tree_map(lambda a: a.astype(dtype), params)
    x = _arr((2, 15, 15, c_in)).astype(dtype)

    cp = chain.plan(spec, x.shape, dtype=x.dtype)
    assert _kinds(cp) == ["fusedmb"]
    assert cp.residual == residual
    got = chain.execute(spec, params, x, policy=PAL, chain_plan=cp)

    y = ref.conv2d_ref(x, params[0]["f"], stride=stride, padding="same",
                       activation="relu6")
    y = ref.pwconv_ref(y, params[1]["w"])
    if residual:
        y = y + x
    tol = 1e-4 if dtype == np.float32 else 8e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(y, np.float32),
                               rtol=tol, atol=tol)


def test_fused_mbconv_parity_across_degradation():
    spec = _fmb()
    params = chain.init_chain(jax.random.PRNGKey(9), spec, FMB_SHAPE[-1])
    x = _arr(FMB_SHAPE)
    fused = chain.execute(spec, params, x, policy=PAL)
    unfused = chain.execute(
        spec, params, x,
        policy=KernelPolicy(impl="pallas", interpret=True, fused=False))
    np.testing.assert_allclose(np.asarray(fused), np.asarray(unfused),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# traffic models: fusion must pay off in modeled HBM bytes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("nb", [4, 2])
def test_dw_se_traffic_below_unfused(nb):
    spec = _se()
    cp_f = chain.plan(spec, SE_SHAPE)
    cp_u = chain.plan(spec, SE_SHAPE, policy=KernelPolicy(fused=False))
    assert _kinds(cp_f) == ["pw", "dw_se", "pw"]
    assert _kinds(cp_u) == ["pw", "dw", "se", "pw"]
    t_f = chain.chain_traffic(spec, cp_f, SE_SHAPE, dtype_bytes=nb)
    t_u = chain.chain_traffic(spec, cp_u, SE_SHAPE, dtype_bytes=nb)
    assert t_f.bytes_hbm < t_u.bytes_hbm, nb
    # fusion moves bytes, not arithmetic — except the standalone DW's
    # separate activation-epilogue pass (1 flop/element), which the fused
    # pass absorbs for free
    assert t_u.flops - t_f.flops == 1 * 14 * 14 * 64


@pytest.mark.parametrize("nb", [4, 2])
def test_fused_mbconv_traffic_below_unfused(nb):
    spec = _fmb()
    cp_f = chain.plan(spec, FMB_SHAPE)
    cp_u = chain.plan(spec, FMB_SHAPE, policy=KernelPolicy(fused=False))
    assert _kinds(cp_f) == ["fusedmb"] and _kinds(cp_u) == ["mb", "pw"]
    t_f = chain.chain_traffic(spec, cp_f, FMB_SHAPE, dtype_bytes=nb)
    t_u = chain.chain_traffic(spec, cp_u, FMB_SHAPE, dtype_bytes=nb)
    assert t_f.bytes_hbm < t_u.bytes_hbm, nb


# ---------------------------------------------------------------------------
# the new network specs end to end
# ---------------------------------------------------------------------------

def _hist(nplan):
    from collections import Counter
    return dict(Counter(s.kind for p in nplan.plans for s in p.segments))


def test_mnasnet_a1_plan_golden():
    """Every one of the 8 SE-carrying MBConv blocks fuses its gate onto the
    DW pass; nothing degrades to standalone se/dw at the paper's 112x112."""
    net = network.mnasnet_a1_spec()
    nplan = network.plan_network(net, (1, 112, 112, net.c_in))
    assert len(net.blocks) == 16
    assert _hist(nplan) == {"fused2": 1, "fused3": 7, "pw": 16, "dw_se": 8}


def test_efficientnet_lite0_plan_golden():
    """All 4 fused-MBConv blocks plan single-pass fusedmb; every other
    block stays fused3/fused2 — the whole body is single-pass-per-block."""
    net = network.efficientnet_lite0_spec()
    nplan = network.plan_network(net, (1, 112, 112, net.c_in))
    assert len(net.blocks) == 16
    assert _hist(nplan) == {"fused2": 1, "fused3": 11, "fusedmb": 4}
    assert all(len(p.segments) == 1 for p in nplan.plans)


@pytest.mark.parametrize("make", [network.mnasnet_a1_spec,
                                  network.efficientnet_lite0_spec])
def test_execute_network_new_archs(make):
    """Both new bodies run end to end through the network engine and match
    the per-block execute composition."""
    net = make()
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 16, net.c_in))
    params = network.init_network(jax.random.PRNGKey(0), net)
    pol = KernelPolicy(impl="xla")
    y = network.execute_network(net, params, x, policy=pol)
    o = x
    for spec, p in zip(net.blocks, params):
        o = chain.execute(spec, p, o, policy=pol)
    got, want = np.asarray(y, np.float32), np.asarray(o, np.float32)
    assert np.isfinite(got).all()
    rel = np.abs(got - want).max() / (np.abs(want).max() + 1e-30)
    assert rel < 1e-5, rel


# ---------------------------------------------------------------------------
# static analysis: PL114 + the XLA-composed contract + grid proofs
# ---------------------------------------------------------------------------

def test_clean_new_plans_lint_clean():
    """Negative for every PL rule on the new kinds at once — including the
    degraded (se/mb-carrying) plans, whose XLA-composed segments have no
    kernel model by design."""
    cases = (
        (_se(), SE_SHAPE, None),
        (_fmb(), FMB_SHAPE, None),
        (chain.mbconv_se_spec(16, 16, expand=6), (1, 112, 112, 16), None),
        (_fmb(), FMB_SHAPE, KernelPolicy(fused=False)),
    )
    for spec, shape, pol in cases:
        cp = chain.plan(spec, shape, policy=pol or KernelPolicy())
        diags = planlint.lint_chain(spec, cp, shape)
        assert _rules(diags) == [], [d.format() for d in diags]


def test_pl114_dw_se_residency_violations():
    """Seeded positives: every way the dw_se residency contract can break
    (partial channels, spatial slabbing, wrong SE width) fires PL114 —
    each would silently compute a WRONG pooled mean, not a slow one."""
    spec = _se()
    cp = chain.plan(spec, SE_SHAPE)
    assert cp.segments[1].kind == "dw_se"

    partial = _with_plan(cp, 1, block_c=32)  # C=64: pool sees half
    assert "PL114" in _rules(planlint.lint_chain(spec, partial, SE_SHAPE))

    slabbed = _with_plan(cp, 1, slab_h=7, n_slabs=2)
    assert "PL114" in _rules(planlint.lint_chain(spec, slabbed, SE_SHAPE))

    wrong_se = _with_plan(cp, 1, block_g=8)  # spec says reduce=4
    assert "PL114" in _rules(planlint.lint_chain(spec, wrong_se, SE_SHAPE))

    # and the clean plan fires none of them
    assert "PL114" not in _rules(planlint.lint_chain(spec, cp, SE_SHAPE))


def test_chain_models_none_for_xla_composed_kinds():
    """se/mb segments have NO single Pallas kernel (model is None by
    design) and lint_chain must not report that as a failure — only an
    unexpectedly missing model on a kernel-backed kind is an error."""
    spec = chain.mbconv_se_spec(16, 16, expand=6)
    shape = (1, 112, 112, 16)
    cp = chain.plan(spec, shape)
    kinds = {g.kind: m for _l, g, m in planlint.chain_models(spec, cp, shape)}
    assert kinds["se"] is None and kinds["dw"] is not None
    assert _rules(planlint.lint_chain(spec, cp, shape)) == []

    spec2 = _fmb()
    cp2 = chain.plan(spec2, FMB_SHAPE, policy=KernelPolicy(fused=False))
    kinds2 = {g.kind: m
              for _l, g, m in planlint.chain_models(spec2, cp2, FMB_SHAPE)}
    assert kinds2["mb"] is None and kinds2["pw"] is not None


def test_new_kernel_models_grid_proofs():
    """The derived dw_se and fusedmb models pass the full grid proof
    (in-bounds halo windows, exact disjoint output coverage) — the
    negative for PL120-123 on the new index maps."""
    for spec, shape, kind in ((_se(), SE_SHAPE, "dw_se"),
                              (_fmb(), FMB_SHAPE, "fusedmb")):
        cp = chain.plan(spec, shape)
        models = [(g, m) for _l, g, m in planlint.chain_models(spec, cp,
                                                               shape)
                  if g.kind == kind]
        assert models and models[0][1] is not None
        assert _rules(planlint.check_grid(models[0][1])) == []


def test_claimed_vmem_honest_for_new_kinds():
    """PL102 drift detection reaches the new kinds: a corrupted vmem claim
    on a dw_se or fusedmb segment is caught."""
    for spec, shape, si in ((_se(), SE_SHAPE, 1), (_fmb(), FMB_SHAPE, 0)):
        cp = chain.plan(spec, shape)
        bad = _with_plan(cp, si, vmem_bytes=123)
        assert "PL102" in _rules(planlint.lint_chain(spec, bad, shape))


# ---------------------------------------------------------------------------
# jaxpr audit on the new kinds
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec,shape", [(_se(), SE_SHAPE),
                                        (_fmb(), FMB_SHAPE)])
def test_new_chain_jaxpr_audit_clean(spec, shape):
    cp = chain.plan(spec, shape, policy=PAL)
    diags = jaxpr_audit.lint_chain_jaxpr(spec, cp, shape,
                                         dtype=jnp.float32, policy=PAL)
    assert _rules(diags) == [], [d.format() for d in diags]


def test_jx310_seeded_cast_around_se_chain():
    """A rogue fp16 round-trip wrapped around the SE chain fires the
    cast-ownership rule; the clean trace does not."""
    spec, shape = _se(), SE_SHAPE
    cp = chain.plan(spec, shape, policy=PAL)
    run = chain.lower(spec, cp, PAL)
    params = jaxpr_audit.param_structs(spec, shape[-1], jnp.float32)
    x = jax.ShapeDtypeStruct(shape, jnp.float32)
    clean = jax.make_jaxpr(run)(params, x)
    assert _rules(jaxpr_audit.audit_casts(clean, {"float32"})) == []
    leaky = jax.make_jaxpr(
        lambda p, a: run(p, a.astype(jnp.float16).astype(jnp.float32)))(
            params, x)
    assert _rules(jaxpr_audit.audit_casts(leaky, {"float32"})) == ["JX310"]


def test_param_structs_cover_new_stages():
    """The audit's shape-only param mirror matches init_chain exactly for
    SE and FusedMB stages (key set AND shapes), so traces need no real
    weights."""
    for spec, c_in in ((_se(), SE_SHAPE[-1]), (_fmb(), FMB_SHAPE[-1])):
        real = chain.init_chain(jax.random.PRNGKey(0), spec, c_in)
        structs = jaxpr_audit.param_structs(spec, c_in, jnp.float32)
        assert len(real) == len(structs)
        for rp, sp in zip(real, structs):
            assert set(rp) == set(sp)
            for k in rp:
                assert rp[k].shape == sp[k].shape, (k, rp[k].shape)
