"""kernels/epilogue.apply_epilogue: every activation x {fp32, bf16} x
{bias, bias-free} against independent numpy formulas, dtype preservation,
the 0 -> 0 property the fused expand path relies on, and the unknown-
activation error path."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.epilogue import ACTIVATIONS, apply_epilogue

RNG = np.random.default_rng(11)


def _expected(y: np.ndarray, bias, activation) -> np.ndarray:
    """Independent fp64 numpy reimplementation (gelu = the tanh
    approximation jax.nn.gelu defaults to)."""
    y = y.astype(np.float64)
    if bias is not None:
        y = y + bias.astype(np.float64)
    if activation is None:
        return y
    if activation == "relu":
        return np.maximum(y, 0.0)
    if activation == "relu6":
        return np.clip(y, 0.0, 6.0)
    if activation == "gelu":
        c = np.sqrt(2.0 / np.pi)
        return 0.5 * y * (1.0 + np.tanh(c * (y + 0.044715 * y ** 3)))
    if activation == "silu":
        return y / (1.0 + np.exp(-y))
    raise AssertionError(activation)


@pytest.mark.parametrize("activation", list(ACTIVATIONS) + [None])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("with_bias", [True, False])
def test_epilogue_matches_numpy(activation, dtype, with_bias):
    y = RNG.normal(size=(3, 5, 8), scale=3.0).astype(np.float32)
    b = RNG.normal(size=(8,)).astype(np.float32) if with_bias else None
    yj = jnp.asarray(y).astype(dtype)
    bj = jnp.asarray(b) if b is not None else None  # fp32 bias, bf16 y:
    got = apply_epilogue(yj, bj, activation)        # cast happens inside
    assert got.dtype == jnp.dtype(dtype)            # dtype preserved

    # expected on the ROUNDED inputs (what the kernel actually consumes)
    yr = np.asarray(jnp.asarray(y).astype(dtype), np.float32)
    br = (np.asarray(jnp.asarray(b).astype(dtype), np.float32)
          if b is not None else None)
    want = _expected(yr, br, activation)
    tol = 1e-6 if dtype == jnp.float32 else 8e-2
    np.testing.assert_allclose(np.asarray(got, np.float32), want,
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("activation", ACTIVATIONS)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_every_activation_maps_zero_to_zero(activation, dtype):
    """The property the fused expand-on-the-fly kernel relies on: zero
    SAME-padding pixels stay exactly zero through a bias-free epilogue."""
    z = jnp.zeros((4, 4), dtype)
    out = apply_epilogue(z, None, activation)
    assert np.asarray(out, np.float32).max() == 0.0
    assert np.asarray(out, np.float32).min() == 0.0


def test_bias_only_is_plain_add():
    y = jnp.asarray(RNG.normal(size=(2, 8)).astype(np.float32))
    b = jnp.asarray(RNG.normal(size=(8,)).astype(np.float32))
    np.testing.assert_array_equal(np.asarray(apply_epilogue(y, b, None)),
                                  np.asarray(y + b))


def test_relu6_clips_both_sides():
    y = jnp.asarray(np.array([-3.0, 0.0, 3.0, 6.0, 9.0], np.float32))
    np.testing.assert_array_equal(
        np.asarray(apply_epilogue(y, None, "relu6")),
        np.array([0.0, 0.0, 3.0, 6.0, 6.0], np.float32))


def test_unknown_activation_raises():
    y = jnp.zeros((2, 2))
    with pytest.raises(ValueError, match="unknown activation"):
        apply_epilogue(y, None, "swishish")
