"""The paper's analytical claims, validated exactly (reproduction gate)."""
import math

import pytest

from repro.core import intensity as it


def test_tflite_dw_plain_is_one_eighth():
    assert it.t_tf_dw() == pytest.approx(1 / 8)


@pytest.mark.parametrize("w_ob", [1, 2, 4, 8, 64])
def test_tflite_dw_below_one_sixth(w_ob):
    """Paper: T_tf < 1/6 even with the benefit-of-the-doubt variant."""
    assert it.t_tf_dw(w_ob) < 1 / 6


@pytest.mark.parametrize("hf,wf,lower", [(3, 3, 9 / 22), (5, 5, 25 / 54)])
def test_ours_dw_asymptotic_bound(hf, wf, lower):
    """Paper: T^DW = HfWf/((2+HfWf)*2) >= 9/22 for 3x3."""
    assert it.t_ours_dw_asymptotic(hf, wf) == pytest.approx(lower)
    assert it.t_ours_dw_asymptotic(hf, wf) >= 9 / 22 - 1e-12


def test_ours_dw_eq1_converges_to_asymptotic():
    full = it.t_ours_dw(3, 3, 2, 2, 112, 112)
    asym = it.t_ours_dw_asymptotic(3, 3)
    assert abs(full - asym) < 1e-3


def test_ours_dw_beats_tflite_by_paper_margin():
    # >= (9/22) / (1/6) = 2.45x better AI
    assert it.t_ours_dw_asymptotic(3, 3) / it.t_tf_dw(4) > 2.4


def test_rtrd_vs_rtra_ratio_approaches_1p5():
    """Paper: T_RTRD ~= 1.5 x T_RTRA for large Ci, Co."""
    r = it.t_rtrd_pw(ci=4096) / it.t_rtra_pw(co=4096)
    assert 1.45 < r < 1.55
    # and exact paper numbers at the paper's block sizes
    assert it.t_rtra_pw(8, 8, 4, co=10**9) == pytest.approx(4 / 3, rel=1e-6)
    assert it.t_rtrd_pw(8, 8, 4, ci=10**9) == pytest.approx(2.0, rel=1e-6)


def test_vmem_translation_rtrd_beats_rtra():
    """TPU-level: output-stationary traffic < A-stationary traffic for the
    paper's PWConv layer shapes (MobileNetV1 P2: G=12544, Ci=64, Co=128)."""
    rtrd = it.pwconv_traffic_rtrd(12544, 64, 128, 256, 256, 256)
    rtra = it.pwconv_traffic_rtra(12544, 64, 128, 256, 256, 256)
    assert rtrd.bytes_hbm < rtra.bytes_hbm
    assert rtrd.intensity > rtra.intensity


def test_dwconv_traffic_is_information_floor():
    t = it.dwconv2d_traffic(1, 112, 112, 32, 3, 3, 1)
    floor = 4 * (112 * 112 * 32 + 3 * 3 * 32 + 110 * 110 * 32)
    assert t.bytes_hbm == floor


def test_separable_fused_traffic_strictly_lower():
    """Fusion acceptance gate: for every MobileNet separable block in the
    roofline table, the fused kernel's modeled HBM bytes are STRICTLY lower
    than the unfused composition, and the gap equals the intermediate
    round-trip when the chooser lands on a single Co panel."""
    import os
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks.roofline_table import separable_fusion_rows

    rows = separable_fusion_rows()
    assert rows, "no separable blocks in the table"
    for r in rows:
        assert r["fusible"], r
        assert r["fused_mb"] < r["unfused_mb"], r
        assert r["ai_fused"] > r["ai_unfused"], r


def test_separable_fused_removes_intermediate_term():
    """Single-Co-panel case: unfused - fused >= one full intermediate
    round-trip (store + load of B*Ho*Wo*C)."""
    b, hi, wi, c, co = 1, 114, 114, 32, 64
    unf = it.separable_traffic_unfused(b, hi, wi, c, co, 3, 3, 1)
    fus = it.separable_traffic_fused(b, hi, wi, c, co, 3, 3, 1, block_co=co)
    inter_roundtrip = 4 * 2 * (b * 112 * 112 * c)  # store + 1 load (n_co=1)
    assert unf.bytes_hbm - fus.bytes_hbm >= inter_roundtrip
    assert unf.flops == fus.flops  # fusion moves bytes, not work


def test_fused_slab_bytes_below_unfused_at_hires():
    """Row-slab invariant (the point of the slab grid): at resolutions
    above the old ~1.5M-pixel ceiling the fused-with-slabs HBM bytes stay
    STRICTLY below the unfused composition — the halo re-read is far
    smaller than the intermediate round-trip it buys out."""
    from repro.kernels import blocking

    for h, c, co, stride in ((1504, 32, 32, 1), (1504, 32, 64, 2),
                             (2048, 16, 32, 1)):
        ho = -(-h // stride)
        hi = (ho - 1) * stride + 3
        plan = blocking.plan_separable(ho, ho, c, co, stride=stride)
        assert plan is not None and plan.n_slabs > 1
        unf = it.separable_traffic_unfused(1, hi, hi, c, co, 3, 3, stride)
        fus = it.separable_traffic_fused(
            1, hi, hi, c, co, 3, 3, stride,
            block_co=plan.block_co, slab_h=plan.slab_h)
        assert fus.bytes_hbm < unf.bytes_hbm, (h, c, co, stride)
        assert fus.intensity > unf.intensity


def test_slab_halo_bytes_counted_explicitly():
    """Slabbing is not free: the slabbed fused model must exceed the
    unslabbed one by at least the halo term, and the halo term must vanish
    when unslabbed or when stride >= Hf (disjoint windows)."""
    b, hi, c, co = 1, 1506, 32, 32
    base = it.separable_traffic_fused(b, hi, hi, c, co, 3, 3, 1, block_co=co)
    slab = it.separable_traffic_fused(b, hi, hi, c, co, 3, 3, 1,
                                      block_co=co, slab_h=8)
    n_slabs = -(-1504 // 8)
    halo = it.separable_slab_halo_bytes(b, hi, c, 3, 1, n_slabs)
    assert halo > 0
    assert slab.bytes_hbm >= base.bytes_hbm + halo
    assert slab.flops == base.flops       # slabbing moves bytes, not work
    assert it.separable_slab_halo_bytes(b, hi, c, 3, 1, 1) == 0
    assert it.separable_slab_halo_bytes(b, hi, c, 3, 3, n_slabs) == 0


def test_rowpar_traffic_exceeds_channelpar():
    """The paper's core-inscalability claim, as traffic: row-parallel
    partitioning moves strictly more bytes and the gap grows with p."""
    ours = it.dwconv2d_traffic(1, 56, 56, 128, 3, 3, 1)
    prev = None
    for p in (1, 2, 4, 8):
        tf = it.dwconv2d_traffic_rowpar(1, 56, 56, 128, 3, 3, 1, p=p)
        assert tf.bytes_hbm >= ours.bytes_hbm
        if prev is not None:
            assert tf.bytes_hbm >= prev
        prev = tf.bytes_hbm
