"""Per-kernel allclose sweeps vs the pure-jnp oracles (interpret=True)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.dwconv1d import dwconv1d_causal_pallas
from repro.kernels.dwconv2d import dwconv2d_pallas
from repro.kernels.pwconv import pwconv_pallas

RNG = np.random.default_rng(0)


def _arr(shape, dtype=np.float32, scale=1.0):
    return jnp.asarray((RNG.normal(size=shape) * scale).astype(dtype))


# ---------------------------------------------------------------------------
# dwconv2d
# ---------------------------------------------------------------------------

DW2D_CASES = [
    # (B, Hi, Wi, C, Hf, Wf, stride)
    (1, 8, 8, 4, 3, 3, 1),
    (2, 12, 9, 16, 3, 3, 2),
    (1, 16, 16, 32, 5, 5, 1),
    (2, 19, 23, 40, 3, 3, 2),
    (1, 7, 7, 130, 3, 3, 1),     # channel padding path (>128 lanes)
    (1, 14, 14, 8, 5, 5, 2),
]


@pytest.mark.parametrize("b,hi,wi,c,hf,wf,s", DW2D_CASES)
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_dwconv2d_matches_ref(b, hi, wi, c, hf, wf, s, dtype):
    x = _arr((b, hi, wi, c)).astype(dtype)
    f = _arr((hf, wf, c)).astype(dtype)
    got = dwconv2d_pallas(x, f, stride=s, interpret=True)
    want = ref.dwconv2d_ref(x, f, stride=s, padding="valid")
    tol = 1e-5 if dtype == np.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_dwconv2d_same_padding():
    x = _arr((2, 10, 11, 12))
    f = _arr((3, 3, 12))
    got = ops.dwconv2d(x, f, stride=1, padding="same", impl="pallas",
                       interpret=True)
    want = ref.dwconv2d_ref(x, f, stride=1, padding="same")
    assert got.shape == x.shape
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_dwconv2d_ref_matches_naive_loops():
    x = RNG.normal(size=(1, 9, 8, 6)).astype(np.float32)
    f = RNG.normal(size=(3, 3, 6)).astype(np.float32)
    naive = ref.dwconv2d_loops_ref(x, f, stride=2)
    lax_ = ref.dwconv2d_ref(jnp.asarray(x), jnp.asarray(f), stride=2,
                            padding="valid")
    np.testing.assert_allclose(naive, np.asarray(lax_), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# dwconv1d (causal)
# ---------------------------------------------------------------------------

DW1D_CASES = [
    (1, 16, 8, 4, 8, 8),
    (2, 100, 48, 4, 32, 16),
    (2, 64, 64, 3, 64, 64),     # single L block
    (1, 37, 20, 5, 8, 8),       # padding both dims
]


@pytest.mark.parametrize("b,l,d,k,bl,bd", DW1D_CASES)
def test_dwconv1d_matches_ref(b, l, d, k, bl, bd):
    x = _arr((b, l, d))
    f = _arr((k, d))
    got = dwconv1d_causal_pallas(x, f, block_l=bl, block_d=bd,
                                 interpret=True)
    want = ref.dwconv1d_causal_ref(x, f)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_dwconv1d_step_matches_full():
    b, l, d, k = 2, 20, 6, 4
    x = _arr((b, l, d))
    f = _arr((k, d))
    full = ref.dwconv1d_causal_ref(x, f)
    state = jnp.zeros((b, k - 1, d))
    outs = []
    for t in range(l):
        state, y = ref.dwconv1d_step_ref(state, x[:, t], f)
        outs.append(y)
    np.testing.assert_allclose(jnp.stack(outs, 1), full, rtol=1e-5,
                               atol=1e-5)


# ---------------------------------------------------------------------------
# pwconv (output-stationary GEMM)
# ---------------------------------------------------------------------------

PW_CASES = [
    (16, 16, 16, 8, 128, 128),
    (300, 200, 170, 128, 128, 64),
    (64, 256, 512, 64, 256, 128),
    (100, 100, 100, 128, 128, 128),   # all-pad path
]


@pytest.mark.parametrize("g,ci,co,bg,bco,bci", PW_CASES)
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_pwconv_matches_ref(g, ci, co, bg, bco, bci, dtype):
    x = _arr((g, ci)).astype(dtype)
    w = _arr((ci, co), scale=ci ** -0.5).astype(dtype)
    got = pwconv_pallas(x, w, block_g=bg, block_co=bco, block_ci=bci,
                        interpret=True)
    want = ref.pwconv_ref(x, w)
    tol = 1e-4 if dtype == np.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("act", [None, "relu", "relu6", "gelu", "silu"])
def test_pwconv_fused_epilogue(act):
    x = _arr((65, 48))
    w = _arr((48, 33), scale=0.1)
    bias = _arr((33,))
    got = pwconv_pallas(x, w, bias, activation=act, block_g=32,
                        block_co=128, block_ci=32, interpret=True)
    want = ref.pwconv_ref(x, w, bias=bias, activation=act)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_pwconv_nd_wrapper():
    x = _arr((2, 7, 5, 24))
    w = _arr((24, 16))
    got = ops.pwconv(x, w, impl="pallas", interpret=True, block_g=8,
                     block_co=128, block_ci=128)
    want = ref.pwconv_ref(x, w)
    assert got.shape == (2, 7, 5, 16)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_rtra_oracle_equals_matmul():
    a = _arr((45, 70))
    b = _arr((70, 31))
    np.testing.assert_allclose(ref.matmul_rtra_ref(a, b, block_k=32),
                               a @ b, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Edge geometry: block planner fallbacks, sub-128 Co padding, SAME + stride 2
# ---------------------------------------------------------------------------

from repro.kernels import blocking  # noqa: E402


def test_dwconv2d_tiny_block_execution_path():
    """The planner's power-of-two lane fallback (tests/test_blocking.py)
    must correspond to a correct kernel execution path at forced tiny
    blocks."""
    assert blocking.plan_dwconv2d(14, 14, 12, 12, 512).block_c == 512
    x = _arr((1, 9, 9, 12))
    f = _arr((3, 3, 12))
    got = dwconv2d_pallas(x, f, stride=1, block_c=2, interpret=True)
    want = ref.dwconv2d_ref(x, f, stride=1, padding="valid")
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("co", [1, 7, 33, 127])
def test_pwconv_co_smaller_than_128_padding(co):
    """Co < 128 forces lane padding of the output tile (bco=max(128,co));
    the unpadded slice must match the oracle exactly."""
    x = _arr((40, 64))
    w = _arr((64, co), scale=0.125)
    bias = _arr((co,), scale=0.1)
    got = pwconv_pallas(x, w, bias, activation="relu", interpret=True)
    want = ref.pwconv_ref(x, w, bias=bias, activation="relu")
    assert got.shape == (40, co)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("hi,wi,hf", [(11, 13, 3), (14, 14, 5), (7, 9, 3)])
def test_dwconv2d_same_padding_stride2(hi, wi, hf):
    """SAME + stride 2: odd/even spatial sizes hit asymmetric pad splits and
    the VALID-remainder crop inside the kernel wrapper."""
    c = 10
    x = _arr((2, hi, wi, c))
    f = _arr((hf, hf, c))
    got = ops.dwconv2d(x, f, stride=2, padding="same", impl="pallas",
                       interpret=True)
    want = ref.dwconv2d_ref(x, f, stride=2, padding="same")
    assert got.shape == want.shape == (2, -(-hi // 2), -(-wi // 2), c)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Property-based invariants (hypothesis)
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(
    c=st.integers(1, 24),
    hf=st.sampled_from([1, 3, 5]),
    s=st.sampled_from([1, 2]),
    seed=st.integers(0, 2**31 - 1),
)
def test_dwconv2d_linearity(c, hf, s, seed):
    """DWConv is linear in the input: f(ax+by) == a f(x) + b f(y)."""
    r = np.random.default_rng(seed)
    hi = hf + 4
    x = jnp.asarray(r.normal(size=(1, hi, hi, c)).astype(np.float32))
    y = jnp.asarray(r.normal(size=(1, hi, hi, c)).astype(np.float32))
    f = jnp.asarray(r.normal(size=(hf, hf, c)).astype(np.float32))
    lhs = dwconv2d_pallas(2.0 * x + 3.0 * y, f, stride=s, interpret=True)
    rhs = (2.0 * dwconv2d_pallas(x, f, stride=s, interpret=True)
           + 3.0 * dwconv2d_pallas(y, f, stride=s, interpret=True))
    np.testing.assert_allclose(lhs, rhs, rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), shift=st.integers(1, 3))
def test_dwconv1d_shift_equivariance(seed, shift):
    """Causal depthwise conv commutes with time shift (zero boundary)."""
    r = np.random.default_rng(seed)
    b, l, d, k = 1, 24, 4, 3
    x = jnp.asarray(r.normal(size=(b, l, d)).astype(np.float32))
    f = jnp.asarray(r.normal(size=(k, d)).astype(np.float32))
    xs = jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, :l]
    y = dwconv1d_causal_pallas(x, f, block_l=8, block_d=4, interpret=True)
    ys = dwconv1d_causal_pallas(xs, f, block_l=8, block_d=4, interpret=True)
    np.testing.assert_allclose(
        ys[:, shift:], y[:, : l - shift], rtol=1e-4, atol=1e-4
    )


@settings(max_examples=10, deadline=None)
@given(
    g=st.integers(1, 40),
    ci=st.integers(1, 40),
    co=st.integers(1, 40),
    seed=st.integers(0, 2**31 - 1),
)
def test_pwconv_matches_matmul_any_shape(g, ci, co, seed):
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.normal(size=(g, ci)).astype(np.float32))
    w = jnp.asarray(r.normal(size=(ci, co)).astype(np.float32))
    got = pwconv_pallas(x, w, block_g=16, block_co=128, block_ci=16,
                        interpret=True)
    np.testing.assert_allclose(got, x @ w, rtol=2e-4, atol=2e-4)
