"""Per-architecture smoke tests (reduced configs): forward/train step on CPU
with shape + finiteness assertions, and prefill/decode logit parity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES, input_specs, shape_skip_reason
from repro.configs.registry import ARCH_IDS, get_config
from repro.models import transformer as T
from repro.models.layers import unembed_logits
from repro.serve import serve_step as S

B, SQ = 2, 16


def _batch(cfg, key=1):
    tokens = jax.random.randint(jax.random.PRNGKey(key), (B, SQ), 0,
                                cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.family == "vlm" or (cfg.fusion_tokens and cfg.family == "moe"):
        batch["frontend"] = 0.1 * jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.fusion_tokens, cfg.d_model),
            cfg.jax_dtype)
    if cfg.encdec is not None:
        batch["frontend"] = 0.1 * jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.encdec.enc_seq, cfg.d_model),
            cfg.jax_dtype)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_loss(arch):
    cfg = get_config(arch, smoke=True)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    x, prefix, aux = T.hidden_states(cfg, params, batch["tokens"],
                                     frontend=batch.get("frontend"))
    assert x.shape == (B, prefix + SQ, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(x.astype(jnp.float32))))
    loss, metrics = jax.jit(lambda p, b: T.loss_fn(cfg, p, b))(params, batch)
    assert bool(jnp.isfinite(loss))
    # random-init loss should be ~ln(V)
    assert abs(float(metrics["nll"]) - np.log(cfg.vocab_size)) < 1.5


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    from repro.train.train_step import TrainConfig, init_train_state, \
        make_train_step
    cfg = get_config(arch, smoke=True)
    tcfg = TrainConfig(microbatches=2)
    state = init_train_state(cfg, tcfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, tcfg))
    batch = _batch(cfg)
    state, m1 = step(state, batch)
    state, m2 = step(state, batch)
    assert bool(jnp.isfinite(m2["loss"]))
    assert float(m2["grad_norm"]) > 0
    # two steps on the same batch should reduce its loss
    assert float(m2["loss"]) < float(m1["loss"]) + 1e-3
    assert int(state["opt"]["step"]) == 2


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_parity(arch):
    cfg = get_config(arch, smoke=True)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    tokens = batch["tokens"]
    x, prefix, _ = T.hidden_states(cfg, params, tokens,
                                   frontend=batch.get("frontend"))
    table = params["embedding" if cfg.tie_embeddings else "unembed"]["table"]
    ref = unembed_logits(x[:, prefix:], table)
    half = SQ // 2
    last, cache = S.prefill(cfg, params, tokens[:, :half], max_len=64,
                            frontend=batch.get("frontend"))
    errs = [float(jnp.abs(last - ref[:, half - 1]).max())]
    for t in range(half, SQ):
        logits, cache = S.decode_step(cfg, params, cache, tokens[:, t:t + 1])
        errs.append(float(jnp.abs(logits - ref[:, t]).max()))
    assert max(errs) < 5e-4, errs


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_input_specs_cover_all_shapes(arch):
    cfg = get_config(arch)
    for shape in SHAPES:
        if shape_skip_reason(cfg, shape):
            continue
        specs = input_specs(cfg, shape)
        assert "tokens" in specs
        meta = SHAPES[shape]
        if meta["kind"] == "decode":
            assert specs["tokens"].shape == (meta["global_batch"], 1)
        else:
            assert specs["tokens"].shape == (meta["global_batch"],
                                             meta["seq_len"])


def test_long_500k_skips_are_exactly_the_full_attention_archs():
    skipped = {a for a in ARCH_IDS
               if shape_skip_reason(get_config(a), "long_500k")}
    assert skipped == set(ARCH_IDS) - {"xlstm-125m", "hymba-1.5b"}


def test_param_count_sanity():
    """Analytical counts close to the names on the tin."""
    expect = {
        "xlstm-125m": (0.05e9, 0.25e9),
        "smollm-360m": (0.3e9, 0.45e9),
        "qwen3-1.7b": (1.4e9, 2.1e9),
        "command-r-35b": (25e9, 40e9),
        "qwen1.5-110b": (95e9, 125e9),
        "qwen3-moe-235b-a22b": (210e9, 260e9),
        "llama4-maverick-400b-a17b": (350e9, 450e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).n_params()
        assert lo < n < hi, (arch, n)


def test_int8_kv_cache_decode_accuracy():
    """Quantized KV cache tracks the full forward within ~1% rel error."""
    import dataclasses
    cfg = get_config("smollm-360m", smoke=True)
    cfgq = dataclasses.replace(cfg, kv_quant=True)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, 12), 0,
                                cfg.vocab_size)
    x, prefix, _ = T.hidden_states(cfg, params, tokens)
    ref = unembed_logits(x, params["embedding"]["table"])
    cache = S.init_cache(cfgq, B, 64)
    assert cache["v0"]["k"].dtype == jnp.int8
    for t in range(12):
        logits, cache = S.decode_step(cfgq, params, cache,
                                      tokens[:, t:t + 1])
        rel = float(jnp.abs(logits - ref[:, t]).max()
                    / jnp.abs(ref[:, t]).max())
        assert rel < 0.03, (t, rel)


def test_smoke_params_match_analytical_scaling():
    """Smoke config param count within 2x of the analytical formula (the
    formula ignores norms/small biases)."""
    for arch in ARCH_IDS:
        cfg = get_config(arch, smoke=True)
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        real = sum(x.size for x in jax.tree_util.tree_leaves(params))
        pred = cfg.n_params()
        assert 0.4 < real / pred < 2.5, (arch, real, pred)
