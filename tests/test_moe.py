"""MoE: EP path vs dense oracle, routing invariants, capacity accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import MoEConfig
from repro.launch.mesh import make_mesh_compat
from repro.models import moe


def _setup(e=8, k=2, d=24, cap=8.0, n_shared=0, seed=0):
    cfg = MoEConfig(n_experts=e, top_k=k, d_ff_expert=32, n_shared=n_shared,
                    capacity_factor=cap)
    p = moe.init_moe(jax.random.PRNGKey(seed), d, cfg, d_ff_shared=48)
    return cfg, p


def test_ep_matches_dense_high_capacity():
    cfg, p = _setup(cap=8.0, n_shared=1)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 24))
    y_ref, aux_ref = moe.moe_dense_ref(p, x, cfg)
    y_ep, aux_ep = moe.moe_forward(p, x, cfg)
    np.testing.assert_allclose(y_ref, y_ep, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(aux_ref["aux_loss"], aux_ep["aux_loss"],
                               rtol=1e-6)
    assert float(aux_ep["drop_frac"]) == 0.0


def test_ep_matches_dense_through_shard_map_1dev():
    cfg, p = _setup(cap=8.0)
    mesh = make_mesh_compat((1,), ("model",))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 24))
    y_ref, _ = moe.moe_dense_ref(p, x, cfg)
    y_sm, _ = moe.moe_forward(p, x, cfg, mesh=mesh, data_axes=(),
                              model_axis="model", shard_seq=False)
    np.testing.assert_allclose(y_ref, y_sm, rtol=1e-5, atol=1e-5)


def test_capacity_drops_are_reported():
    """With capacity << need, drop_frac > 0 and outputs stay finite."""
    cfg, p = _setup(e=2, k=2, cap=0.10)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 24))
    y, aux = moe.moe_forward(p, x, cfg)
    assert float(aux["drop_frac"]) > 0.0
    assert bool(jnp.all(jnp.isfinite(y)))


def test_router_topk_normalized():
    logits = jax.random.normal(jax.random.PRNGKey(0), (10, 8))
    w, ids, probs = moe.router_topk(logits, 3, norm_topk=True)
    np.testing.assert_allclose(w.sum(-1), 1.0, rtol=1e-6)
    assert ids.shape == (10, 3)
    # ids are the argmax-k of probs
    expect = jnp.argsort(-probs, axis=-1)[:, :3]
    assert jnp.array_equal(jnp.sort(ids, -1), jnp.sort(expect, -1))


def test_load_balance_loss_uniform_is_one():
    """Perfectly uniform routing gives aux loss == 1 (Switch normalization)."""
    t, e, k = 64, 8, 1
    probs = jnp.full((t, e), 1.0 / e)
    ids = jnp.arange(t)[:, None] % e
    val = moe.load_balance_loss(probs, ids, e)
    np.testing.assert_allclose(val, 1.0, rtol=1e-5)


def test_ranks_by_group():
    ids = jnp.asarray([0, 1, 0, 2, 1, 0])
    ranks = moe._ranks_by_group(ids, 3)
    np.testing.assert_array_equal(ranks, [0, 0, 1, 0, 1, 2])


def test_grads_flow_through_dispatch():
    cfg, p = _setup(cap=8.0)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 24))

    def loss(p):
        y, aux = moe.moe_forward(p, x, cfg)
        return jnp.sum(y ** 2) + aux["aux_loss"]

    g = jax.grad(loss)(p)
    for name in ("router", "w_gate_e", "w_up_e", "w_down_e"):
        leaf = g[name]["w"] if name == "router" else g[name]
        assert float(jnp.linalg.norm(leaf)) > 0, name


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_token_permutation_equivariance(seed):
    """Permuting tokens permutes outputs (routing is per-token)."""
    cfg, p = _setup(cap=8.0)
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.normal(size=(1, 12, 24)).astype(np.float32))
    perm = jnp.asarray(r.permutation(12))
    y1, _ = moe.moe_forward(p, x, cfg)
    y2, _ = moe.moe_forward(p, x[:, perm], cfg)
    np.testing.assert_allclose(y1[:, perm], y2, rtol=1e-4, atol=1e-4)


def test_expert_parallel_multidevice_subprocess():
    """Real 4-device EP all_to_all == dense oracle (subprocess w/ fake devs)."""
    import os
    import subprocess
    import sys
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import MoEConfig
from repro.launch.mesh import make_mesh_compat
from repro.models import moe
cfg = MoEConfig(n_experts=8, top_k=2, d_ff_expert=32, n_shared=0,
                capacity_factor=8.0)
p = moe.init_moe(jax.random.PRNGKey(0), 24, cfg, 48)
x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 24))
mesh = make_mesh_compat((2, 2), ("data", "model"))
y_ref, _ = moe.moe_dense_ref(p, x, cfg)
with mesh:
    fn = jax.jit(lambda p, x: moe.moe_forward(
        p, x, cfg, mesh=mesh, data_axes=("data",), model_axis="model",
        shard_seq=True)[0])
    y = fn(p, x)
np.testing.assert_allclose(y_ref, y, rtol=1e-4, atol=1e-4)
print("EP-4dev-OK")
"""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, cwd=os.path.dirname(
                             os.path.dirname(os.path.abspath(__file__))),
                         env=env, timeout=300)
    assert "EP-4dev-OK" in out.stdout, out.stdout + out.stderr
