"""Whole-network chain engine (core/network.py, DESIGN.md §7): backbone
specs, one-shot planning, single-jit execution, per-segment mixed-precision
streaming, traffic ordering, and the network-level tune cache."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import chain, network
from repro.core import intensity as it
from repro.kernels.policy import DtypePolicy, KernelPolicy

BF16_REL_TOL = 5e-2  # documented in DESIGN.md §7 and examples/

XLA = KernelPolicy(impl="xla")
PAL = KernelPolicy(impl="pallas", interpret=True)


def _tiny_net(c_in=8):
    """A 3-block mixed net (V1-style block, inverted residual, t=1 block)
    small enough for interpret-mode pallas."""
    return network.NetworkSpec(name="tiny", c_in=c_in, blocks=(
        chain.separable_block_spec(16, stride=1),
        chain.inverted_residual_spec(16, 16, expand=2, stride=1),
        chain.SeparableSpec(stages=(
            chain.DW(stride=2, activation="relu6"),
            chain.PW(24),
        ), residual="auto"),
    ))


def _run_blocks(net, params, x, policy):
    """The pre-network-engine oracle: a Python loop of chain.execute."""
    for spec, p in zip(net.blocks, params):
        x = chain.execute(spec, p, x, policy=policy)
    return x


# ---------------------------------------------------------------------------
# spec construction
# ---------------------------------------------------------------------------

def test_mobilenet_v1_spec_geometry():
    net = network.mobilenet_v1_spec()
    assert net.n_blocks == 13
    assert net.c_in == 32
    assert net.out_channels() == 1024
    assert net.stride_product() == 16  # 4 stride-2 DWs in the body
    assert all(len(b.stages) == 2 for b in net.blocks)


def test_mobilenet_v2_spec_geometry():
    net = network.mobilenet_v2_spec()
    assert net.n_blocks == sum(n for _, _, n, _ in network.MOBILENET_V2_BODY)
    assert net.n_blocks == 17
    assert net.c_in == 32
    assert net.out_channels() == 320
    # first (t=1) row has no expansion GEMM; every other block is 3-stage
    assert len(net.blocks[0].stages) == 2
    assert all(len(b.stages) == 3 for b in net.blocks[1:])


def test_width_mult_rounds_to_multiple_of_8():
    net = network.mobilenet_v2_spec(width_mult=0.75)
    assert net.c_in == 24
    c = net.c_in
    for b in net.blocks:
        c = b.out_channels(c)
        assert c % 8 == 0
    assert net.name == "mobilenet_v2_0.75"


# ---------------------------------------------------------------------------
# planning
# ---------------------------------------------------------------------------

def test_v2_network_plans_every_block_single_pass():
    net = network.mobilenet_v2_spec()
    for dp in (DtypePolicy(), DtypePolicy(stream="bfloat16")):
        nplan = network.plan_network(
            net, (1, 112, 112, net.c_in),
            policy=KernelPolicy(dtype_policy=dp))
        assert nplan.fully_fused
        assert nplan.n_kernel_passes == net.n_blocks
        histo = nplan.segment_histogram()
        assert histo == {"fused2": 1, "fused3": 16}
        # every inverted residual -> the 3-stage fused kernel
        for spec, p in zip(net.blocks, nplan.plans):
            if len(spec.stages) == 3:
                assert p.segments[0].kind == "fused3"


def test_plan_walks_shapes_and_dtypes():
    net = _tiny_net()
    pol = KernelPolicy(dtype_policy=DtypePolicy(stream="bfloat16",
                                                out="float32"))
    nplan = network.plan_network(net, (2, 16, 16, 8), policy=pol)
    assert nplan.block_shapes == ((2, 16, 16, 8), (2, 16, 16, 16),
                                  (2, 16, 16, 16))
    assert nplan.out_shape == (2, 8, 8, 24)
    # inner handoffs happen at the stream width; only the last block's
    # policy keeps the out pin (resolve_block_policies broadcast rule)
    assert nplan.block_dtypes == ("float32", "bfloat16", "bfloat16")
    pols = network.resolve_block_policies(net, pol)
    assert [p.dtype_policy.out for p in pols] == [None, None, "float32"]
    # bf16-budgeted plans: stream width drives dtype_bytes
    assert all(p.dtype_bytes == 2 for p in nplan.plans)


def test_network_key_sensitivity():
    net = _tiny_net()
    shape = (1, 16, 16, 8)
    k = network.network_key(net, shape, jnp.float32, XLA)
    k_bf = network.network_key(
        net, shape, jnp.float32,
        dataclasses.replace(XLA,
                            dtype_policy=DtypePolicy(stream="bfloat16")))
    k_shape = network.network_key(net, (1, 32, 32, 8), jnp.float32, XLA)
    other = dataclasses.replace(net, blocks=net.blocks[:2])
    k_spec = network.network_key(other, shape, jnp.float32, XLA)
    assert len({k, k_bf, k_shape, k_spec}) == 4
    assert all(s.startswith("net:") for s in (k, k_bf, k_shape, k_spec))


def test_plan_called_once_per_block(monkeypatch):
    """execute_network memoizes (plan, jitted fn): two calls -> exactly
    n_blocks chain.plan invocations and ONE trace."""
    net = _tiny_net()
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 16, 8))
    params = network.init_network(jax.random.PRNGKey(0), net)
    network.clear_network_cache()

    plan_calls = []
    real_plan = chain.plan
    monkeypatch.setattr(network.chain, "plan",
                        lambda *a, **k: (plan_calls.append(1),
                                         real_plan(*a, **k))[1])
    traces = []
    real_build = network.build_network_fn

    def counting_build(*a, **k):
        run = real_build(*a, **k)

        def wrapped(params, x):
            traces.append(1)  # appended only at trace time under jit
            return run(params, x)
        return wrapped

    monkeypatch.setattr(network, "build_network_fn", counting_build)

    y1 = network.execute_network(net, params, x, policy=XLA)
    y2 = network.execute_network(net, params, x, policy=XLA)
    assert len(plan_calls) == net.n_blocks
    assert len(traces) == 1
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))


# ---------------------------------------------------------------------------
# execution parity
# ---------------------------------------------------------------------------

def test_fp32_network_bitwise_vs_per_block_loop():
    net = network.mobilenet_v1_spec()
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 8, net.c_in))
    params = network.init_network(jax.random.PRNGKey(0), net)
    got = network.execute_network(net, params, x, policy=XLA)
    ref = _run_blocks(net, params, x, XLA)
    assert got.dtype == jnp.float32
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


@pytest.mark.parametrize("make_spec,res", [
    (network.mobilenet_v1_spec, 8),
    (network.mobilenet_v2_spec, 16),
])
def test_bf16_network_parity_vs_fp32_oracle(make_spec, res):
    net = make_spec()
    x = jax.random.normal(jax.random.PRNGKey(1), (1, res, res, net.c_in))
    params = network.init_network(jax.random.PRNGKey(0), net)
    pol = KernelPolicy(dtype_policy=DtypePolicy(stream="bfloat16"))
    got = network.execute_network(
        net, network.cast_network_params(params, jnp.bfloat16), x,
        policy=pol)
    assert got.dtype == jnp.bfloat16
    ref = np.asarray(_run_blocks(net, params, x, XLA), np.float32)
    rel = np.abs(np.asarray(got, np.float32) - ref).max() / np.abs(ref).max()
    assert rel < BF16_REL_TOL, rel


def test_out_pin_restores_fp32_at_network_output():
    net = _tiny_net()
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 16, 8))
    params = network.init_network(jax.random.PRNGKey(0), net)
    pol = KernelPolicy(dtype_policy=DtypePolicy(stream="bfloat16",
                                                out="float32"))
    y = network.execute_network(net, params, x, policy=pol)
    assert y.dtype == jnp.float32
    ref = np.asarray(_run_blocks(net, params, x, XLA), np.float32)
    rel = np.abs(np.asarray(y) - ref).max() / np.abs(ref).max()
    assert rel < BF16_REL_TOL, rel


def test_per_block_dtype_policies():
    """Mixed per-block precision: first block fp32, rest bf16-streamed."""
    net = _tiny_net()
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 16, 8))
    params = network.init_network(jax.random.PRNGKey(0), net)
    dps = (DtypePolicy(),
           DtypePolicy(stream="bfloat16"),
           DtypePolicy(stream="bfloat16", out="float32"))
    nplan = network.plan_network(net, x.shape, policy=XLA,
                                 block_dtype_policies=dps)
    assert [p.dtype_bytes for p in nplan.plans] == [4, 2, 2]
    assert nplan.block_dtypes == ("float32", "float32", "bfloat16")
    y = network.execute_network(net, params, x, policy=XLA,
                                block_dtype_policies=dps)
    assert y.dtype == jnp.float32
    ref = np.asarray(_run_blocks(net, params, x, XLA), np.float32)
    rel = np.abs(np.asarray(y) - ref).max() / np.abs(ref).max()
    assert rel < BF16_REL_TOL, rel


def test_pallas_interpret_matches_xla():
    net = _tiny_net()
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 8, 8))
    params = network.init_network(jax.random.PRNGKey(0), net)
    got = network.execute_network(net, params, x, policy=PAL)
    ref = network.execute_network(net, params, x, policy=XLA)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# traffic model
# ---------------------------------------------------------------------------

def test_network_traffic_ordering_bf16_fp32_unfused():
    for net in (network.mobilenet_v1_spec(), network.mobilenet_v2_spec()):
        shape = (1, 56, 56, net.c_in)
        t32 = it.network_traffic(
            net, network.plan_network(net, shape, policy=KernelPolicy()))
        tbf = it.network_traffic(
            net, network.plan_network(
                net, shape, policy=KernelPolicy(
                    dtype_policy=DtypePolicy(stream="bfloat16"))))
        tunf = it.network_traffic(
            net, network.plan_network(net, shape,
                                      policy=KernelPolicy(fused=False)))
        assert tbf.bytes_hbm < t32.bytes_hbm < tunf.bytes_hbm
        assert tbf.flops == t32.flops  # dtype streaming moves bytes only


# ---------------------------------------------------------------------------
# network-level tune cache
# ---------------------------------------------------------------------------

def test_tune_network_then_replay(tmp_path):
    net = network.NetworkSpec(name="tune2", c_in=8, blocks=(
        chain.separable_block_spec(8),
        chain.inverted_residual_spec(8, 8, expand=2),
    ))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 8, 8))
    params = network.init_network(jax.random.PRNGKey(0), net)
    pol = KernelPolicy(impl="pallas", interpret=True, autotune=True,
                       tune_cache=str(tmp_path / "tune.json"))
    r1 = network.tune_network(net, params, x, policy=pol, repeats=1)
    assert not r1.cache_hit and r1.n_measured > 0
    r2 = network.tune_network(net, params, x, policy=pol, repeats=1)
    assert r2.cache_hit and r2.n_measured == 0
    assert r2.plan == r1.plan
    # plan_network consults the same network entry
    replay = network.plan_network(net, x.shape, policy=pol)
    assert replay == r1.plan
    # execution with the tuned plan matches the untuned path
    network.clear_network_cache()
    got = network.execute_network(net, params, x, policy=pol)
    ref = network.execute_network(
        net, params, x, policy=dataclasses.replace(pol, autotune=False))
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-6, atol=1e-6)
