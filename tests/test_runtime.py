"""repro.runtime (DESIGN.md §9): failure taxonomy, fault injection, the
degradation ladder, plan quarantine persistence, and fallback telemetry.

Everything runs on CPU via the deterministic fault-injection harness — the
ladder rungs, quarantine round-trips and numeric guards that only real
hardware failures would otherwise exercise.
"""
import dataclasses
import os
import subprocess
import sys
import types
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import chain, network
from repro.kernels import autotune
from repro.kernels.diskstore import VersionedJsonStore
from repro.kernels.policy import DtypePolicy, KernelPolicy
from repro.runtime import (executor, failures, faultinject, ladder,
                           quarantine, telemetry)

BF16_REL_TOL = 5e-2


@pytest.fixture(autouse=True)
def _clean_runtime():
    faultinject.disarm_all()
    telemetry.reset_runtime_telemetry()
    quarantine.clear_memo()
    network.clear_network_cache()
    yield
    faultinject.disarm_all()
    telemetry.reset_runtime_telemetry()
    quarantine.clear_memo()
    network.clear_network_cache()


def _pol(tmp_path, **kw):
    """Policy pinning the tune cache (and therefore the quarantine store)
    inside the test's tmp dir."""
    return KernelPolicy(impl="xla", tune_cache=str(tmp_path / "tune.json"),
                        **kw)


def _ir_spec():
    return chain.inverted_residual_spec(c_in=8, c_out=8, expand=2)


def _chain_data(spec):
    params = chain.init_chain(jax.random.PRNGKey(0), spec, 8)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 8, 8))
    return params, x


def _tiny_net():
    return network.NetworkSpec(name="tiny3", c_in=8, blocks=(
        chain.separable_block_spec(16),
        chain.inverted_residual_spec(16, 16, expand=2),
        chain.separable_block_spec(8, stride=2),
    ))


def _oracle_chain(spec, params, x, pol):
    with faultinject.suppressed():
        return np.asarray(chain.execute(
            spec, params, x,
            policy=dataclasses.replace(pol, impl="xla", on_failure="raise",
                                       numeric_guard=False,
                                       dtype_policy=DtypePolicy())),
            np.float32)


def _ban(pol, spec, shape, dtype, *bans):
    """Pre-seed the policy's quarantine store with bans for this problem."""
    qp = quarantine.quarantine_path(pol)
    q = quarantine.Quarantine.load(qp)
    key = autotune.problem_key(spec, shape, dtype, pol)
    for b in bans:
        q.add_failure(key, signature={}, ban=b,
                      failure={"kind": "test", "message": "seeded"})
    q.save()
    return key


# ---------------------------------------------------------------------------
# failures.classify: whitelist taxonomy
# ---------------------------------------------------------------------------

def test_classify_whitelist():
    assert failures.classify(ValueError("same")) is None
    assert failures.classify(TypeError("x")) is None
    assert failures.classify(AssertionError("x")) is None
    f = failures.classify(RuntimeError("Mosaic lowering failed: op"))
    assert isinstance(f, failures.LoweringFailure)
    f = failures.classify(NotImplementedError("no lowering rule"))
    assert isinstance(f, failures.LoweringFailure)
    f = failures.classify(RuntimeError("RESOURCE_EXHAUSTED: out of memory"))
    assert isinstance(f, failures.CompileFailure)
    assert isinstance(failures.classify(MemoryError()),
                      failures.CompileFailure)


def test_classify_tags_and_passthrough():
    f = failures.classify(RuntimeError("pallas failure"),
                          segment_kind="fused3", segment_index=0,
                          stage_indices=(0, 1, 2))
    assert (f.segment_kind, f.segment_index, f.stage_indices) == \
        ("fused3", 0, (0, 1, 2))
    assert isinstance(f.original, RuntimeError)
    # passthrough: an already-tagged failure keeps its tags
    g = failures.classify(f, segment_kind="pw", segment_index=9)
    assert g is f and g.segment_kind == "fused3"
    d = f.describe()
    assert d["kind"] == "lowering" and d["segment_kind"] == "fused3"


def test_plan_verification_error_never_classified():
    from repro.analysis import PlanVerificationError, Report
    assert failures.classify(PlanVerificationError(Report())) is None


# ---------------------------------------------------------------------------
# faultinject: determinism, suppression, CLI spec parsing
# ---------------------------------------------------------------------------

def test_arm_unknown_point_raises():
    with pytest.raises(ValueError, match="unknown injection point"):
        faultinject.arm("lowering:nope")


def test_times_and_fired_counts():
    faultinject.arm("compile:chain", times=2)
    for _ in range(2):
        with pytest.raises(failures.InjectedFault):
            faultinject.check("compile:chain")
    faultinject.check("compile:chain")  # exhausted: no-op
    assert faultinject.fired_counts()["compile:chain"] == 2
    assert faultinject.armed_points() == ()


def test_suppressed_blocks_firing():
    faultinject.arm("compile:chain", times=faultinject.PERSISTENT)
    with faultinject.suppressed():
        faultinject.check("compile:chain")
    with pytest.raises(failures.InjectedFault):
        faultinject.check("compile:chain")


def test_arm_from_spec():
    pts = faultinject.arm_from_spec(
        "lowering:pwconv, compile:network:3 ,numeric:chain")
    assert pts == ("lowering:pwconv", "compile:network", "numeric:chain")
    assert faultinject._faults["compile:network"].times == 3
    assert faultinject._faults["lowering:pwconv"].times == \
        faultinject.PERSISTENT


def test_injected_context_disarms():
    with faultinject.injected("compile:chain"):
        assert "compile:chain" in faultinject.armed_points()
    assert faultinject.armed_points() == ()


# ---------------------------------------------------------------------------
# ladder semantics
# ---------------------------------------------------------------------------

def test_ladder_rung_mapping(tmp_path):
    pol = _pol(tmp_path)
    spec = _ir_spec()
    cp = chain.plan(spec, (1, 8, 8, 8), policy=pol)
    assert ladder.plan_rung(cp) == "fused3"
    f3 = failures.LoweringFailure("x", segment_kind="fused3")
    pw = failures.LoweringFailure("x", segment_kind="pw")
    untagged = failures.CompileFailure("x")
    assert ladder.ban_for_failure(f3) == "fused3"
    assert ladder.ban_for_failure(pw) == "unfused"
    assert ladder.ban_for_failure(untagged, cp) == "fused3"
    # DESIGN §10 stage-algebra rungs map to themselves / unfused too
    assert ladder.ban_for_failure(
        failures.LoweringFailure("x", segment_kind="fusedmb")) == "fusedmb"
    assert ladder.ban_for_failure(
        failures.LoweringFailure("x", segment_kind="dw_se")) == "dw_se"
    assert ladder.ban_for_failure(
        failures.LoweringFailure("x", segment_kind="se")) == "unfused"
    assert ladder.ban_for_failure(
        failures.LoweringFailure("x", segment_kind="mb")) == "unfused"
    assert ladder.next_rung("fused3", {"fused3"}) == "fusedmb"
    assert ladder.next_rung("fusedmb", {"fusedmb"}) == "fused2"
    assert ladder.next_rung("fused2", {"fused3", "fused2"}) == "dw_se"
    assert ladder.next_rung("dw_se", {"dw_se"}) == "unfused"
    assert ladder.next_rung("unfused", {"unfused"}) == "ref"


# ---------------------------------------------------------------------------
# diskstore satellites: warn-on-corrupt load, merge-on-write save
# ---------------------------------------------------------------------------

def test_corrupt_store_warns_and_recovers(tmp_path):
    path = str(tmp_path / "tune.json")
    with open(path, "w") as f:
        f.write("{not json")
    with pytest.warns(UserWarning, match="could not read"):
        cache = autotune.TuneCache.load(path)
    assert cache.entries == {}
    cache.put("k", {"v": 1})
    cache.save()  # must not warn/raise: save re-reads with warn=False
    assert autotune.TuneCache.load(path).get("k") == {"v": 1}


def test_merge_on_write_preserves_concurrent_entries(tmp_path):
    path = str(tmp_path / "tune.json")
    a = autotune.TuneCache.load(path)
    b = autotune.TuneCache.load(path)
    a.put("ka", {"v": "a"})
    a.save()
    b.put("kb", {"v": "b"})
    b.save()  # must union with a's entry, not clobber the file
    c = autotune.TuneCache.load(path)
    assert c.get("ka") == {"v": "a"} and c.get("kb") == {"v": "b"}


def test_version_gate_reads_other_version_as_empty(tmp_path):
    path = str(tmp_path / "store.json")

    class V9(VersionedJsonStore):
        version = 9

    s = V9(path)
    s.put("k", {"v": 1})
    s.save()
    assert VersionedJsonStore.load(path).entries == {}  # version 1 reader
    assert V9.load(path).get("k") == {"v": 1}


def test_quarantine_store_roundtrip(tmp_path):
    path = str(tmp_path / "quarantine.json")
    q = quarantine.Quarantine.load(path)
    q.add_failure("k1", signature={"s": 1}, ban="fused3",
                  failure={"kind": "lowering"})
    q.add_failure("k1", signature={"s": 1}, ban="unfused",
                  failure={"kind": "compile"})
    with pytest.raises(AssertionError):
        q.add_failure("k1", signature={}, ban="ref", failure={})
    q.save()
    q2 = quarantine.Quarantine.load(path)
    assert q2.banned("k1") == frozenset({"fused3", "unfused"})
    assert q2.banned("missing") == frozenset()
    assert len(q2.entries["k1"]["failures"]) == 2


# ---------------------------------------------------------------------------
# measure_run satellites: transient retry, outlier discard
# ---------------------------------------------------------------------------

def test_measure_run_retries_transient():
    state = {"raised": False}

    def run(p, x):
        if not state["raised"]:
            state["raised"] = True
            raise RuntimeError("RESOURCE_EXHAUSTED: transient")
        return x

    x = jnp.ones((4,))
    with pytest.warns(UserWarning, match="transient"):
        t = autotune.measure_run(run, None, x, warmup=1, repeats=3)
    assert t >= 0.0


def test_measure_run_bounded_retries_then_raises():
    def run(p, x):
        raise RuntimeError("RESOURCE_EXHAUSTED: always")

    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        with pytest.raises(RuntimeError, match="RESOURCE_EXHAUSTED"):
            autotune.measure_run(run, None, jnp.ones((4,)), retries=1)


def test_measure_run_unrecognized_propagates_immediately():
    def run(p, x):
        raise AssertionError("a genuine bug")

    with pytest.raises(AssertionError, match="genuine bug"):
        autotune.measure_run(run, None, jnp.ones((4,)))


def test_measure_run_discards_straggler_first_sample(monkeypatch):
    # deltas: first timed sample 1.0s, the rest 0.01s -> the straggler is
    # >10x the median of the rest and must be discarded
    seq = iter([0.0, 1.0, 1.0, 1.01, 1.01, 1.02, 1.02, 1.03, 1.03, 1.04])
    monkeypatch.setattr(autotune, "time",
                        types.SimpleNamespace(perf_counter=lambda:
                                              next(seq)))
    t = autotune.measure_run(lambda p, x: x, None, jnp.ones((4,)),
                             warmup=1, repeats=5)
    assert t == pytest.approx(0.01, rel=1e-6)


# ---------------------------------------------------------------------------
# autotune_chain: failed candidates folded, all-fail unpersisted
# ---------------------------------------------------------------------------

def test_autotune_folds_failed_candidate(tmp_path, monkeypatch):
    spec = chain.SeparableSpec((chain.PW(16),))
    params, x = _chain_data(spec)
    pol = _pol(tmp_path, autotune=True)
    base = chain.plan(spec, x.shape,
                      policy=dataclasses.replace(pol, autotune=False))
    calls = {"n": 0}

    def fake_measure(run, p, xx, **kw):
        calls["n"] += 1
        if calls["n"] == 2:  # the first non-base candidate dies
            raise RuntimeError("RESOURCE_EXHAUSTED: candidate died")
        return 1.0

    monkeypatch.setattr(autotune, "measure_run", fake_measure)
    r = autotune.autotune_chain(spec, params, x, policy=pol, base_plan=base)
    assert not r.cache_hit and r.plan == base
    entry = autotune.TuneCache.load(pol.tune_cache).get(r.key)
    assert entry is not None
    fc = entry["failed_candidates"]
    assert len(fc) == 1 and "RESOURCE_EXHAUSTED" in fc[0]["error"]


def test_autotune_all_fail_returns_base_unpersisted(tmp_path, monkeypatch):
    spec = chain.SeparableSpec((chain.PW(16),))
    params, x = _chain_data(spec)
    pol = _pol(tmp_path, autotune=True)
    base = chain.plan(spec, x.shape,
                      policy=dataclasses.replace(pol, autotune=False))

    def fake_measure(run, p, xx, **kw):
        raise RuntimeError("RESOURCE_EXHAUSTED: device is gone")

    monkeypatch.setattr(autotune, "measure_run", fake_measure)
    with pytest.warns(UserWarning, match="every candidate failed"):
        r = autotune.autotune_chain(spec, params, x, policy=pol,
                                    base_plan=base)
    assert r.plan == base and r.measured_us == float("inf")
    assert autotune.TuneCache.load(pol.tune_cache).get(r.key) is None


def test_lookup_cached_plan_drops_quarantined_winner(tmp_path):
    spec = _ir_spec()
    shape = (1, 8, 8, 8)
    pol = _pol(tmp_path, autotune=True)
    base = chain.plan(spec, shape,
                      policy=dataclasses.replace(pol, autotune=False,
                                                 on_failure="raise"))
    key = autotune.problem_key(spec, shape, jnp.float32, pol)
    cache = autotune.TuneCache.load(pol.tune_cache)
    cache.put(key, {"signature": {}, "plan":
                    autotune.serialize_chain_plan(base),
                    "measured_us": 1.0, "analytic_us": 1.0})
    cache.save()
    assert autotune.lookup_cached_plan(spec, shape, jnp.float32,
                                       pol) is not None
    _ban(pol, spec, shape, jnp.float32, "fused3")
    with pytest.warns(UserWarning, match="quarantined rungs"):
        assert autotune.lookup_cached_plan(spec, shape, jnp.float32,
                                           pol) is None
    # raise-mode callers opt out of the ladder and keep the tuned winner
    assert autotune.lookup_cached_plan(
        spec, shape, jnp.float32,
        dataclasses.replace(pol, on_failure="raise")) is not None


# ---------------------------------------------------------------------------
# plan(): quarantine steers the analytic walk
# ---------------------------------------------------------------------------

def test_plan_consults_quarantine(tmp_path):
    spec = _ir_spec()
    shape = (1, 8, 8, 8)
    pol = _pol(tmp_path)
    assert [s.kind for s in chain.plan(spec, shape, policy=pol).segments] \
        == ["fused3"]
    _ban(pol, spec, shape, jnp.float32, "fused3")
    kinds = [s.kind for s in chain.plan(spec, shape, policy=pol).segments]
    assert "fused3" not in kinds and "fused2" in kinds
    # raise-mode planning is quarantine-blind (the ladder opt-out)
    kinds = [s.kind for s in chain.plan(
        spec, shape,
        policy=dataclasses.replace(pol, on_failure="raise")).segments]
    assert kinds == ["fused3"]
    assert telemetry.runtime_report()["quarantine_hits"] > 0


# ---------------------------------------------------------------------------
# the ladder matrix: every rung x {fp32, bf16} x {chain, network}
# ---------------------------------------------------------------------------

#: (case name, points to arm {point: times}, rung the recovery lands on)
_MATRIX = [
    ("fused-transient", {"lowering:separable_fused": 1}, "fused2"),
    ("fused-persistent",
     {"lowering:separable_fused": faultinject.PERSISTENT}, "unfused"),
    ("all-lowering",
     {p: faultinject.PERSISTENT for p in
      ("lowering:separable_fused", "lowering:pwconv",
       "lowering:dwconv2d")}, "ref"),
    ("compile-transient", {"compile:chain": 1}, None),
]


@pytest.mark.parametrize("dname", ["fp32", "bf16"])
@pytest.mark.parametrize("case,points,_rung",
                         _MATRIX, ids=[c[0] for c in _MATRIX])
def test_ladder_matrix_chain(tmp_path, case, points, _rung, dname):
    spec = _ir_spec()
    params, x = _chain_data(spec)
    dp = DtypePolicy(stream="bfloat16") if dname == "bf16" else DtypePolicy()
    pol = _pol(tmp_path, dtype_policy=dp, numeric_guard=True)
    oracle = _oracle_chain(spec, params, x, pol)
    for p, t in points.items():
        faultinject.arm(p, times=t)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        y = chain.execute(spec, params, x, policy=pol)
    got = np.asarray(y, np.float32)
    if dname == "fp32" and case == "all-lowering":
        # every rung failed -> the reference rung IS the oracle: bitwise
        np.testing.assert_array_equal(got, oracle)
    else:
        tol = BF16_REL_TOL if dname == "bf16" else 1e-5
        rel = np.abs(got - oracle).max() / (np.abs(oracle).max() + 1e-30)
        assert rel < tol, (case, dname, rel)
    rep = telemetry.runtime_report()
    assert rep["fallbacks"] > 0
    assert rep["fallbacks"] == rep["injected_fallbacks"]
    assert rep["recoveries"] >= 1


@pytest.mark.parametrize("dname", ["fp32", "bf16"])
@pytest.mark.parametrize("case,points,_rung",
                         _MATRIX, ids=[c[0] for c in _MATRIX])
def test_ladder_matrix_network(tmp_path, case, points, _rung, dname):
    net = _tiny_net()
    params = network.init_network(jax.random.PRNGKey(0), net)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 16, 8))
    dp = DtypePolicy(stream="bfloat16") if dname == "bf16" else DtypePolicy()
    pol = _pol(tmp_path, dtype_policy=dp, numeric_guard=True)
    if case == "compile-transient":
        points = {"compile:network": 1}
    with faultinject.suppressed():
        oracle = x
        for spec, p in zip(net.blocks, params):
            oracle = chain.execute(
                spec, p, oracle,
                policy=dataclasses.replace(pol, on_failure="raise",
                                           numeric_guard=False,
                                           dtype_policy=DtypePolicy()))
        oracle = np.asarray(oracle, np.float32)
    for p, t in points.items():
        faultinject.arm(p, times=t)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        y = network.execute_network(net, params, x, policy=pol)
    got = np.asarray(y, np.float32)
    if dname == "fp32" and case == "all-lowering":
        np.testing.assert_array_equal(got, oracle)
    else:
        tol = BF16_REL_TOL if dname == "bf16" else 1e-5
        rel = np.abs(got - oracle).max() / (np.abs(oracle).max() + 1e-30)
        assert rel < tol, (case, dname, rel)
    rep = telemetry.runtime_report()
    assert rep["fallbacks"] > 0
    assert rep["fallbacks"] == rep["injected_fallbacks"]


# ---------------------------------------------------------------------------
# on_failure="raise": the taxonomy error propagates with its tags
# ---------------------------------------------------------------------------

def test_raise_mode_propagates_tagged_failure(tmp_path):
    spec = _ir_spec()
    params, x = _chain_data(spec)
    pol = _pol(tmp_path, on_failure="raise")
    faultinject.arm("lowering:separable_fused", times=1)
    with pytest.raises(failures.LoweringFailure) as ei:
        chain.execute(spec, params, x, policy=pol)
    e = ei.value
    assert e.segment_kind == "fused3" and e.injected
    assert isinstance(e.original, failures.InjectedFault)
    assert telemetry.fallback_count() == 0  # no ladder in raise mode
    # and nothing was quarantined
    q = quarantine.Quarantine.load(quarantine.quarantine_path(pol))
    assert q.entries == {}


def test_numeric_guard_raise_mode(tmp_path):
    spec = _ir_spec()
    params, x = _chain_data(spec)
    pol = _pol(tmp_path, on_failure="raise", numeric_guard=True)
    faultinject.arm("numeric:chain", times=1)
    with pytest.raises(failures.NumericalFailure, match="non-finite"):
        chain.execute(spec, params, x, policy=pol)


def test_numeric_guard_degrade_recovers(tmp_path):
    spec = _ir_spec()
    params, x = _chain_data(spec)
    pol = _pol(tmp_path, numeric_guard=True)
    oracle = _oracle_chain(spec, params, x, pol)
    faultinject.arm("numeric:chain", times=1)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        y = chain.execute(spec, params, x, policy=pol)
    got = np.asarray(y, np.float32)
    assert np.isfinite(got).all()
    rel = np.abs(got - oracle).max() / (np.abs(oracle).max() + 1e-30)
    assert rel < 1e-5
    rep = telemetry.runtime_report()
    assert rep["numeric_trips"] == 1 and rep["fallbacks"] == 1


# ---------------------------------------------------------------------------
# quarantine: pre-seeded bans honored with zero retries
# ---------------------------------------------------------------------------

def test_unfused_ban_executes_ref_with_zero_fallbacks(tmp_path):
    spec = _ir_spec()
    params, x = _chain_data(spec)
    pol = _pol(tmp_path)
    oracle = _oracle_chain(spec, params, x, pol)
    _ban(pol, spec, x.shape, x.dtype, "unfused")
    y = chain.execute(spec, params, x, policy=pol)
    np.testing.assert_array_equal(np.asarray(y, np.float32), oracle)
    rep = telemetry.runtime_report()
    assert rep["fallbacks"] == 0 and rep["quarantine_hits"] > 0


def test_supplied_banned_plan_ignored_with_warning(tmp_path):
    spec = _ir_spec()
    params, x = _chain_data(spec)
    pol = _pol(tmp_path)
    cp_fused = chain.plan(spec, x.shape,
                          policy=dataclasses.replace(pol,
                                                     on_failure="raise"))
    assert ladder.plan_rung(cp_fused) == "fused3"
    oracle = _oracle_chain(spec, params, x, pol)
    _ban(pol, spec, x.shape, x.dtype, "fused3")
    with pytest.warns(RuntimeWarning, match="ignoring supplied chain_plan"):
        y = chain.execute(spec, params, x, policy=pol, chain_plan=cp_fused)
    rel = np.abs(np.asarray(y, np.float32) - oracle).max() / \
        (np.abs(oracle).max() + 1e-30)
    assert rel < 1e-5
    assert telemetry.fallback_count() == 0


def test_quarantine_survives_into_fresh_process(tmp_path):
    spec = _ir_spec()
    params, x = _chain_data(spec)
    pol = _pol(tmp_path)
    faultinject.arm("lowering:separable_fused", times=1)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        chain.execute(spec, params, x, policy=pol)
    assert telemetry.fallback_count() == 1
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    script = f"""
import sys
sys.path.insert(0, {os.path.join(root, "src")!r})
import jax, jax.numpy as jnp
from repro.core import chain
from repro.kernels.policy import KernelPolicy
from repro.runtime import telemetry
spec = chain.inverted_residual_spec(c_in=8, c_out=8, expand=2)
params = chain.init_chain(jax.random.PRNGKey(0), spec, 8)
x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 8, 8))
pol = KernelPolicy(impl="xla", tune_cache={pol.tune_cache!r})
y = chain.execute(spec, params, x, policy=pol)
rep = telemetry.runtime_report()
assert rep["fallbacks"] == 0, rep       # zero retries in the new process
assert rep["quarantine_hits"] > 0, rep  # ...because the ban was honored
cp = chain.plan(spec, x.shape, policy=pol)
assert all(s.kind != "fused3" for s in cp.segments), cp
print("CHILD_OK")
"""
    r = subprocess.run([sys.executable, "-c", script],
                       capture_output=True, text=True, timeout=300)
    assert r.returncode == 0, r.stderr
    assert "CHILD_OK" in r.stdout


# ---------------------------------------------------------------------------
# network engine integration
# ---------------------------------------------------------------------------

def test_network_steady_state_after_transient_fault(tmp_path):
    net = _tiny_net()
    params = network.init_network(jax.random.PRNGKey(0), net)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 16, 8))
    pol = _pol(tmp_path)
    faultinject.arm("lowering:separable_fused", times=1)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        y1 = network.execute_network(net, params, x, policy=pol)
    assert telemetry.fallback_count() == 1
    faultinject.disarm_all()
    telemetry.reset_runtime_telemetry()
    # the failed jit was NOT memoized: this call re-plans, re-jits clean
    y2 = network.execute_network(net, params, x, policy=pol)
    assert telemetry.fallback_count() == 0
    np.testing.assert_array_equal(np.asarray(y1, np.float32),
                                  np.asarray(y2, np.float32))
    # and now it IS memoized: a third call records nothing
    network.execute_network(net, params, x, policy=pol)
    assert telemetry.fallback_count() == 0


def test_network_unfused_ban_forces_xla_block(tmp_path):
    net = _tiny_net()
    params = network.init_network(jax.random.PRNGKey(0), net)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 16, 8))
    pol = _pol(tmp_path)
    with faultinject.suppressed():
        oracle = x
        for spec, p in zip(net.blocks, params):
            oracle = chain.execute(
                spec, p, oracle,
                policy=dataclasses.replace(pol, on_failure="raise"))
        oracle = np.asarray(oracle, np.float32)
    policies = network.resolve_block_policies(net, pol, None)
    problems, _ = network._block_problems(net, x.shape, x.dtype, policies)
    (shape1, dt1) = problems[1]
    _ban(policies[1], net.blocks[1], shape1, jnp.dtype(dt1),
         "fused3", "unfused")
    y = network.execute_network(net, params, x, policy=pol)
    rel = np.abs(np.asarray(y, np.float32) - oracle).max() / \
        (np.abs(oracle).max() + 1e-30)
    assert rel < 1e-5
    assert telemetry.fallback_count() == 0


def test_pallas_interpret_chain_fault_parity(tmp_path):
    spec = _ir_spec()
    params, x = _chain_data(spec)
    pol = KernelPolicy(impl="pallas", interpret=True,
                       tune_cache=str(tmp_path / "tune.json"))
    oracle = _oracle_chain(spec, params, x, pol)
    faultinject.arm("lowering:separable_fused", times=1)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        y = chain.execute(spec, params, x, policy=pol)
    rel = np.abs(np.asarray(y, np.float32) - oracle).max() / \
        (np.abs(oracle).max() + 1e-30)
    assert rel < 1e-5
    assert telemetry.fallback_count() == 1
