"""Parity of the fused separable-block kernel (interpret mode) against the
unfused depthwise2d+pointwise composition and the pure-jnp oracle, plus the
policy routing through core/separable.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.pwconv import KernelPolicy
from repro.core.separable import (
    init_inverted_residual,
    init_separable,
    inverted_residual,
    separable_block,
)
from repro.kernels import blocking, ops, ref
from repro.kernels.separable_fused import separable_fused_pallas

RNG = np.random.default_rng(7)


def _arr(shape, dtype=np.float32, scale=1.0):
    return jnp.asarray((RNG.normal(size=shape) * scale).astype(dtype))


# (B, Hi, Wi, C, Co) — odd / non-multiple-of-128 channel counts included
SWEEP = [
    (1, 10, 10, 8, 16),
    (2, 12, 9, 13, 33),      # odd C, odd Co (< 128 lane padding)
    (1, 9, 9, 130, 64),      # C > 128 -> multi-step reduction
    (1, 8, 8, 3, 5),         # tiny odd channels
]


@pytest.mark.parametrize("b,hi,wi,c,co", SWEEP)
@pytest.mark.parametrize("stride", [1, 2])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_fused_matches_ref(b, hi, wi, c, co, stride, dtype):
    x = _arr((b, hi, wi, c)).astype(dtype)
    f = _arr((3, 3, c), scale=1 / 3).astype(dtype)
    w = _arr((c, co), scale=c ** -0.5).astype(dtype)
    db = _arr((c,), scale=0.1).astype(dtype)
    pb = _arr((co,), scale=0.1).astype(dtype)
    got = separable_fused_pallas(
        x, f, w, db, pb, stride=stride,
        dw_activation="relu6", activation="relu6", interpret=True)
    want = ref.separable_fused_ref(
        x, f, w, db, pb, stride=stride, padding="valid",
        dw_activation="relu6", activation="relu6")
    tol = 1e-4 if dtype == np.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("b,hi,wi,c,co", SWEEP[:3])
@pytest.mark.parametrize("stride", [1, 2])
def test_fused_matches_unfused_composition(b, hi, wi, c, co, stride):
    """The acceptance gate: fused kernel == depthwise2d+pointwise chain
    within 1e-4 (f32, interpret, SAME padding as the model blocks use)."""
    x = _arr((b, hi, wi, c))
    f = _arr((3, 3, c), scale=1 / 3)
    w = _arr((c, co), scale=c ** -0.5)
    db = _arr((c,), scale=0.1)
    pb = _arr((co,), scale=0.1)
    fused = ops.separable_fused(
        x, f, w, db, pb, stride=stride, padding="same",
        dw_activation="relu6", activation="relu6",
        impl="pallas", interpret=True)
    y = ops.dwconv2d(x, f, stride=stride, padding="same",
                     impl="pallas", interpret=True)
    y = jnp.clip(y + db, 0.0, 6.0)
    unfused = ops.pwconv(y, w, pb, activation="relu6",
                         impl="pallas", interpret=True)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(unfused),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("residual", [False, True])
def test_fused_residual(residual):
    """Inverted-residual tail: DW -> PW-project (+ residual add) fused."""
    x = _arr((1, 11, 11, 24))
    f = _arr((3, 3, 24), scale=1 / 3)
    w = _arr((24, 24), scale=24 ** -0.5)
    res = _arr((1, 11, 11, 24)) if residual else None
    got = ops.separable_fused(
        x, f, w, None, None, res, stride=1, padding="same",
        dw_activation="relu6", activation=None,
        impl="pallas", interpret=True)
    want = ref.separable_fused_ref(
        x, f, w, None, None, res, stride=1, padding="same",
        dw_activation="relu6", activation=None)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_separable_block_policy_routing():
    """core.separable_block(policy.fused) == unfused policy path (f32)."""
    key = jax.random.PRNGKey(0)
    params = init_separable(key, 16, 24)
    x = _arr((1, 14, 14, 16))
    for stride in (1, 2):
        base = separable_block(params, x, stride=stride,
                               policy=KernelPolicy(impl="xla"))
        fused_xla = separable_block(
            params, x, stride=stride,
            policy=KernelPolicy(impl="xla", fused=True))
        fused_pal = separable_block(
            params, x, stride=stride,
            policy=KernelPolicy(impl="pallas", interpret=True, fused=True))
        np.testing.assert_allclose(np.asarray(base), np.asarray(fused_xla),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(base), np.asarray(fused_pal),
                                   rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("stride,c_in,c_out", [(1, 8, 8), (2, 8, 16)])
def test_inverted_residual_policy_routing(stride, c_in, c_out):
    """V2 block: fused DW->project tail (+residual when stride 1, c_in==c_out)
    matches the unfused composition."""
    key = jax.random.PRNGKey(1)
    params = init_inverted_residual(key, c_in, c_out, expand=4)
    x = _arr((1, 10, 10, c_in))
    base = inverted_residual(params, x, stride=stride,
                             policy=KernelPolicy(impl="xla"))
    fused = inverted_residual(
        params, x, stride=stride,
        policy=KernelPolicy(impl="pallas", interpret=True, fused=True))
    np.testing.assert_allclose(np.asarray(base), np.asarray(fused),
                               rtol=1e-4, atol=1e-4)


def test_fused_vmem_fallback_path():
    """When even the minimal block plan exceeds the VMEM budget the op must
    fall back to the unfused Pallas composition and stay correct."""
    x = _arr((1, 9, 9, 10))
    f = _arr((3, 3, 10), scale=1 / 3)
    w = _arr((10, 12), scale=0.3)
    db = _arr((10,), scale=0.1)
    want = ref.separable_fused_ref(
        x, f, w, db, stride=1, padding="same",
        dw_activation="relu6", activation=None)
    # budget below even (cb=1, cob=1, slab_h=1) -> unfused composition path
    assert blocking.plan_separable(9, 9, 10, 12, vmem_budget=64) is None
    got_fb = ops.separable_fused(
        x, f, w, db, stride=1, padding="same",
        dw_activation="relu6", activation=None,
        impl="pallas", interpret=True, vmem_budget=64)
    np.testing.assert_allclose(np.asarray(got_fb), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    # handpicked tiny blocking still fused: multi-panel Co + multi-step C
    got_tiny = separable_fused_pallas(
        x, f, w, db, stride=1, dw_activation="relu6", activation=None,
        block_c=2, block_co=4, interpret=True)
    want_valid = ref.separable_fused_ref(
        x, f, w, db, stride=1, padding="valid",
        dw_activation="relu6", activation=None)
    np.testing.assert_allclose(np.asarray(got_tiny), np.asarray(want_valid),
                               rtol=1e-4, atol=1e-4)


def test_fused_slab_path_via_tiny_budget():
    """A budget that was infeasible pre-slabs now routes through the FUSED
    kernel with a row-slab plan (not the unfused fallback) and stays
    correct on the SAME-padded op path."""
    plan = blocking.plan_separable(12, 12, 10, 12, vmem_budget=8 * 1024)
    assert plan is not None and plan.n_slabs > 1
    x = _arr((1, 12, 12, 10))
    f = _arr((3, 3, 10), scale=1 / 3)
    w = _arr((10, 12), scale=0.3)
    db = _arr((10,), scale=0.1)
    got = ops.separable_fused(
        x, f, w, db, stride=1, padding="same",
        dw_activation="relu6", activation=None,
        impl="pallas", interpret=True, vmem_budget=8 * 1024)
    want = ref.separable_fused_ref(
        x, f, w, db, stride=1, padding="same",
        dw_activation="relu6", activation=None)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


# (Hi, Wi, stride, slab_h) — halo edge cases: stride-2 (1-row halo), odd Ho,
# slab_h not dividing Ho (garbage-row crop), slab_h == 1 (maximal halo).
SLAB_CASES = [
    (12, 12, 1, 4),      # slab divides Ho exactly
    (13, 13, 1, 4),      # Ho = 11, remainder slab of 3
    (13, 11, 2, 3),      # stride 2, Ho = 6, halo = 1 row
    (14, 9, 2, 5),       # stride 2, odd Wo, remainder slab
    (10, 10, 1, 1),      # slab_h = 1: every interior row re-fetched
]


@pytest.mark.parametrize("hi,wi,stride,slab_h", SLAB_CASES)
def test_fused_slab_halo_edge_cases(hi, wi, stride, slab_h):
    """Forced row-slab blocking vs the oracle at awkward geometries."""
    c, co = 13, 17
    x = _arr((1, hi, wi, c))
    f = _arr((3, 3, c), scale=1 / 3)
    w = _arr((c, co), scale=c ** -0.5)
    db = _arr((c,), scale=0.1)
    pb = _arr((co,), scale=0.1)
    got = separable_fused_pallas(
        x, f, w, db, pb, stride=stride,
        dw_activation="relu6", activation="relu6",
        block_c=8, block_co=16, slab_h=slab_h, interpret=True)
    want = ref.separable_fused_ref(
        x, f, w, db, pb, stride=stride, padding="valid",
        dw_activation="relu6", activation="relu6")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_fused_slab_residual_add():
    """Residual add with a slab grid whose last slab is a remainder: the
    residual BlockSpec is slabbed too and padded rows are cropped."""
    x = _arr((2, 11, 11, 24))
    f = _arr((3, 3, 24), scale=1 / 3)
    w = _arr((24, 24), scale=24 ** -0.5)
    res = _arr((2, 9, 9, 24))
    got = separable_fused_pallas(
        x, f, w, None, None, res, stride=1,
        dw_activation="relu6", activation=None,
        block_c=8, block_co=24, slab_h=4, interpret=True)
    want = ref.separable_fused_ref(
        x, f, w, None, None, res, stride=1, padding="valid",
        dw_activation="relu6", activation=None)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_fused_hires_above_old_ceiling(dtype):
    """Acceptance gate: a 1x1504x1504x32 separable block — Ho*Wo ~ 2.26M,
    far above the old ~1.5M-pixel accumulator ceiling that forced the
    unfused fallback — must route through the fused Pallas kernel on a real
    row-slab plan and match the reference oracle."""
    plan = blocking.plan_separable(1504, 1504, 32, 32, dtype=dtype)
    assert plan is not None and plan.n_slabs > 1      # real plan, slabbed
    x = _arr((1, 1504, 1504, 32)).astype(dtype)
    f = _arr((3, 3, 32), scale=1 / 3).astype(dtype)
    w = _arr((32, 32), scale=32 ** -0.5).astype(dtype)
    got = ops.separable_fused(
        x, f, w, stride=1, padding="same",
        dw_activation="relu6", activation=None,
        impl="pallas", interpret=True)
    want = ref.separable_fused_ref(
        x, f, w, stride=1, padding="same",
        dw_activation="relu6", activation=None)
    tol = 1e-4 if dtype == np.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)
