"""Sharding rules, roofline HLO parsing, and multi-device DP/TP equivalence
(the latter via subprocess with forced host devices)."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.registry import get_config
from repro.launch.mesh import make_mesh_compat
from repro.models import transformer as T
from repro.roofline.analysis import (ICI_BW, PEAK_FLOPS, analyze,
                                     model_flops, parse_collectives)
from repro.sharding.rules import (ShardingRules, param_specs, shard_act,
                                  use_rules, zero1_specs)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# Param specs
# ---------------------------------------------------------------------------


class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape


def _rules(fsdp="data"):
    return ShardingRules(mesh=_FakeMesh({"data": 16, "model": 16}),
                         batch_axes=("data",), model_axis="model",
                         fsdp_axis=fsdp)


def test_param_specs_shard_every_big_tensor():
    cfg = get_config("qwen3-1.7b")
    shapes = jax.eval_shape(lambda: T.init_params(cfg, jax.random.PRNGKey(0)))
    specs = param_specs(shapes, _rules())
    flat = jax.tree_util.tree_flatten_with_path(specs)[0]
    sflat = {tuple(str(k) for k in p): s for p, s in flat}
    leaves = jax.tree_util.tree_flatten_with_path(shapes)[0]
    for (path, leaf), (_, spec) in zip(leaves, flat):
        if leaf.size >= 1 << 20:  # every >=1M-element tensor must be sharded
            assert any(a is not None for a in spec), (path, leaf.shape, spec)


def test_param_specs_divisibility():
    """Specs never shard a non-divisible dim."""
    for arch in ("qwen3-moe-235b-a22b", "hymba-1.5b", "command-r-35b"):
        cfg = get_config(arch)
        shapes = jax.eval_shape(
            lambda: T.init_params(cfg, jax.random.PRNGKey(0)))
        rules = _rules()
        specs = param_specs(shapes, rules)

        def check(leaf, spec):
            for dim, axis in zip(leaf.shape, tuple(spec) + (None,) * 9):
                if axis is not None:
                    size = 16
                    assert dim % size == 0, (leaf.shape, spec)
        jax.tree_util.tree_map(check, shapes, specs,
                               is_leaf=lambda x: hasattr(x, "shape"))


def test_zero1_upgrades_unsharded_dims():
    cfg = get_config("smollm-360m", smoke=True)
    shapes = jax.eval_shape(lambda: T.init_params(cfg, jax.random.PRNGKey(0)))
    rules = ShardingRules(mesh=_FakeMesh({"data": 2, "model": 1}),
                          batch_axes=("data",), model_axis=None,
                          fsdp_axis="data")
    specs = param_specs(shapes, rules)
    z = zero1_specs(shapes, specs, rules)
    flat_s = jax.tree_util.tree_leaves(
        jax.tree_util.tree_map(lambda s: tuple(s), specs,
                               is_leaf=lambda s: isinstance(s, P)))
    n_sharded_before = sum("data" in s for s in flat_s)
    flat_z = jax.tree_util.tree_leaves(
        jax.tree_util.tree_map(lambda s: tuple(s), z,
                               is_leaf=lambda s: isinstance(s, P)))
    n_sharded_after = sum("data" in s for s in flat_z)
    assert n_sharded_after > n_sharded_before


def test_shard_act_noop_without_context():
    x = jnp.zeros((4, 8, 16))
    assert shard_act(x, "btd") is x


# ---------------------------------------------------------------------------
# Roofline parsing
# ---------------------------------------------------------------------------

HLO_FIXTURE = """
HloModule test
ENTRY main {
  %p0 = bf16[16,512,128]{2,1,0} parameter(0)
  %ag = bf16[16,512,2048]{2,1,0} all-gather(%p0), replica_groups=[32,16]<=[512], dimensions={2}
  %ar = f32[1024]{0} all-reduce(%c), replica_groups={{0,1,2,3}}, to_apply=%add
  %rs = f32[64,32]{1,0} reduce-scatter(%d), replica_groups=[16,16]<=[256], dimensions={0}
  %a2a = bf16[8,128,64]{2,1,0} all-to-all(%e), replica_groups=[16,16]<=[256]
  %cp = f32[256]{0} collective-permute(%f), source_target_pairs={{0,1}}
  %ard = f32[12]{0} all-reduce-done(%ar)
}
"""


def test_parse_collectives_fixture():
    res = parse_collectives(HLO_FIXTURE)
    ag = 16 * 512 * 2048 * 2 * (15 / 16)
    ar = 1024 * 4 * 2 * (3 / 4)
    rs = 64 * 32 * 4 * (15 / 16)
    a2a = 8 * 128 * 64 * 2 * (15 / 16)
    cp = 256 * 4 * (1 / 2)
    assert res["all-gather"] == pytest.approx(ag)
    assert res["all-reduce"] == pytest.approx(ar)
    assert res["reduce-scatter"] == pytest.approx(rs)
    assert res["all-to-all"] == pytest.approx(a2a)
    assert res["collective-permute"] == pytest.approx(cp)
    assert res["counts"]["all-reduce"] == 1  # -done not double-counted


def test_model_flops_conventions():
    cfg = get_config("qwen3-1.7b")
    tr = model_flops(cfg, {"kind": "train", "global_batch": 256,
                           "seq_len": 4096})
    assert tr == pytest.approx(6 * cfg.n_params() * 256 * 4096)
    de = model_flops(cfg, {"kind": "decode", "global_batch": 128,
                           "seq_len": 32768})
    assert de == pytest.approx(2 * cfg.n_params() * 128)


def test_analyze_end_to_end_tiny():
    """analyze() on a real compiled 4-device program finds the all-reduce."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, "src")
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.launch.mesh import make_mesh_compat
from repro.roofline.analysis import analyze
mesh = make_mesh_compat((4,), ("model",))
x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
w = jax.ShapeDtypeStruct((256, 64), jnp.float32)
with mesh:
    f = jax.jit(lambda x, w: x @ w,
                in_shardings=(NamedSharding(mesh, P(None, "model")),
                              NamedSharding(mesh, P("model", None))))
    compiled = f.lower(x, w).compile()
rec = analyze(compiled, n_devices=4, model_flops_global=2*128*256*64)
assert rec["collective_bytes_per_device"] > 0, "expected an all-reduce"
assert rec["hlo_flops_per_device"] > 0
print("ANALYZE-OK", rec["dominant"])
"""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, cwd=REPO, env=env, timeout=300)
    assert "ANALYZE-OK" in out.stdout, out.stdout + out.stderr


# ---------------------------------------------------------------------------
# Multi-device DP/TP equivalence (subprocess, 8 fake host devices)
# ---------------------------------------------------------------------------


def test_dp_tp_loss_matches_single_device():
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np
from repro.configs.registry import get_config
from repro.models import transformer as T
from repro.launch.dryrun import make_rules
from repro.launch.mesh import make_mesh_compat
from repro.sharding.rules import use_rules, param_specs, batch_pspecs, named

cfg = get_config("qwen3-1.7b", smoke=True)
params = T.init_params(cfg, jax.random.PRNGKey(0))
tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab_size)
batch = {"tokens": tokens, "labels": tokens}
loss1, _ = jax.jit(lambda p, b: T.loss_fn(cfg, p, b))(params, batch)

mesh = make_mesh_compat((4, 2), ("data", "model"))
rules = make_rules(mesh, mode="train", multi_pod=False)
with use_rules(rules), mesh:
    pspecs = named(mesh, param_specs(params, rules))
    bspecs = named(mesh, batch_pspecs(batch, rules))
    p_sh = jax.device_put(params, pspecs)
    b_sh = jax.device_put(batch, bspecs)
    loss8, _ = jax.jit(lambda p, b: T.loss_fn(cfg, p, b))(p_sh, b_sh)
np.testing.assert_allclose(float(loss1), float(loss8), rtol=2e-5)
print("DPTP-OK", float(loss1), float(loss8))
"""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, cwd=REPO, env=env, timeout=600)
    assert "DPTP-OK" in out.stdout, out.stdout + out.stderr


def test_elastic_reshard_roundtrip():
    """Checkpoint written under 1 device restores under 8 (elastic)."""
    code = r"""
import os, tempfile
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np
from repro.configs.registry import get_config
from repro.models import transformer as T
from repro.launch.dryrun import make_rules
from repro.launch.mesh import make_mesh_compat
from repro.sharding.rules import use_rules, param_specs, named
from repro.train.checkpoint import Checkpointer

cfg = get_config("smollm-360m", smoke=True)
params = T.init_params(cfg, jax.random.PRNGKey(0))
d = tempfile.mkdtemp()
ck = Checkpointer(d)
ck.save(1, {"params": params})
mesh = make_mesh_compat((4, 2), ("data", "model"))
rules = make_rules(mesh, mode="train", multi_pod=False)
shardings = named(mesh, {"params": param_specs(params, rules)})
restored, step, _ = ck.restore({"params": params}, shardings=shardings)
for a, b in zip(jax.tree_util.tree_leaves(params),
                jax.tree_util.tree_leaves(restored["params"])):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
print("ELASTIC-OK")
"""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, cwd=REPO, env=env, timeout=600)
    assert "ELASTIC-OK" in out.stdout, out.stdout + out.stderr
