"""Recurrent-family equivalences: chunked scan == stepwise recurrence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import SSMConfig, XLSTMConfig
from repro.models import ssm, xlstm


# ---------------------------------------------------------------------------
# Selective scan (Mamba)
# ---------------------------------------------------------------------------


def test_mamba_full_vs_steps():
    cfg = SSMConfig(d_state=8, conv_k=4, expand=2, chunk=16)
    d = 20
    p = ssm.init_mamba(jax.random.PRNGKey(0), d, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 50, d)) * 0.5
    y_full, cache = ssm.mamba_mixer(p, x, cfg, return_state=True)
    state = ssm.init_mamba_state(2, d, cfg)
    ys = []
    for t in range(50):
        y_t, state = ssm.mamba_mixer_step(p, x[:, t:t + 1], state, cfg)
        ys.append(y_t)
    np.testing.assert_allclose(jnp.concatenate(ys, 1), y_full, rtol=2e-5,
                               atol=2e-5)
    np.testing.assert_allclose(state["h"], cache["h"], rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(state["conv"], cache["conv"], rtol=2e-5,
                               atol=2e-5)


@settings(max_examples=8, deadline=None)
@given(chunk=st.sampled_from([4, 8, 16, 64]), seed=st.integers(0, 2**31 - 1))
def test_selective_scan_chunk_invariance(chunk, seed):
    r = np.random.default_rng(seed)
    nb, l, di, n = 1, 33, 6, 4
    u = jnp.asarray(r.normal(size=(nb, l, di)).astype(np.float32))
    dt = jnp.asarray(r.uniform(0.01, 0.2, size=(nb, l, di)).astype(np.float32))
    a = -jnp.asarray(r.uniform(0.5, 2.0, size=(di, n)).astype(np.float32))
    b = jnp.asarray(r.normal(size=(nb, l, n)).astype(np.float32))
    c = jnp.asarray(r.normal(size=(nb, l, n)).astype(np.float32))
    dskip = jnp.ones((di,))
    y1, h1 = ssm.selective_scan(u, dt, a, b, c, dskip, chunk=chunk)
    y2, h2 = ssm.selective_scan(u, dt, a, b, c, dskip, chunk=l)
    np.testing.assert_allclose(y1, y2, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(h1, h2, rtol=2e-5, atol=2e-5)


def test_selective_scan_decay_property():
    """With B=0 the state decays: y == D*u exactly."""
    nb, l, di, n = 1, 10, 3, 2
    u = jnp.ones((nb, l, di))
    dt = jnp.full((nb, l, di), 0.1)
    a = -jnp.ones((di, n))
    b = jnp.zeros((nb, l, n))
    c = jnp.ones((nb, l, n))
    d = 2.0 * jnp.ones((di,))
    y, h = ssm.selective_scan(u, dt, a, b, c, d, chunk=4)
    np.testing.assert_allclose(y, 2.0 * u, rtol=1e-6)
    np.testing.assert_allclose(h, 0.0, atol=1e-7)


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def _mlstm_inputs(b=2, l=40, h=3, dh=8, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    q = jax.random.normal(ks[0], (b, l, h, dh))
    k = jax.random.normal(ks[1], (b, l, h, dh))
    v = jax.random.normal(ks[2], (b, l, h, dh))
    ig = jax.random.normal(ks[3], (b, l, h)) * 2
    lf = jax.nn.log_sigmoid(jax.random.normal(ks[4], (b, l, h)) * 2)
    return q, k, v, ig, lf


@pytest.mark.parametrize("chunk", [8, 16, 40])
def test_mlstm_chunkwise_equals_recurrent(chunk):
    q, k, v, ig, lf = _mlstm_inputs()
    h_rec, st_rec = xlstm.mlstm_recurrent(q, k, v, ig, lf)
    h_ch, st_ch = xlstm.mlstm_chunkwise(q, k, v, ig, lf, chunk=chunk)
    np.testing.assert_allclose(h_rec, h_ch, rtol=2e-4, atol=2e-4)
    for a, b in zip(st_rec, st_ch):
        np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)


def test_mlstm_state_threading():
    """Running two halves with carried state == one full pass."""
    q, k, v, ig, lf = _mlstm_inputs(l=32)
    h_full, _ = xlstm.mlstm_chunkwise(q, k, v, ig, lf, chunk=8)
    h1, st = xlstm.mlstm_chunkwise(q[:, :16], k[:, :16], v[:, :16],
                                   ig[:, :16], lf[:, :16], chunk=8)
    h2, _ = xlstm.mlstm_chunkwise(q[:, 16:], k[:, 16:], v[:, 16:],
                                  ig[:, 16:], lf[:, 16:], chunk=8, state=st)
    np.testing.assert_allclose(jnp.concatenate([h1, h2], 1), h_full,
                               rtol=2e-4, atol=2e-4)


def test_mlstm_block_decode_parity():
    cfg = XLSTMConfig(conv_k=4, proj_factor=2.0)
    d, nh, b, l = 24, 2, 2, 20
    p = xlstm.init_mlstm_block(jax.random.PRNGKey(7), d, nh, cfg)
    x = jax.random.normal(jax.random.PRNGKey(8), (b, l, d)) * 0.5
    y_full = xlstm.mlstm_block(p, x, n_heads=nh, cfg=cfg, chunk=8)
    cache = xlstm.init_mlstm_cache(b, d, nh, cfg)
    ys = []
    for t in range(l):
        y_t, cache = xlstm.mlstm_block_step(p, x[:, t:t + 1], cache,
                                            n_heads=nh, cfg=cfg)
        ys.append(y_t)
    np.testing.assert_allclose(jnp.concatenate(ys, 1), y_full, rtol=1e-4,
                               atol=1e-4)


def test_slstm_block_decode_parity():
    cfg = XLSTMConfig(conv_k=4)
    d, nh, b, l = 24, 2, 2, 20
    p = xlstm.init_slstm_block(jax.random.PRNGKey(9), d, nh, cfg)
    x = jax.random.normal(jax.random.PRNGKey(8), (b, l, d)) * 0.5
    y_full = xlstm.slstm_block(p, x, n_heads=nh, cfg=cfg, chunk=5)
    cache = xlstm.init_slstm_cache(b, d, nh, cfg)
    ys = []
    for t in range(l):
        y_t, cache = xlstm.slstm_block_step(p, x[:, t:t + 1], cache,
                                            n_heads=nh, cfg=cfg)
        ys.append(y_t)
    np.testing.assert_allclose(jnp.concatenate(ys, 1), y_full, rtol=1e-4,
                               atol=1e-4)


def test_slstm_checkpointed_scan_matches_plain():
    """Chunk-checkpointed scan must not change values."""
    b, l, h, dh = 1, 24, 2, 4
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    gates = [jax.random.normal(ks[i], (b, l, h, dh)) for i in range(4)]
    r = jax.random.normal(ks[4], (h, dh, 4 * dh)) * 0.2
    h1, _ = xlstm.slstm_scan(*gates, r, chunk=l)       # plain
    h2, _ = xlstm.slstm_scan(*gates, r, chunk=8)       # checkpointed
    np.testing.assert_allclose(h1, h2, rtol=1e-5, atol=1e-5)


def test_mlstm_grads_finite_through_chunkwise():
    q, k, v, ig, lf = _mlstm_inputs(l=24)

    def loss(q, k, v, ig, lf):
        h, _ = xlstm.mlstm_chunkwise(q, k, v, ig, lf, chunk=8)
        return jnp.sum(h ** 2)

    g = jax.grad(loss, argnums=(0, 1, 2, 3, 4))(q, k, v, ig, lf)
    for x in g:
        assert bool(jnp.all(jnp.isfinite(x)))
        assert float(jnp.linalg.norm(x)) > 0
