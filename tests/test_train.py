"""Training substrate: convergence, checkpoint/restore, fault tolerance,
data determinism, gradient compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.data.pipeline import DataConfig, DataIterator, _batch_np
from repro.optim import adamw
from repro.optim.compress import CompressionConfig, compress, init_error
from repro.train.checkpoint import Checkpointer
from repro.train.train_step import TrainConfig, init_train_state, \
    make_train_step
from repro.train.trainer import FaultInjector, LoopConfig, train_loop


def _tiny():
    cfg = get_config("smollm-360m", smoke=True)
    tcfg = TrainConfig(
        optimizer=adamw.AdamWConfig(lr=1e-2, warmup_steps=2,
                                    total_steps=100, weight_decay=0.0),
    )
    return cfg, tcfg


def _dcfg(cfg, steps=64, bs=4, seq=32):
    return DataConfig(vocab_size=cfg.vocab_size, seq_len=seq,
                      global_batch=bs, seed=7)


# ---------------------------------------------------------------------------
# Convergence
# ---------------------------------------------------------------------------


def test_loss_decreases_on_structured_data():
    cfg, tcfg = _tiny()
    dcfg = _dcfg(cfg)
    state = init_train_state(cfg, tcfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, tcfg))
    it = DataIterator(dcfg, prefetch=0)
    losses = []
    for _ in range(40):
        state, m = step(state, next(it))
        losses.append(float(m["loss"]))
    first, last = np.mean(losses[:5]), np.mean(losses[-5:])
    assert last < first - 0.5, (first, last)


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------


def test_data_deterministic_and_resumable():
    cfg, _ = _tiny()
    dcfg = _dcfg(cfg)
    a = _batch_np(dcfg, step=5)
    b = _batch_np(dcfg, step=5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    it = DataIterator(dcfg, prefetch=0)
    for _ in range(3):
        next(it)
    st = it.state()
    b1 = next(it)
    it2 = DataIterator.restore(dcfg, st, prefetch=0)
    b2 = next(it2)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])


def test_data_shards_are_disjoint_and_partition_the_batch():
    cfg, _ = _tiny()
    dcfg = _dcfg(cfg, bs=8)
    full = _batch_np(dcfg, step=3, shard=0, n_shards=1)
    parts = [_batch_np(dcfg, step=3, shard=i, n_shards=4) for i in range(4)]
    assert all(p["tokens"].shape[0] == 2 for p in parts)
    # shards cannot repeat each other (statistically distinct streams)
    assert not np.array_equal(parts[0]["tokens"], parts[1]["tokens"])


def test_data_has_learnable_structure():
    cfg, _ = _tiny()
    dcfg = _dcfg(cfg)
    b = _batch_np(dcfg, step=0)
    t, l = b["tokens"], b["labels"]
    # the structured positions are predictable: anchor+j appears periodically
    period = dcfg.structure
    preds = (t[:, 0::period][:, : l[:, 0::period].shape[1]])
    assert t.min() >= 0 and t.max() < cfg.vocab_size


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip_exact(tmp_path):
    cfg, tcfg = _tiny()
    state = init_train_state(cfg, tcfg, jax.random.PRNGKey(0))
    ck = Checkpointer(str(tmp_path), keep=2)
    ck.save(3, state, extra={"data": {"step": 3}})
    restored, step, extra = ck.restore(state)
    assert step == 3 and extra["data"]["step"] == 3
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_gc_and_latest(tmp_path):
    cfg, tcfg = _tiny()
    state = init_train_state(cfg, tcfg, jax.random.PRNGKey(0))
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, state)
    assert ck.committed_steps() == [3, 4]
    assert ck.latest_step() == 4


def test_checkpoint_corruption_falls_back(tmp_path):
    cfg, tcfg = _tiny()
    state = init_train_state(cfg, tcfg, jax.random.PRNGKey(0))
    ck = Checkpointer(str(tmp_path), keep=3)
    ck.save(1, state)
    ck.save(2, state)
    # corrupt the newest arrays file
    with open(os.path.join(str(tmp_path), "step_000000002", "arrays.npz"),
              "r+b") as f:
        f.seek(100)
        f.write(b"\x00" * 64)
    restored, step, _ = ck.restore(state)
    assert step == 1


# ---------------------------------------------------------------------------
# Fault tolerance: injected failures must not change the final model
# ---------------------------------------------------------------------------


def _run_loop(tmp_path, fail_at=None, steps=12):
    cfg, tcfg = _tiny()
    dcfg = _dcfg(cfg)
    state = init_train_state(cfg, tcfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, tcfg))
    inj = FaultInjector(fail_at) if fail_at else None
    state, info = train_loop(
        step, state, dcfg,
        LoopConfig(total_steps=steps, ckpt_every=4, log_every=100),
        str(tmp_path), fault_injector=inj, log=lambda s: None,
    )
    return state, info


def test_fault_recovery_bitexact(tmp_path):
    clean_state, _ = _run_loop(tmp_path / "clean")
    faulty_state, _ = _run_loop(tmp_path / "faulty",
                                fail_at={6: "sim-preemption",
                                         9: "sim-device-loss"})
    for a, b in zip(jax.tree_util.tree_leaves(clean_state["params"]),
                    jax.tree_util.tree_leaves(faulty_state["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_resume_from_checkpoint_continues(tmp_path):
    # run 8 steps, then "restart the job" and run to 12
    cfg, tcfg = _tiny()
    dcfg = _dcfg(cfg)
    step = jax.jit(make_train_step(cfg, tcfg))
    s0 = init_train_state(cfg, tcfg, jax.random.PRNGKey(0))
    _run = lambda st, n: train_loop(
        step, st, dcfg, LoopConfig(total_steps=n, ckpt_every=4,
                                   log_every=100),
        str(tmp_path), log=lambda s: None)
    st, _ = _run(s0, 8)
    st2, info = _run(init_train_state(cfg, tcfg, jax.random.PRNGKey(0)), 12)
    # resumed run must start from step 8 checkpoint, not step 0
    assert info["history"][0]["step"] == 9


# ---------------------------------------------------------------------------
# Gradient compression
# ---------------------------------------------------------------------------


def test_topk_error_feedback_invariant():
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(64,)),
                          jnp.float32)}
    e = init_error(g)
    cfg = CompressionConfig(kind="topk", topk_frac=0.1)
    c, e_new = compress(g, e, cfg)
    # exact invariant: compressed + residual == grad + old error
    np.testing.assert_allclose(c["w"] + e_new["w"], g["w"], rtol=1e-6)
    # sparsity
    assert int((c["w"] != 0).sum()) <= max(1, int(64 * 0.1)) + 1


def test_int8_compression_unbiased():
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(size=(512,)).astype(np.float32))}
    e = init_error(g)
    cfg = CompressionConfig(kind="int8")
    samples = []
    for i in range(50):
        c, _ = compress(g, e, cfg, key=jax.random.PRNGKey(i))
        samples.append(np.asarray(c["w"]))
    mean = np.mean(samples, axis=0)
    np.testing.assert_allclose(mean, g["w"], atol=0.02)


def test_training_with_topk_compression_converges():
    cfg, _ = _tiny()
    tcfg = TrainConfig(
        optimizer=adamw.AdamWConfig(lr=1e-2, warmup_steps=2,
                                    total_steps=100, weight_decay=0.0),
        compression=CompressionConfig(kind="topk", topk_frac=0.3),
    )
    dcfg = _dcfg(cfg)
    state = init_train_state(cfg, tcfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, tcfg))
    it = DataIterator(dcfg, prefetch=0)
    losses = []
    rng = jax.random.PRNGKey(0)
    for i in range(40):
        state, m = step(state, next(it), jax.random.fold_in(rng, i))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3


# ---------------------------------------------------------------------------
# Optimizer unit behaviour
# ---------------------------------------------------------------------------


def test_adamw_matches_reference_formula():
    cfg = adamw.AdamWConfig(lr=0.1, b1=0.9, b2=0.99, eps=1e-8,
                            weight_decay=0.0, clip_norm=1e9,
                            warmup_steps=0, total_steps=10**9,
                            min_lr_frac=1.0)
    p = {"w": jnp.asarray([1.0, -2.0])}
    g = {"w": jnp.asarray([0.5, 0.5])}
    st = adamw.init_state(p)
    newp, st, _ = adamw.apply_updates(p, g, st, cfg)
    mu = 0.1 * 0.5
    nu = 0.01 * 0.25
    upd = (mu / 0.1) / (np.sqrt(nu / 0.01) + 1e-8)
    np.testing.assert_allclose(newp["w"][0], 1.0 - 0.1 * upd, rtol=1e-5)


def test_schedule_warmup_and_cosine():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110,
                            min_lr_frac=0.1)
    assert float(adamw.schedule(cfg, jnp.int32(5))) == pytest.approx(0.5)
    assert float(adamw.schedule(cfg, jnp.int32(10))) == pytest.approx(1.0)
    assert float(adamw.schedule(cfg, jnp.int32(110))) == pytest.approx(0.1)
