"""Benchmark-trajectory gate (benchmarks/trajectory.py, DESIGN.md §10).

The gate's whole value is that it FAILS on regressions and stays quiet on
the shipped baseline: every comparison rule gets a seeded positive (a
mutated current run that must fail) and the clean self-compare negative,
plus the acceptance check that the committed ``BENCH_baseline.json``
passes against a fresh collection.
"""
import json

import pytest

from benchmarks import trajectory


@pytest.fixture(scope="module")
def snap():
    """One small-resolution collection shared by every test (module-scoped:
    collect() plans 4 networks; cheap but not free)."""
    return trajectory.collect(resolutions=[56])


def _copy(d):
    return json.loads(json.dumps(d))


def _some_row(data):
    return next(iter(sorted(data["networks"])))


def test_collect_schema(snap):
    assert snap["schema"] == trajectory.SCHEMA_VERSION
    assert len(snap["networks"]) == 4  # all benchmarked archs at res 56
    for name, rec in snap["networks"].items():
        assert set(rec) == {"traffic", "flags", "blocks"}
        assert rec["traffic"]["mb_bf16"] < rec["traffic"]["mb_fp32"] \
            < rec["traffic"]["mb_unfused"]
        assert rec["flags"]["traffic_ok"] is True
        assert all(set(b) == {"kinds", "passes", "segments"}
                   for b in rec["blocks"])


def test_self_compare_is_clean(snap):
    failures, notes = trajectory.compare(snap, _copy(snap))
    assert failures == [] and notes == []


def test_traffic_regression_fails(snap):
    cur = _copy(snap)
    row = _some_row(cur)
    cur["networks"][row]["traffic"]["mb_bf16"] *= 1.01
    failures, _ = trajectory.compare(snap, cur)
    assert any("mb_bf16 regressed" in f and row in f for f in failures)


def test_traffic_improvement_is_a_note_not_a_failure(snap):
    cur = _copy(snap)
    row = _some_row(cur)
    cur["networks"][row]["traffic"]["mb_fp32"] *= 0.9
    failures, notes = trajectory.compare(snap, cur)
    assert failures == []
    assert any("mb_fp32 improved" in n for n in notes)


def test_flag_drop_fails(snap):
    cur = _copy(snap)
    row = _some_row(cur)
    assert snap["networks"][row]["flags"]["traffic_ok"] is True
    cur["networks"][row]["flags"]["traffic_ok"] = False
    failures, _ = trajectory.compare(snap, cur)
    assert any("flag traffic_ok dropped" in f for f in failures)


def test_added_pass_fails(snap):
    """fused3 -> pw+fused2 style downgrade: pass count grows."""
    cur = _copy(snap)
    row = _some_row(cur)
    blk = cur["networks"][row]["blocks"][0]
    blk["passes"] += 1
    failures, _ = trajectory.compare(snap, cur)
    assert any("plan downgraded" in f and f"{row}/block0" in f
               for f in failures)


def test_segment_split_fails_even_at_equal_passes(snap):
    """The fusedmb -> mb+pw trap: mb is an XLA pass so the kernel-pass
    count can stay flat, but the segment split still fails the gate."""
    cur = _copy(snap)
    row = _some_row(cur)
    blk = cur["networks"][row]["blocks"][0]
    blk["segments"] += 1
    blk["kinds"] = blk["kinds"] + "+mb"
    failures, _ = trajectory.compare(snap, cur)
    assert any("plan downgraded" in f for f in failures)


def test_kind_change_no_worse_is_a_note(snap):
    cur = _copy(snap)
    row = _some_row(cur)
    cur["networks"][row]["blocks"][0]["kinds"] = "something_else"
    failures, notes = trajectory.compare(snap, cur)
    assert failures == []
    assert any("plan changed (no worse)" in n for n in notes)


def test_missing_row_fails_new_row_notes(snap):
    cur = _copy(snap)
    row = _some_row(cur)
    rec = cur["networks"].pop(row)
    cur["networks"]["brand_new/res7"] = rec
    failures, notes = trajectory.compare(snap, cur)
    assert any("row missing" in f and row in f for f in failures)
    assert any("brand_new/res7: new row" in n for n in notes)


def test_block_count_change_fails(snap):
    cur = _copy(snap)
    row = _some_row(cur)
    cur["networks"][row]["blocks"].pop()
    failures, _ = trajectory.compare(snap, cur)
    assert any("block count changed" in f for f in failures)


def test_write_and_check_roundtrip(tmp_path, snap):
    path = str(tmp_path / "baseline.json")
    trajectory.write_baseline(path, baseline=snap)
    assert trajectory.check_baseline(path, current=_copy(snap)) == 0
    bad = _copy(snap)
    bad["networks"][_some_row(bad)]["traffic"]["mb_unfused"] *= 2
    assert trajectory.check_baseline(path, current=bad) == 1


def test_shipped_baseline_matches_fresh_collection():
    """The acceptance gate CI runs: the committed BENCH_baseline.json must
    pass against a from-scratch collection at the full resolution set."""
    assert trajectory.check_baseline() == 0
